"""Pallas TPU flash-decode kernel (split-KV single-token attention).

One query token attends a long KV cache. The cache's sequence dim is split
across the grid; each split emits a partial (max, sum, weighted-V)
triple, and the tiny log-sum-exp combine runs as plain jnp in the wrapper
(``repro.kernels.ops.decode_attention``). This is the same structure the
serving engine's sequence-sharded distributed decode uses across chips —
here it is the *within-chip* version that turns HBM cache reads into
streamed VMEM blocks.

This kernel assumes a contiguous per-sequence cache (the static-batch
engine's ring buffers). ``repro.kernels.paged_decode`` is the block-table
variant for the continuous-batching scheduler's paged KV cache: same
partials and the same LSE combine, but each grid step DMAs one *page*
resolved through a scalar-prefetched block table.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fd_kernel(q_ref, k_ref, v_ref, m_ref, l_ref, o_ref, *,
               scale: float, softcap: Optional[float], block_k: int,
               kv_len: int):
    si = pl.program_id(1)                     # kv split index
    q = q_ref[0].astype(jnp.float32)          # (G, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = si * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    s = jnp.where(k_pos < kv_len, s, _NEG)    # (G, bk)
    m = s.max(axis=-1)                        # (G,)
    p = jnp.exp(s - m[:, None])
    lse = p.sum(axis=-1)
    v = v_ref[0].astype(jnp.float32)          # (bk, d)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_ref[0, 0] = m
    l_ref[0, 0] = lse
    o_ref[0, 0] = pv


def decode_attention_partials(q: jnp.ndarray, k_cache: jnp.ndarray,
                              v_cache: jnp.ndarray, *,
                              softcap: Optional[float] = None,
                              scale: Optional[float] = None,
                              block_k: int = 512,
                              interpret: bool = False):
    """q: (B, H, d); caches: (B, S, KVH, d).

    Returns partials (m, l, o) with a leading kv-split dim for the LSE
    combine: m/l (B*KVH, splits, G), o (B*KVH, splits, G, d).
    """
    B, H, d = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_k = min(block_k, S)
    pk = (-S) % block_k
    kp = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k_cache
    vp = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v_cache
    n_s = (S + pk) // block_k

    qf = q.reshape(B * KVH, G, d)
    kf = jnp.moveaxis(kp, 2, 1).reshape(B * KVH, S + pk, d)
    vf = jnp.moveaxis(vp, 2, 1).reshape(B * KVH, S + pk, d)

    kernel = functools.partial(_fd_kernel, scale=scale, softcap=softcap,
                               block_k=block_k, kv_len=S)
    m, lse, o = pl.pallas_call(
        kernel,
        grid=(B * KVH, n_s),
        in_specs=[
            pl.BlockSpec((1, G, d), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, si: (b, si, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, 1, G), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, 1, G, d), lambda b, si: (b, si, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * KVH, n_s, G), jnp.float32),
            jax.ShapeDtypeStruct((B * KVH, n_s, G), jnp.float32),
            jax.ShapeDtypeStruct((B * KVH, n_s, G, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return m, lse, o
