"""Optimized-planner sweep: re-run dry-run cells with the blueprint's
``optimize=True`` configuration (the §Perf hillclimb winners generalized)
and record them next to the paper-faithful baselines.

Run:  PYTHONPATH=src python -m benchmarks.opt_sweep [shape ...]
Writes benchmarks/results/dryrun_opt/<arch>__<shape>__<mesh>.json.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json
import pathlib
import sys

from repro.configs.base import SHAPES, cell_is_runnable
from repro.configs.registry import ARCHS, get_arch, get_shape
from repro.core.blueprint import optimized_cfg_overrides, suggest_plan
from repro.launch.mesh import make_production_mesh

OUT = pathlib.Path("benchmarks/results/dryrun_opt")


def main() -> None:
    from repro.launch import dryrun
    shapes = sys.argv[1:] or ["train_4k", "decode_32k"]
    OUT.mkdir(parents=True, exist_ok=True)
    for shape_name in shapes:
        for arch in ARCHS:
            if not cell_is_runnable(arch, shape_name):
                continue
            path = OUT / f"{arch}__{shape_name}__pod16x16.json"
            if path.exists():
                print(f"[skip-cached] {path.name}")
                continue
            cfg = get_arch(arch)
            shape = get_shape(shape_name)
            mesh = make_production_mesh(multi_pod=False)
            plan = suggest_plan(cfg, shape, mesh, optimize=True)
            plan_over = {"param_rules": plan.param_rules,
                         "act_rules": plan.act_rules,
                         "remat": plan.remat,
                         "serve_param_dtype": plan.serve_param_dtype}
            cfg_over = optimized_cfg_overrides(cfg, shape)
            print(f"[opt] {arch} x {shape_name} cfg={cfg_over} "
                  f"notes={list(plan.notes)}", flush=True)
            try:
                rec = dryrun.run_cell(arch, shape_name, False,
                                      overrides=plan_over,
                                      cfg_overrides=cfg_over)
                rec["optimized"] = True
                rec["cfg_overrides"] = cfg_over
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": "pod16x16", "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
            path.write_text(json.dumps(rec, indent=1))
            if rec.get("status") == "ok":
                r = rec["roofline"]
                print(f"  -> bound={rec['bound_s']:.3f}s "
                      f"(comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
                      f"coll={r['collective_s']:.3f})", flush=True)
            else:
                print(f"  -> {rec['status']}: {rec.get('error','')[:120]}",
                      flush=True)


if __name__ == "__main__":
    main()
