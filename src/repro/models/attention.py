"""Attention: GQA (+bias/qk-norm/softcap/sliding-window) and MLA (deepseek-v2).

The sequence-level math lives in ``attend`` — a chunked, online-softmax
(flash-structured) implementation in pure XLA ops. It is the reference path
used for CPU smoke tests and the multi-pod dry-run; ``repro.kernels``
contains the Pallas TPU kernels that compute the same function (allclose
tested) for real deployments.

Causality is exploited *structurally*: the python-level loop over query
blocks only visits the key/value chunks a block can see, so compiled HLO
FLOPs match optimal causal attention (this matters for the roofline's
useful-FLOP ratio).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import soft_cap
from repro.models.rope import apply_rope, rotary_dim
from repro.models.schema import ParamSpec

_NEG = -1e30


# ---------------------------------------------------------------------------
# core chunked attention
# ---------------------------------------------------------------------------

def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
           causal: bool = True,
           window: Optional[int] = None,
           softcap: Optional[float] = None,
           scale: Optional[float] = None,
           q_offset: int = 0,
           q_block: int = 512,
           kv_block: int = 1024,
           mask_opt: bool = False) -> jnp.ndarray:
    """q: (B,Sq,H,hd) k/v: (B,Skv,KVH,hd_v) -> (B,Sq,H,hd_v)."""
    B, Sq, H, hd = q.shape
    _, Skv, KVH, hdv = v.shape
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qr = q.reshape(B, Sq, KVH, G, hd)

    small = Sq * Skv <= 4096 * 4096 and (Sq <= q_block or Skv <= kv_block
                                         or not causal)
    if small or (not causal and Skv <= 4096):
        # short-kv non-causal (e.g. cross-attention to a 1500-frame encoder)
        return _direct(qr, k, v, causal, window, softcap, scale, q_offset
                       ).reshape(B, Sq, H, hdv)

    # scale blocks with sequence length to bound HLO op count
    q_block = min(2048, max(q_block, Sq // 32))
    kv_block = min(4096, max(kv_block, Skv // 16))
    n_q = -(-Sq // q_block)
    outs = []
    for i in range(n_q):
        q0, q1 = i * q_block, min((i + 1) * q_block, Sq)
        qi = qr[:, q0:q1]
        if causal:
            kend = min(Skv, -(-(q_offset + q1) // kv_block) * kv_block)
        else:
            kend = Skv
        kstart = 0
        if window is not None:
            kstart = max(0, (q_offset + q0 - (window - 1)) // kv_block * kv_block)
        if not mask_opt:
            outs.append(_scan_chunk(qi, k[:, kstart:kend], v[:, kstart:kend],
                                    causal, window, softcap, scale,
                                    q_offset + q0, kstart, kv_block))
            continue
        # §Perf lever: interior kv chunks are fully visible to every query in
        # the block — no mask tensors needed there. Only the diagonal chunk
        # (causal) and the window's trailing edge get the masked path.
        qlo, qhi = q_offset + q0, q_offset + q1 - 1
        interior_end = kstart
        for j in range(kstart, kend, kv_block):
            k_hi = j + kv_block - 1
            ok = (not causal or k_hi <= qlo) and \
                (window is None or (qhi - j) < window)
            if ok and j == interior_end:
                interior_end = j + kv_block
            else:
                break
        carry = None
        if interior_end > kstart:
            carry = _scan_chunk(qi, k[:, kstart:interior_end],
                                v[:, kstart:interior_end],
                                False, None, softcap, scale,
                                q_offset + q0, kstart, kv_block,
                                return_carry=True)
        if interior_end < kend:
            carry = _scan_chunk(qi, k[:, interior_end:kend],
                                v[:, interior_end:kend],
                                causal, window, softcap, scale,
                                q_offset + q0, interior_end, kv_block,
                                carry=carry, return_carry=True)
        m, lse, acc = carry
        o = acc / jnp.maximum(lse, 1e-30)[..., None]
        outs.append(jnp.moveaxis(o, 3, 1).astype(v.dtype))
    return jnp.concatenate(outs, axis=1).reshape(B, Sq, H, hdv)


def _mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _direct(qr, k, v, causal, window, softcap, scale, q_offset):
    B, Sq, KVH, G, hd = qr.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    s = soft_cap(s, softcap)
    if causal or window is not None:
        q_pos = q_offset + jnp.arange(Sq)
        m = _mask(q_pos, jnp.arange(Skv), causal, window)
        s = jnp.where(m[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _scan_chunk(qi, ks, vs, causal, window, softcap, scale,
                q_pos0, k_pos0, kv_block, carry=None, return_carry=False):
    """Online-softmax over kv chunks for one query block.

    ``causal=False, window=None`` is the unmasked interior path — no mask
    tensors are materialised (§Perf lever ``attn_mask_opt``).
    """
    B, qb, KVH, G, hd = qi.shape
    Sk = ks.shape[1]
    hdv = vs.shape[-1]
    nkc = Sk // kv_block
    assert nkc * kv_block == Sk, (Sk, kv_block)
    kc = jnp.moveaxis(ks.reshape(B, nkc, kv_block, KVH, -1), 1, 0)
    vc = jnp.moveaxis(vs.reshape(B, nkc, kv_block, KVH, -1), 1, 0)
    kpos = (k_pos0 + jnp.arange(Sk)).reshape(nkc, kv_block)
    q_pos = q_pos0 + jnp.arange(qb)

    if carry is None:
        carry = (jnp.full((B, KVH, G, qb), _NEG, jnp.float32),
                 jnp.zeros((B, KVH, G, qb), jnp.float32),
                 jnp.zeros((B, KVH, G, qb, hdv), jnp.float32))

    masked = causal or window is not None

    def body(c, xs):
        m, lse, acc = c
        kcb, vcb, kp = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kcb,
                       preferred_element_type=jnp.float32) * scale
        s = soft_cap(s, softcap)
        if masked:
            msk = _mask(q_pos, kp, causal, window)
            s = jnp.where(msk[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = lse * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vcb.dtype), vcb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    from repro.models.flags import unroll_scans
    if unroll_scans():
        for j in range(nkc):
            carry, _ = body(carry, (kc[j], vc[j], kpos[j]))
    else:
        carry, _ = jax.lax.scan(body, carry, (kc, vc, kpos))
    if return_carry:
        return carry
    m, lse, acc = carry
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    return jnp.moveaxis(out, 3, 1).astype(vs.dtype)  # (B,qb,KVH,G,hdv)


def decode_attend(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                  *, valid_len: Optional[jnp.ndarray] = None,
                  start_len: Optional[jnp.ndarray] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Single-position attention over a full cache.

    q: (B,1,H,hd); caches: (B,S,KVH,hd). valid_len masks slots >= valid_len;
    start_len (paged sliding-window layers) additionally masks slots below
    it — the paged cache stores absolute positions, so the window is a mask
    rather than a ring write (cf. ``gqa_decode``).
    """
    B, _, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qr = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = soft_cap(s, softcap)
    if valid_len is not None:
        ok = jnp.arange(S)[None] < valid_len[:, None]          # (B,S)
        if start_len is not None:
            ok &= jnp.arange(S)[None] >= start_len[:, None]
        s = jnp.where(ok[:, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, -1).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def gqa_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": ParamSpec((d, H * hd), ("embed", "heads")),
        "wk": ParamSpec((d, KVH * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, KVH * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((H * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((H * hd,), ("heads",), init="zeros")
        p["bk"] = ParamSpec((KVH * hd,), ("kv_heads",), init="zeros")
        p["bv"] = ParamSpec((KVH * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        p["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return p


def _qkv(cfg: ModelConfig, p, x, cos, sin, positions_offset_rope=True,
         n_heads=None, n_kv_heads=None):
    """QKV projections. ``n_heads``/``n_kv_heads`` override the config when
    ``p`` holds one shard's head-slice of the weights (tensor-parallel
    paged decode) — every per-head op below is independent of the head
    count, so a slice computes exactly the corresponding slice of the
    full-width result."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H = cfg.n_heads if n_heads is None else n_heads
    KVH = cfg.n_kv_heads if n_kv_heads is None else n_kv_heads
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KVH, hd)
    v = v.reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        from repro.models.layers import rmsnorm
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    rd = rotary_dim(cfg)
    q = apply_rope(q, cos, sin, rd)
    k = apply_rope(k, cos, sin, rd)
    return q, k, v


def gqa_train(cfg: ModelConfig, p, x, cos, sin, *, local: bool,
              causal: bool = True, q_offset: int = 0):
    q, k, v = _qkv(cfg, p, x, cos, sin)
    window = cfg.sliding_window if local else None
    o = attend(q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap,
               q_offset=q_offset, mask_opt=cfg.attn_mask_opt)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def gqa_prefill(cfg: ModelConfig, p, x, cos, sin, *, local: bool):
    """Returns (y, kv_to_cache)."""
    q, k, v = _qkv(cfg, p, x, cos, sin)
    window = cfg.sliding_window if local else None
    o = attend(q, k, v, causal=True, window=window, softcap=cfg.attn_softcap,
               mask_opt=cfg.attn_mask_opt)
    B, S = x.shape[:2]
    y = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    return y, {"k": k, "v": v}


FP8_MAX = 448.0  # float8_e4m3 largest finite value


def kv_quant_mode(cfg: ModelConfig) -> Optional[str]:
    """Resolve ``cfg.cache_quant`` to a quantisation mode.

    ``False`` -> None, ``True``/``"int8"`` -> "int8", ``"fp8"`` -> "fp8"
    (float8_e4m3 values + fp32 scales). Non-empty strings are truthy, so
    every existing ``if cfg.cache_quant:`` branch keeps working for fp8.
    """
    q = cfg.cache_quant
    if not q:
        return None
    if q is True:
        return "int8"
    if q not in ("int8", "fp8"):
        raise ValueError(f"cache_quant must be bool, 'int8' or 'fp8': {q!r}")
    return q


def quantize_kv(x: jnp.ndarray, mode: str = "int8"):
    """Per-(position, kv-head) symmetric quantisation. x: (..., hd).

    "int8": values in [-127, 127], scale = absmax/127. "fp8": values cast
    to float8_e4m3fn after scaling absmax onto the format's max normal
    (448) — the cast itself performs the 4-bit-mantissa rounding. Both
    return (q, fp32 scale) with dequant ``q.astype(f32) * scale``.
    """
    a = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    # explicit reciprocal multiply: XLA strength-reduces x/const to it
    # under jit anyway — writing it out keeps eager calls and the Pallas
    # in-kernel quantisation bit-identical to the jitted path
    if mode == "fp8":
        scale = jnp.maximum(a * jnp.float32(1.0 / FP8_MAX), 1e-12)
        q = (x.astype(jnp.float32) / scale[..., None]).astype(
            jnp.float8_e4m3fn)
    else:
        scale = jnp.maximum(a * jnp.float32(1.0 / 127.0), 1e-12)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return q, scale


def gqa_decode(cfg: ModelConfig, p, x, cos, sin, cache: Dict[str, jnp.ndarray],
               cur_len: jnp.ndarray, *, local: bool):
    """x: (B,1,D). Writes new kv at slot cur_len % capacity, attends cache.

    Returns (y, new_cache). The cache for a local (sliding-window) layer has
    capacity == window, so the ring-write implements the window eviction.
    With ``cfg.cache_quant`` the cache holds int8 values + per-(pos, head)
    scales (§Perf lever: halves cache HBM footprint and read bytes).
    """
    B = x.shape[0]
    dt = x.dtype
    q, k_new, v_new = _qkv(cfg, p, x, cos, sin)
    cap = cache["k"].shape[1]
    slot = (cur_len % cap).astype(jnp.int32)
    if cfg.cache_quant:
        mode = kv_quant_mode(cfg)
        k8, ks = quantize_kv(k_new, mode)
        v8, vs_ = quantize_kv(v_new, mode)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k8, (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v8, (0, slot, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                               (0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(cache["v_scale"], vs_,
                                               (0, slot, 0))
        k_deq = (k_cache.astype(dt) * k_scale[..., None].astype(dt))
        v_deq = (v_cache.astype(dt) * v_scale[..., None].astype(dt))
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_deq = k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                                       (0, slot, 0, 0))
        v_deq = v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                                       (0, slot, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
    valid = jnp.minimum(cur_len + 1, cap) * jnp.ones((B,), jnp.int32)
    o = decode_attend(q, k_deq, v_deq, valid_len=valid,
                      softcap=cfg.attn_softcap)
    y = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return y, new_cache


def _paged_write_attend(cfg: ModelConfig, pool: Dict[str, jnp.ndarray],
                        q: jnp.ndarray, k_new: jnp.ndarray,
                        v_new: jnp.ndarray, seq_lens: jnp.ndarray,
                        block_table: jnp.ndarray, *, local: bool):
    """Write one decode token's K/V into its page and attend the pages.

    The head-width-agnostic core of the paged decode step: ``pool`` holds
    one pool's leaves (num_pages, page_size, KVH', hd) where KVH' is either
    the full kv-head count (tp=1) or one shard's slice — the same function
    serves both, which is what keeps the tensor-parallel path's math
    identical to the unsharded one per head. q: (B,1,H',hd); k_new/v_new:
    (B,1,KVH',hd). Returns (o (B,1,H',hd), new_pool).
    """
    B = q.shape[0]
    dt = q.dtype
    ps = pool["k_pages"].shape[1]
    n_pg = block_table.shape[1]
    pos = seq_lens.astype(jnp.int32)                       # write position
    page = jnp.take_along_axis(block_table, (pos // ps)[:, None],
                               axis=1)[:, 0]
    slot = pos % ps
    if cfg.cache_quant:
        mode = kv_quant_mode(cfg)
        k8, ks = quantize_kv(k_new, mode)
        v8, vs_ = quantize_kv(v_new, mode)
        k_pages = pool["k_pages"].at[page, slot].set(k8[:, 0])
        v_pages = pool["v_pages"].at[page, slot].set(v8[:, 0])
        k_sc = pool["k_scale_pages"].at[page, slot].set(ks[:, 0])
        v_sc = pool["v_scale_pages"].at[page, slot].set(vs_[:, 0])
        k_deq = (k_pages[block_table].astype(dt)
                 * k_sc[block_table][..., None].astype(dt))
        v_deq = (v_pages[block_table].astype(dt)
                 * v_sc[block_table][..., None].astype(dt))
        new_pool = {"k_pages": k_pages, "v_pages": v_pages,
                    "k_scale_pages": k_sc, "v_scale_pages": v_sc}
    else:
        k_pages = pool["k_pages"].at[page, slot].set(k_new[:, 0].astype(
            pool["k_pages"].dtype))
        v_pages = pool["v_pages"].at[page, slot].set(v_new[:, 0].astype(
            pool["v_pages"].dtype))
        k_deq = k_pages[block_table]
        v_deq = v_pages[block_table]
        new_pool = {"k_pages": k_pages, "v_pages": v_pages}
    KVH, hd = k_deq.shape[-2], k_deq.shape[-1]
    k_deq = k_deq.reshape(B, n_pg * ps, KVH, hd)
    v_deq = v_deq.reshape(B, n_pg * ps, KVH, hd)
    valid = pos + 1
    start = None
    if local and cfg.sliding_window:
        start = jnp.maximum(valid - cfg.sliding_window, 0)
    o = decode_attend(q, k_deq, v_deq, valid_len=valid, start_len=start,
                      softcap=cfg.attn_softcap)
    return o, new_pool


def shard_gqa_params(cfg: ModelConfig, p, s: int, tp: int):
    """Head-slice of one GQA layer's projection params for shard ``s``.

    Columns of wq/wk/wv are head-major, so shard ``s`` owns the contiguous
    column blocks of its query heads ``[s*H/tp, (s+1)*H/tp)`` and kv heads
    ``[s*KVH/tp, (s+1)*KVH/tp)``. qk-norm scales are per-head-dim and stay
    replicated; ``wo`` is not sliced — the combine concatenates head
    outputs (the shard_map path all-gathers them) and applies the full
    output projection, which keeps tp>1 bitwise identical to tp=1.
    """
    hd = cfg.resolved_head_dim
    Hs = cfg.n_heads // tp * hd
    Ks = cfg.n_kv_heads // tp * hd
    out = {"wq": p["wq"][:, s * Hs:(s + 1) * Hs],
           "wk": p["wk"][:, s * Ks:(s + 1) * Ks],
           "wv": p["wv"][:, s * Ks:(s + 1) * Ks]}
    if cfg.qkv_bias:
        out["bq"] = p["bq"][s * Hs:(s + 1) * Hs]
        out["bk"] = p["bk"][s * Ks:(s + 1) * Ks]
        out["bv"] = p["bv"][s * Ks:(s + 1) * Ks]
    if cfg.qk_norm:
        out["q_norm"] = p["q_norm"]
        out["k_norm"] = p["k_norm"]
    return out


def _gqa_paged_decode_loop(cfg, p, x, cos, sin, cache, seq_lens,
                           block_table, *, local, tp):
    """Unrolled shard-group decode: the per-shard body runs ``tp`` times in
    one program (single-host simulation of the shard_map layout)."""
    B = x.shape[0]
    Hs = cfg.n_heads // tp
    KVHs = cfg.n_kv_heads // tp
    o_parts, pools = [], []
    for s in range(tp):
        p_s = shard_gqa_params(cfg, p, s, tp)
        pool_s = {k: v[s] for k, v in cache.items()}
        q, k_new, v_new = _qkv(cfg, p_s, x, cos, sin,
                               n_heads=Hs, n_kv_heads=KVHs)
        o_s, pool_s = _paged_write_attend(cfg, pool_s, q, k_new, v_new,
                                          seq_lens, block_table, local=local)
        o_parts.append(o_s)
        pools.append(pool_s)
    o = jnp.concatenate(o_parts, axis=2)         # head-axis "all_gather"
    new_cache = {k: jnp.stack([pools[s][k] for s in range(tp)])
                 for k in cache}
    y = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return y, new_cache


def _gqa_paged_decode_shard_map(cfg, p, x, cos, sin, cache, seq_lens,
                                block_table, *, local, shard):
    """Shard-group decode as one program per device: pools and projection
    weights partition on the group's mesh axis, the per-shard body is the
    same ``_qkv`` + ``_paged_write_attend`` the loop path runs, and the
    only wire traffic is the tiny (B,1,H,hd) head all_gather before the
    replicated output projection."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.collectives import shard_map_compat

    tp, ax = shard.tp, shard.axis
    B = x.shape[0]
    Hs = cfg.n_heads // tp
    KVHs = cfg.n_kv_heads // tp
    sliced = {"wq": p["wq"].reshape(x.shape[-1], tp, -1),
              "wk": p["wk"].reshape(x.shape[-1], tp, -1),
              "wv": p["wv"].reshape(x.shape[-1], tp, -1)}
    sliced_specs = {k: P(None, ax, None) for k in sliced}
    if cfg.qkv_bias:
        for k in ("bq", "bk", "bv"):
            sliced[k] = p[k].reshape(tp, -1)
            sliced_specs[k] = P(ax, None)
    repl = {k: p[k] for k in ("q_norm", "k_norm") if cfg.qk_norm}
    pool_specs = {k: P(ax) for k in cache}

    def body(sl, rp, pool, xx, cc, ss, lens, bt):
        p_s = {k: v[:, 0] if v.ndim == 3 else v[0] for k, v in sl.items()}
        p_s.update(rp)
        pool_s = {k: v[0] for k, v in pool.items()}
        q, k_new, v_new = _qkv(cfg, p_s, xx, cc, ss,
                               n_heads=Hs, n_kv_heads=KVHs)
        o_s, pool_s = _paged_write_attend(cfg, pool_s, q, k_new, v_new,
                                          lens, bt, local=local)
        o = jax.lax.all_gather(o_s, ax, axis=2, tiled=True)  # (B,1,H,hd)
        return o, {k: v[None] for k, v in pool_s.items()}

    fn = shard_map_compat(
        body, mesh=shard.mesh,
        in_specs=(sliced_specs, {k: P() for k in repl}, pool_specs,
                  P(), P(), P(), P(), P()),
        out_specs=(P(), pool_specs))
    o, new_cache = fn(sliced, repl, cache, x, cos, sin, seq_lens,
                      block_table.astype(jnp.int32))
    y = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return y, new_cache


def gqa_paged_decode(cfg: ModelConfig, p, x, cos, sin,
                     cache: Dict[str, jnp.ndarray], seq_lens: jnp.ndarray,
                     block_table: jnp.ndarray, *, local: bool, shard=None):
    """Paged-KV decode step: write the new token's K/V into its page, then
    attend the sequence's pages via the block table.

    x: (B,1,D); seq_lens: (B,) live token counts (the new token lands at
    position ``seq_lens[b]``); block_table: (B, n_pg) page ids into the
    layer's pools ``cache["k_pages"]``/``cache["v_pages"]`` of shape
    (num_pages, page_size, KVH, hd). This is the pure-XLA path (CPU smoke
    tests / dry-run); ``repro.kernels.paged_decode`` computes the same
    function on TPU without materialising the gathered cache. Sliding-window
    layers mask ``[len+1-window, len]`` instead of ring-writing — pages hold
    absolute positions.

    ``shard`` (a ``repro.parallel.context.ShardGroup`` with tp > 1) runs
    the head-sharded tensor-parallel path instead: pool leaves carry a
    leading shard axis, each shard computes its query/kv head slice against
    its own pool slice, and the head-axis concat + full output projection
    keep the result byte-identical to tp=1 (see docs/sharding.md).
    """
    if shard is not None and shard.tp > 1:
        if shard.use_shard_map:
            return _gqa_paged_decode_shard_map(
                cfg, p, x, cos, sin, cache, seq_lens, block_table,
                local=local, shard=shard)
        return _gqa_paged_decode_loop(cfg, p, x, cos, sin, cache, seq_lens,
                                      block_table, local=local, tp=shard.tp)
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x, cos, sin)
    o, new_cache = _paged_write_attend(cfg, cache, q, k_new, v_new,
                                       seq_lens, block_table, local=local)
    y = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return y, new_cache


def _chunk_attend(q, k, v, *, q_abs, total, window, softcap, scale=None):
    """Masked direct-softmax attention for a prompt chunk over the gathered
    (dequantised) pages. q: (B,S,H,hd); k/v: (B,K,KVH,hd); q_abs: (B,S)
    absolute query positions; total: (B,) live token count after the chunk
    lands (= start + chunk_len). Same einsum/precision structure as
    ``decode_attend``, batched over the chunk's query rows."""
    B, S, H, hd = q.shape
    K, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qr = q.reshape(B, S, KVH, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    s = soft_cap(s, softcap)
    k_pos = jnp.arange(K, dtype=jnp.int32)
    ok = (k_pos[None, None] <= q_abs[..., None]) \
        & (k_pos[None, None] < total[:, None, None])
    if window is not None:
        ok &= (q_abs[..., None] - k_pos[None, None]) < window
    s = jnp.where(ok[:, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(v.dtype)


def _paged_prefill_write_attend(cfg: ModelConfig, pool: Dict[str, jnp.ndarray],
                                q: jnp.ndarray, k_new: jnp.ndarray,
                                v_new: jnp.ndarray, start: jnp.ndarray,
                                chunk_len: jnp.ndarray,
                                block_table: jnp.ndarray, *, local: bool):
    """Write one prompt chunk's K/V into its pages, then attend
    prefix+chunk — the chunk-width sibling of ``_paged_write_attend``.

    q: (B,S,H',hd); k_new/v_new: (B,S,KVH',hd) — chunk token ``t`` lands
    at absolute position ``start[b] + t``; rows past ``chunk_len[b]`` are
    padding (not written, output rows unspecified). Head-width-agnostic
    like the decode core, so the tp loop/shard paths reuse it per shard.

    Two callers, and B>1 with ragged ``chunk_len`` (including 0 — all rows
    dead, routed to the sink) is load-bearing for both: chunked prefill
    (one chunk per prefilling slot) and speculative verify
    (``model.paged_verify_step`` — a last-token+drafts chunk per decoding
    slot, where every row's output feeds acceptance).

    With ``repro.models.flags.prefill_kernel()`` set (a trace-time flag)
    the Pallas write+attend pair from ``repro.kernels.paged_prefill``
    computes the same function without materialising the gathered cache.
    """
    from repro.models import flags
    B, S = q.shape[0], q.shape[1]
    dt = q.dtype
    mode = kv_quant_mode(cfg)
    window = cfg.sliding_window if (local and cfg.sliding_window) else None
    if flags.prefill_kernel():
        from repro.kernels import ops as kops
        o, new_pool = kops.paged_prefill(
            q, k_new, v_new, pool, block_table, start, chunk_len,
            quant=mode, softcap=cfg.attn_softcap, window=window,
            interpret=True)
        return o.astype(dt), new_pool
    ps = pool["k_pages"].shape[1]
    n_pg = block_table.shape[1]
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None]     # (B,S)
    live = jnp.arange(S, dtype=jnp.int32)[None] < chunk_len[:, None]
    # dead rows route to the sink page's slot 0 re-writing its own value,
    # so scatter duplicate-index resolution can't clobber a live slot
    pg_idx = jnp.clip(pos // ps, 0, n_pg - 1)
    page = jnp.where(live, jnp.take_along_axis(block_table, pg_idx, axis=1), 0)
    slot = jnp.where(live, pos % ps, 0)
    if mode:
        k8, ks = quantize_kv(k_new, mode)
        v8, vs_ = quantize_kv(v_new, mode)
        sink_k = pool["k_pages"][0, 0]
        sink_v = pool["v_pages"][0, 0]
        sink_ks = pool["k_scale_pages"][0, 0]
        sink_vs = pool["v_scale_pages"][0, 0]
        k8 = jnp.where(live[..., None, None], k8, sink_k)
        v8 = jnp.where(live[..., None, None], v8, sink_v)
        ks = jnp.where(live[..., None], ks, sink_ks)
        vs_ = jnp.where(live[..., None], vs_, sink_vs)
        k_pages = pool["k_pages"].at[page, slot].set(k8)
        v_pages = pool["v_pages"].at[page, slot].set(v8)
        k_sc = pool["k_scale_pages"].at[page, slot].set(ks)
        v_sc = pool["v_scale_pages"].at[page, slot].set(vs_)
        k_deq = (k_pages[block_table].astype(dt)
                 * k_sc[block_table][..., None].astype(dt))
        v_deq = (v_pages[block_table].astype(dt)
                 * v_sc[block_table][..., None].astype(dt))
        new_pool = {"k_pages": k_pages, "v_pages": v_pages,
                    "k_scale_pages": k_sc, "v_scale_pages": v_sc}
    else:
        pdt = pool["k_pages"].dtype
        sink_k = pool["k_pages"][0, 0]
        sink_v = pool["v_pages"][0, 0]
        kw = jnp.where(live[..., None, None], k_new.astype(pdt), sink_k)
        vw = jnp.where(live[..., None, None], v_new.astype(pdt), sink_v)
        k_pages = pool["k_pages"].at[page, slot].set(kw)
        v_pages = pool["v_pages"].at[page, slot].set(vw)
        k_deq = k_pages[block_table]
        v_deq = v_pages[block_table]
        new_pool = {"k_pages": k_pages, "v_pages": v_pages}
    KVH, hd = k_deq.shape[-2], k_deq.shape[-1]
    k_deq = k_deq.reshape(B, n_pg * ps, KVH, hd)
    v_deq = v_deq.reshape(B, n_pg * ps, KVH, hd)
    o = _chunk_attend(q, k_deq, v_deq, q_abs=pos,
                      total=start + chunk_len, window=window,
                      softcap=cfg.attn_softcap)
    return o, new_pool


def _gqa_paged_prefill_loop(cfg, p, x, cos, sin, cache, start, chunk_len,
                            block_table, *, local, tp):
    """Unrolled shard-group fused prefill: the per-shard body runs ``tp``
    times in one program (mirrors ``_gqa_paged_decode_loop`` — prefill
    always takes the loop path; chunk dispatches are rare enough that a
    shard_map variant buys nothing on the simulator)."""
    B, S = x.shape[:2]
    Hs = cfg.n_heads // tp
    KVHs = cfg.n_kv_heads // tp
    o_parts, pools = [], []
    for s in range(tp):
        p_s = shard_gqa_params(cfg, p, s, tp)
        pool_s = {k: v[s] for k, v in cache.items()}
        q, k_new, v_new = _qkv(cfg, p_s, x, cos, sin,
                               n_heads=Hs, n_kv_heads=KVHs)
        o_s, pool_s = _paged_prefill_write_attend(
            cfg, pool_s, q, k_new, v_new, start, chunk_len, block_table,
            local=local)
        o_parts.append(o_s)
        pools.append(pool_s)
    o = jnp.concatenate(o_parts, axis=2)         # head-axis "all_gather"
    new_cache = {k: jnp.stack([pools[s][k] for s in range(tp)])
                 for k in cache}
    y = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    return y, new_cache


def gqa_paged_prefill(cfg: ModelConfig, p, x, cos, sin,
                      cache: Dict[str, jnp.ndarray], start: jnp.ndarray,
                      chunk_len: jnp.ndarray, block_table: jnp.ndarray, *,
                      local: bool, shard=None):
    """Fused chunked-prefill step: write the chunk's K/V directly into its
    pages and attend prefix+chunk in one pass — no dense intermediate, no
    post-hoc ``write_prefill`` copy.

    x: (B,S,D) chunk hidden states; start: (B,) tokens already in the
    pages; chunk_len: (B,) live rows of this chunk; block_table: (B,n_pg).
    Returns (y (B,S,D), new_cache). ``shard`` with tp > 1 runs the
    head-sharded loop path (byte-identical to tp=1, like decode).
    """
    if shard is not None and shard.tp > 1:
        return _gqa_paged_prefill_loop(cfg, p, x, cos, sin, cache, start,
                                       chunk_len, block_table, local=local,
                                       tp=shard.tp)
    B, S = x.shape[:2]
    q, k_new, v_new = _qkv(cfg, p, x, cos, sin)
    o, new_cache = _paged_prefill_write_attend(cfg, cache, q, k_new, v_new,
                                               start, chunk_len, block_table,
                                               local=local)
    y = o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank kv compression + decoupled rope
# ---------------------------------------------------------------------------

def mla_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    # "lora": up-projections sharded on their *input* dim (baseline) — every
    # layer all-reduces the (B,S,H*dh) outputs. "heads": Megatron
    # column-parallel — lora activations replicated (tiny), outputs
    # head-sharded, single AR after wo (§Perf lever, deepseek train cell).
    heads_mode = cfg.mla_shard == "heads"
    lora_axes = ("embed", None) if heads_mode else ("embed", "lora")
    up_axes = (None, "heads") if heads_mode else ("lora", "heads")
    p: Dict[str, ParamSpec] = {
        "wkv_a": ParamSpec((d, kl), lora_axes),
        "wk_pe": ParamSpec((d, dr), ("embed", None)),
        "kv_norm": ParamSpec((kl,), (None,), init="ones"),
        "wkv_b": ParamSpec((kl, H * (dn + dv)), up_axes),
        "wo": ParamSpec((H * dv, d), ("heads", "embed")),
    }
    if ql:
        p["wq_a"] = ParamSpec((d, ql), lora_axes)
        p["q_norm"] = ParamSpec((ql,), (None,), init="ones")
        p["wq_b"] = ParamSpec((ql, H * (dn + dr)), up_axes)
    else:
        p["wq"] = ParamSpec((d, H * (dn + dr)), ("embed", "heads"))
    return p


def _mla_q(cfg, p, x, cos, sin):
    from repro.models.layers import rmsnorm
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    dt = x.dtype
    if cfg.q_lora_rank:
        qc = rmsnorm(x @ p["wq_a"].astype(dt), p["q_norm"], cfg.rms_eps)
        q = qc @ p["wq_b"].astype(dt)
    else:
        q = x @ p["wq"].astype(dt)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, cos, sin, dr)
    return q_nope, q_pe


def _mla_ckv(cfg, p, x, cos, sin):
    from repro.models.layers import rmsnorm
    dt = x.dtype
    c_kv = rmsnorm(x @ p["wkv_a"].astype(dt), p["kv_norm"], cfg.rms_eps)
    k_pe = (x @ p["wk_pe"].astype(dt))[:, :, None, :]       # (B,S,1,dr)
    k_pe = apply_rope(k_pe, cos, sin, cfg.qk_rope_head_dim)
    return c_kv, k_pe[:, :, 0, :]


def mla_train(cfg: ModelConfig, p, x, cos, sin, **_):
    """Direct (non-absorbed) MLA for train/prefill."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = x.dtype
    q_nope, q_pe = _mla_q(cfg, p, x, cos, sin)
    c_kv, k_pe = _mla_ckv(cfg, p, x, cos, sin)
    kv = (c_kv @ p["wkv_b"].astype(dt)).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, dr))], axis=-1)
    o = attend(q, k, v, causal=True, scale=1.0 / math.sqrt(dn + dr),
               mask_opt=cfg.attn_mask_opt)
    return o.reshape(B, S, -1) @ p["wo"].astype(dt)


def mla_prefill(cfg: ModelConfig, p, x, cos, sin, **_):
    y = mla_train(cfg, p, x, cos, sin)
    c_kv, k_pe = _mla_ckv(cfg, p, x, cos, sin)
    return y, {"c_kv": c_kv, "k_pe": k_pe}


def mla_decode(cfg: ModelConfig, p, x, cos, sin, cache, cur_len, **_):
    """Weight-absorbed MLA decode: attends the *compressed* cache directly."""
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    dt = x.dtype
    q_nope, q_pe = _mla_q(cfg, p, x, cos, sin)        # (B,1,H,dn),(B,1,H,dr)
    c_new, pe_new = _mla_ckv(cfg, p, x, cos, sin)     # (B,1,kl),(B,1,dr)
    cap = cache["c_kv"].shape[1]
    slot = (cur_len % cap).astype(jnp.int32)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_pe = jax.lax.dynamic_update_slice(cache["k_pe"], pe_new, (0, slot, 0))
    wkv_b = p["wkv_b"].astype(dt).reshape(kl, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    # absorb W_UK into q: (B,H,kl)
    q_abs = jnp.einsum("bohd,chd->bhc", q_nope, w_uk)
    s = (jnp.einsum("bhc,bkc->bhk", q_abs, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bohd,bkd->bhk", q_pe, k_pe,
                      preferred_element_type=jnp.float32))
    s = s / math.sqrt(dn + dr)
    valid = jnp.minimum(cur_len + 1, cap) * jnp.ones((B,), jnp.int32)
    ok = jnp.arange(cap)[None] < valid[:, None]
    s = jnp.where(ok[:, None], s, _NEG)
    attn = jax.nn.softmax(s, axis=-1).astype(dt)
    ctx = jnp.einsum("bhk,bkc->bhc", attn, c_kv)      # (B,H,kl)
    o = jnp.einsum("bhc,chd->bhd", ctx, w_uv)         # (B,H,dv)
    y = o.reshape(B, 1, H * dv) @ p["wo"].astype(dt)
    return y, {"c_kv": c_kv, "k_pe": k_pe}


# dispatch tables -----------------------------------------------------------

def attn_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return mla_schema(cfg) if cfg.attn_impl == "mla" else gqa_schema(cfg)


def attn_train(cfg, p, x, cos, sin, *, local=False):
    if cfg.attn_impl == "mla":
        return mla_train(cfg, p, x, cos, sin)
    return gqa_train(cfg, p, x, cos, sin, local=local)


def attn_prefill(cfg, p, x, cos, sin, *, local=False):
    if cfg.attn_impl == "mla":
        return mla_prefill(cfg, p, x, cos, sin)
    return gqa_prefill(cfg, p, x, cos, sin, local=local)


def attn_decode(cfg, p, x, cos, sin, cache, cur_len, *, local=False):
    if cfg.attn_impl == "mla":
        return mla_decode(cfg, p, x, cos, sin, cache, cur_len)
    return gqa_decode(cfg, p, x, cos, sin, cache, cur_len, local=local)


def attn_paged_decode(cfg, p, x, cos, sin, cache, seq_lens, block_table, *,
                      local=False, shard=None):
    if cfg.attn_impl == "mla":
        raise NotImplementedError(
            "paged decode covers GQA; MLA serves via the dense absorbed path")
    return gqa_paged_decode(cfg, p, x, cos, sin, cache, seq_lens, block_table,
                            local=local, shard=shard)


def attn_paged_prefill(cfg, p, x, cos, sin, cache, start, chunk_len,
                       block_table, *, local=False, shard=None):
    if cfg.attn_impl == "mla":
        raise NotImplementedError(
            "fused paged prefill covers GQA; MLA serves via the dense path")
    return gqa_paged_prefill(cfg, p, x, cos, sin, cache, start, chunk_len,
                             block_table, local=local, shard=shard)


def kv_cache_spec(cfg: ModelConfig, batch: int, capacity: int,
                  local: bool = False) -> Dict[str, Any]:
    """(shape, dtype, logical axes) for one layer's cache entries."""
    dt = cfg.dtype
    if cfg.attn_impl == "mla":
        return {
            "c_kv": ((batch, capacity, cfg.kv_lora_rank),
                     ("batch", "cache_seq", None), dt),
            "k_pe": ((batch, capacity, cfg.qk_rope_head_dim),
                     ("batch", "cache_seq", None), dt),
        }
    hd = cfg.resolved_head_dim
    cap = min(capacity, cfg.sliding_window) if (local and cfg.sliding_window) \
        else capacity
    mode = kv_quant_mode(cfg)
    kv_dt = {"int8": "int8", "fp8": "float8_e4m3fn", None: dt}[mode]
    out = {
        "k": ((batch, cap, cfg.n_kv_heads, hd),
              ("batch", "cache_seq", "kv_heads", None), kv_dt),
        "v": ((batch, cap, cfg.n_kv_heads, hd),
              ("batch", "cache_seq", "kv_heads", None), kv_dt),
    }
    if cfg.cache_quant:
        out["k_scale"] = ((batch, cap, cfg.n_kv_heads),
                          ("batch", "cache_seq", "kv_heads"), "float32")
        out["v_scale"] = ((batch, cap, cfg.n_kv_heads),
                          ("batch", "cache_seq", "kv_heads"), "float32")
    return out
