import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all surface here.
Per cell it records ``memory_analysis()`` (fits-in-HBM proof),
``cost_analysis()`` (FLOPs/bytes for the roofline) and the collective
schedule parsed from the compiled HLO.

NOTE: the XLA_FLAGS line above must run before any other import — jax locks
the device count on first init. Smoke tests and benches (which want 1
device) must never import this module first.
"""
import argparse
import json
import pathlib
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, cell_is_runnable
from repro.configs.registry import ARCHS, get_arch, get_shape
from repro.core.blueprint import suggest_plan
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.optim.adamw import OptimConfig
from repro.train import steps as steps_mod

# ---------------------------------------------------------------------------
# roofline hardware constants (TPU v5e-class target)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> Dict[str, Any]:
    """Per-device collective byte totals from post-SPMD HLO text."""
    per_op: Dict[str, Dict[str, float]] = {}
    total_operand = 0.0
    total_wire = 0.0
    for line in hlo.splitlines():
        if " = " not in line:
            continue
        _, rhs = line.split(" = ", 1)
        # rhs looks like "f32[16,1024]{1,0} all-reduce(%x), ..." (shapes
        # first, then the op) — instruction *names* on the lhs also contain
        # the op token, so only match in the rhs after the output shape.
        m = _COLL_RE.search(rhs)
        if not m or m.group(2) == "-done":
            continue
        op = m.group(1)
        # output shapes: everything before the op token in the rhs
        head = rhs[:m.start()]
        out_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        if out_bytes == 0:
            continue
        g = 1
        gm = _GROUPS_LIST_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm = _GROUPS_IOTA_RE.search(line)
            if gm:
                g = int(gm.group(2))
        if g <= 1:
            continue
        if op == "all-gather":
            operand = out_bytes / g
            wire = out_bytes * (g - 1) / g
        elif op == "all-reduce":
            operand = out_bytes
            wire = 2 * out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            operand = out_bytes * g
            wire = out_bytes * (g - 1)
        elif op == "all-to-all":
            operand = out_bytes
            wire = out_bytes * (g - 1) / g
        else:  # collective-permute
            operand = out_bytes
            wire = out_bytes
        rec = per_op.setdefault(op, {"count": 0, "operand_bytes": 0.0,
                                     "wire_bytes": 0.0})
        rec["count"] += 1
        rec["operand_bytes"] += operand
        rec["wire_bytes"] += wire
        total_operand += operand
        total_wire += wire
    return {"per_op": per_op, "operand_bytes": total_operand,
            "wire_bytes": total_wire}


def _lin_extrap(c1, c2, n_periods: int):
    """Leafwise linear extrapolation: cost(n) = c1 + (n-1)*(c2-c1)."""
    if isinstance(c1, dict) or isinstance(c2, dict):
        c1 = c1 if isinstance(c1, dict) else {}
        c2 = c2 if isinstance(c2, dict) else {}
        return {k: _lin_extrap(c1.get(k, 0.0), c2.get(k, 0.0), n_periods)
                for k in set(c1) | set(c2)}
    return max(0.0, float(c1) + (n_periods - 1) * (float(c2) - float(c1)))


def _cost_dict(compiled) -> Dict[str, Any]:
    """compiled.cost_analysis() across jax versions: 0.4.x returns a list of
    per-computation dicts, newer jax a single dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _extract_costs(compiled) -> Dict[str, Any]:
    cost = _cost_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "coll_operand": float(colls["operand_bytes"]),
        "coll_wire": float(colls["wire_bytes"]),
        "coll_per_op": {k: dict(v) for k, v in colls["per_op"].items()},
    }


def measure_costs(cfg, shape, mesh, plan) -> Dict[str, Any]:
    """Accurate per-device FLOP/byte/collective accounting.

    XLA cost_analysis counts while-loop bodies once, so the scanned
    full-depth compile undercounts. We compile *unrolled* 1-period and
    2-period depth variants (internal scans also unrolled via the
    ``use_unrolled_scans`` flag) and extrapolate linearly over periods —
    exact for homogeneous periods.
    """
    import dataclasses as dc

    from repro.models.flags import use_unrolled_scans
    from repro.models.transformer import depth_plan

    with use_unrolled_scans():
        if cfg.is_encdec:
            fn, args = build_lowerable(cfg, shape, mesh, plan)
            with mesh:
                c = _extract_costs(fn.lower(*args).compile())
            c["method"] = "direct-unrolled"
            return c
        prefix, period, n_periods = depth_plan(cfg)
        out = []
        for k in (1, 2):
            cfg_k = dc.replace(cfg, n_layers=prefix + k * period)
            fn, args = build_lowerable(cfg_k, shape, mesh, plan)
            with mesh:
                out.append(_extract_costs(fn.lower(*args).compile()))
    c = _lin_extrap(out[0], out[1], n_periods)
    c["method"] = f"extrapolated(p={period},n={n_periods})"
    return c


def model_flops(cfg, shape) -> float:
    """6*N_active*D (train) / 2*N_active*D (prefill) / 2*N_active*B (decode),
    global per step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def build_lowerable(cfg, shape, mesh, plan):
    """-> (jitted_fn, kwargs_of_SDS) ready for .lower()."""
    specs = input_specs(cfg, shape, mesh, plan)
    shardings = jax.tree.map(lambda s: s.sharding, specs,
                             is_leaf=lambda x: isinstance(x,
                                                          jax.ShapeDtypeStruct))
    if shape.kind == "train":
        step = steps_mod.make_train_step(cfg, OptimConfig(), remat=plan.remat,
                                         mesh=mesh, act_rules=plan.act_rules)
        fn = jax.jit(step,
                     in_shardings=(shardings["state"], shardings["batch"]),
                     donate_argnums=(0,))
        args = (specs["state"], specs["batch"])
    elif shape.kind == "prefill":
        step = steps_mod.make_prefill_step(cfg, mesh=mesh,
                                           act_rules=plan.act_rules)
        fn = jax.jit(step,
                     in_shardings=(shardings["params"], shardings["batch"]))
        args = (specs["params"], specs["batch"])
    else:
        step = steps_mod.make_serve_step(cfg, mesh=mesh,
                                         act_rules=plan.act_rules)
        fn = jax.jit(step,
                     in_shardings=(shardings["params"], shardings["cache"],
                                   shardings["tokens"], shardings["cur_len"]),
                     donate_argnums=(1,))
        args = (specs["params"], specs["cache"], specs["tokens"],
                specs["cur_len"])
    return fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[dict] = None, save_hlo: Optional[str] = None,
             cfg_overrides: Optional[dict] = None) -> Dict[str, Any]:
    import dataclasses as _dc
    cfg = get_arch(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name}
    if not cell_is_runnable(arch, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch: 500k decode requires "
                         "sub-quadratic attention (DESIGN.md)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    plan = suggest_plan(cfg, shape, mesh, overrides=overrides)
    rec["plan"] = {"remat": plan.remat, "notes": list(plan.notes),
                   "param_rules": {k: list(v) for k, v in
                                   plan.param_rules.items()},
                   "act_rules": {k: list(v) for k, v in
                                 plan.act_rules.items()},
                   "est": plan.est}
    t0 = time.time()
    fn, args = build_lowerable(cfg, shape, mesh, plan)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls_scanned = parse_collectives(hlo)
    if save_hlo:
        pathlib.Path(save_hlo).write_text(hlo)

    t1 = time.time()
    try:
        meas = measure_costs(cfg, shape, mesh, plan)
    except Exception as e:  # noqa: BLE001 - fall back to scanned numbers
        cost = _cost_dict(compiled)
        meas = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            "coll_operand": float(colls_scanned["operand_bytes"]),
            "coll_wire": float(colls_scanned["wire_bytes"]),
            "coll_per_op": colls_scanned["per_op"],
            "method": f"scanned-fallback ({type(e).__name__}: {e})",
        }
    t_measure = time.time() - t1

    flops_dev = meas["flops"]
    bytes_dev = meas["bytes"]
    coll_dev = meas["coll_operand"]
    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / LINK_BW,
        "collective_wire_s": float(meas["coll_wire"]) / LINK_BW,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    rec.update({
        "status": "ok",
        "n_devices": n_dev,
        "timings_s": {"lower": round(t_lower, 2),
                      "compile": round(t_compile, 2),
                      "measure": round(t_measure, 2)},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            # jax < 0.5 has no peak_memory_in_bytes; resident args +
            # outputs + temps (minus donated aliases) is the same bound
            "peak_bytes": getattr(
                mem, "peak_memory_in_bytes",
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "transcendentals": meas["transcendentals"],
                 "method": meas["method"]},
        "collectives": {"per_op": meas["coll_per_op"],
                        "operand_bytes": coll_dev,
                        "wire_bytes": meas["coll_wire"]},
        "collectives_scanned_hlo": {
            "per_op": colls_scanned["per_op"],
            "operand_bytes": colls_scanned["operand_bytes"],
            "wire_bytes": colls_scanned["wire_bytes"]},
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flop_ratio": (mf / n_dev) / flops_dev if flops_dev else 0.0,
        "roofline": terms,
        "dominant": dominant,
        "bound_s": max(terms["compute_s"], terms["memory_s"],
                       terms["collective_s"]),
        "roofline_fraction": (terms["compute_s"]
                              / max(terms["compute_s"], terms["memory_s"],
                                    terms["collective_s"])
                              * ((mf / n_dev) / flops_dev)
                              if flops_dev else 0.0),
    })
    return rec


def autotune(arch: str, shape_name: str, multi_pod: bool,
             candidates: Dict[str, Dict[str, Any]],
             out_path: Optional[str] = None) -> Dict[str, Any]:
    """Blueprint configuration search (paper §2.2 'advanced CPS
    requirements': configuration optimization w.r.t. cost/performance).

    Each candidate = {"plan": <plan overrides>, "cfg": <ModelConfig
    overrides>}; every candidate is lowered + compiled and scored by its
    dominant roofline term. Returns {name: record} with the winner marked.
    """
    results: Dict[str, Any] = {}
    for name, cand in candidates.items():
        print(f"[autotune] {arch} x {shape_name} :: {name}", flush=True)
        try:
            rec = run_cell(arch, shape_name, multi_pod,
                           overrides=cand.get("plan"),
                           cfg_overrides=cand.get("cfg"))
        except Exception as e:  # noqa: BLE001
            rec = {"status": "error", "error": f"{type(e).__name__}: {e}"}
        rec["candidate"] = name
        results[name] = rec
        if rec.get("status") == "ok":
            r = rec["roofline"]
            print(f"  bound={rec['bound_s']:.3f}s dom={rec['dominant']} "
                  f"(comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
                  f"coll={r['collective_s']:.3f})", flush=True)
    ok = {k: v for k, v in results.items() if v.get("status") == "ok"}
    if ok:
        winner = min(ok, key=lambda k: ok[k]["bound_s"])
        results["_winner"] = winner
    if out_path:
        pathlib.Path(out_path).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(out_path).write_text(json.dumps(results, indent=1))
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", default=None,
                    help="directory to dump compiled HLO text")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = outdir / f"{arch}__{shape}__{mesh_name}.json"
                if path.exists() and not args.force:
                    print(f"[skip-cached] {path.name}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                hlo_path = None
                if args.save_hlo:
                    pathlib.Path(args.save_hlo).mkdir(parents=True,
                                                      exist_ok=True)
                    hlo_path = str(pathlib.Path(args.save_hlo) /
                                   f"{arch}__{shape}__{mesh_name}.hlo")
                try:
                    rec = run_cell(arch, shape, mp, save_hlo=hlo_path)
                except Exception as e:  # noqa: BLE001 - report, keep sweeping
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures.append(path.name)
                path.write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={rec['dominant'][:-2]}"
                             f" comp={r['compute_s']:.3f}s"
                             f" mem={r['memory_s']:.3f}s"
                             f" coll={r['collective_s']:.3f}s"
                             f" peakGiB={rec['memory']['peak_bytes']/2**30:.2f}")
                print(f"  -> {status}{extra}", flush=True)
    if failures:
        print(f"FAILURES: {failures}")
        raise SystemExit(1)
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()
