"""Chunked prefill + prefill/decode disaggregation: byte-identity sweep.

The determinism contract (docs/serving.md): splitting a prompt into
page-sized chunks interleaved with decode ticks, and handing the prefilled
KV pages from a prefill-role replica to a decode-role replica, are pure
*scheduling* changes — at fp32 every serving configuration must emit
exactly the tokens monolithic colocated serving emits. The sweep covers
the three cache families (dense attention / hybrid attention+SSM / pure
MoE), chunking composed with the COW prefix cache, and the failure path:
a replica preempted mid-chunk restarts its streams elsewhere with
identical tokens.

MoE archs run with non-binding expert capacity (capacity_factor =
E / top_k): capacity couples tokens through their grouping, which any
re-chunking legitimately changes — the same caveat as the prefix cache
and the fabric's re-prefill (see tests/test_prefix_cache.py).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler

ARCH_SWEEP = ("qwen3-32b", "jamba-v0.1-52b", "qwen2-moe-a2.7b")


def _fp32(arch):
    cfg = dataclasses.replace(REDUCED[arch], dtype="float32")
    if cfg.n_routed_experts:
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=float(cfg.n_routed_experts)
            / cfg.moe_top_k)
    return cfg


_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        cfg = _fp32(arch)
        _PARAMS[arch] = (cfg, M.init(cfg, jax.random.PRNGKey(0)))
    return _PARAMS[arch]


def _trace(cfg, seed, n=4, p_lo=3, p_hi=26, g_hi=6):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        plen = int(rng.randint(p_lo, p_hi + 1))
        gen = int(rng.randint(2, g_hi + 1))
        out.append((rng.randint(0, cfg.vocab_size, size=plen
                                ).astype(np.int32), gen))
    return out


def _serve_sched(cfg, params, trace, *, budget=None, prefix_cache=False,
                 slots=3, page_size=8, max_seq=64, arrivals=None):
    s = ContinuousBatchingScheduler(
        cfg, params, max_slots=slots, page_size=page_size,
        max_seq_len=max_seq, prefix_cache=prefix_cache,
        prefill_budget=budget)
    reqs = [s.submit(p, g, arrival_step=arrivals[i] if arrivals else i // 2)
            for i, (p, g) in enumerate(trace)]
    s.run()
    return s, [list(r.out_tokens) for r in reqs]


def _serve_fleet(cfg, params, trace, *, budget=None, disagg=0, replicas=2,
                 slots=3, page_size=8, max_seq=64):
    r = ServingRouter(cfg, params, replicas=replicas, max_slots=slots,
                      page_size=page_size, max_seq_len=max_seq,
                      prefix_cache=False, prefill_budget=budget,
                      disagg=disagg)
    reqs = [r.submit(p, g, arrival_step=i // 2)
            for i, (p, g) in enumerate(trace)]
    r.run()
    return r, [list(q.out_tokens) for q in reqs]


# ------------------------------------------------ chunked == monolithic --

@pytest.mark.parametrize("arch", ARCH_SWEEP)
def test_chunked_prefill_token_identity(arch):
    """Acceptance core: any chunk budget emits monolithic's exact tokens.

    Budget 4 forces many mid-prompt chunks (first chunk via the prefill
    kernel, later ones via the suffix paths); a budget larger than every
    prompt degenerates to whole-prompt chunks and must *also* match."""
    cfg, params = _params(arch)
    trace = _trace(cfg, seed=0)
    _, base = _serve_sched(cfg, params, trace)
    budgets = (4, 64) if arch == "qwen3-32b" else (4,)
    for budget in budgets:
        s, toks = _serve_sched(cfg, params, trace, budget=budget)
        assert toks == base, f"budget {budget} changed tokens"
        assert s.stats["prefill_chunk_tokens"] == sum(
            len(p) for p, _ in trace)
        assert s.reserved_pages == 0 and s.alloc.num_allocated == 0


def test_chunked_composes_with_prefix_cache():
    """A chunked admission that hits the COW prefix cache starts its chunk
    cursor at the hit length — tokens identical, cached pages shared."""
    cfg, params = _params("qwen3-32b")
    rng = np.random.RandomState(7)
    persona = rng.randint(0, cfg.vocab_size, size=18).astype(np.int32)
    trace = [(np.concatenate([persona, rng.randint(0, cfg.vocab_size,
                                                   size=3 + u)]).astype(
                  np.int32), 5) for u in range(3)]
    # followers arrive after the leader's last chunk lands (a chunked
    # admission indexes its pages only once the whole prompt is in)
    arrivals = [0, 8, 10]
    _, base = _serve_sched(cfg, params, trace, arrivals=arrivals)
    s, toks = _serve_sched(cfg, params, trace, budget=4, prefix_cache=True,
                           arrivals=arrivals)
    assert toks == base
    assert s.stats["prefix_hits"] >= 2
    # followers skipped the persona: fewer chunk tokens than total prompt
    assert s.stats["prefill_chunk_tokens"] < sum(len(p) for p, _ in trace)


# ------------------------------------------- disaggregated == colocated --

@pytest.mark.parametrize("arch", ARCH_SWEEP)
def test_disagg_token_identity(arch):
    """KV-page handoff is verbatim for every cache family: dense paged KV,
    hybrid KV + SSM slot state, MoE layers — the adopting decode replica
    continues each stream byte-identically to colocated serving."""
    cfg, params = _params(arch)
    trace = _trace(cfg, seed=1)
    _, base = _serve_fleet(cfg, params, trace)
    r, toks = _serve_fleet(cfg, params, trace, disagg=1)
    assert toks == base
    assert r.stats["migrations"] == len(trace)   # every stream handed off
    for rep in r.replicas.values():
        assert rep.sched.alloc.num_allocated == 0
        assert rep.sched.reserved_pages == 0


def test_disagg_composes_with_chunked():
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=2, n=5)
    _, base = _serve_fleet(cfg, params, trace)
    r, toks = _serve_fleet(cfg, params, trace, budget=4, disagg=1)
    assert toks == base
    assert r.stats["migrations"] == len(trace)
    fleet = r.fleet_stats()
    assert fleet["prefill_chunk_tokens"] == sum(len(p) for p, _ in trace)


# --------------------------------------------------- mid-prefill failure --

def test_mid_prefill_preemption_token_identity():
    """A replica preempted while a prompt is mid-chunk: the stream restarts
    (prefill from scratch) on a surviving replica with identical tokens —
    chunk cursors hold no state the fleet cannot rebuild."""
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=3, n=4, p_lo=12, p_hi=24)
    _, base = _serve_fleet(cfg, params, trace)

    r = ServingRouter(cfg, params, replicas=2, max_slots=3, page_size=8,
                      max_seq_len=64, prefix_cache=False, prefill_budget=4)
    reqs = [r.submit(p, g, arrival_step=i // 2)
            for i, (p, g) in enumerate(trace)]
    victim = None
    for _ in range(3):                       # land a few 4-token chunks
        r.step()
    for rid, rep in r.replicas.items():
        if any(q is not None and q.prefill_pos is not None
               for q in rep.sched.slot_req):
            victim = rid
            break
    assert victim is not None, "no replica caught mid-prefill"
    r.fail_replica(victim)
    r.run()
    assert [list(q.out_tokens) for q in reqs] == base
    assert r.stats["reroutes"] >= 1


def test_disagg_prefill_replica_preemption():
    """Disaggregated fleet: a *prefill-role* replica dies mid-chunk; the
    surviving prefill replica re-runs its streams and the decode side still
    sees byte-identical handoffs."""
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=4, n=4, p_lo=12, p_hi=24)
    _, base = _serve_fleet(cfg, params, trace)

    r = ServingRouter(cfg, params, replicas=3, max_slots=3, page_size=8,
                      max_seq_len=64, prefix_cache=False, prefill_budget=4,
                      disagg=2)
    reqs = [r.submit(p, g, arrival_step=i // 2)
            for i, (p, g) in enumerate(trace)]
    for _ in range(3):
        r.step()
    victim = next(rid for rid, rep in r.replicas.items()
                  if rep.role == "prefill"
                  and any(q is not None for q in rep.sched.slot_req))
    r.fail_replica(victim)
    r.run()
    assert [list(q.out_tokens) for q in reqs] == base
    assert r.stats["migrations"] >= len(trace)
