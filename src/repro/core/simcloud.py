"""SimCloud — a simulated IaaS with the EC2 surface InstaCluster uses.

The paper provisions on Amazon EC2; this container has no cloud, so the
control plane runs against a faithful simulation: instances with states
(pending/running/stopped/terminated), *private IPs that change across
stop/start* (the paper's central re-discovery problem), tags, user-data,
spot instances with preemption, and a simulated clock with per-operation
latencies so bring-up *time* (the paper's headline metric) is measurable.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import random
from typing import Any, Callable, Dict, List, Optional

# simulated operation latencies (seconds) — calibrated to the paper's
# narrative (total 4-VM bring-up ~25 min incl. service install)
LATENCY = {
    "boot_instance": 55.0,          # EC2 boot + cloud-init
    "describe": 2.0,
    "stop_instance": 20.0,
    "start_instance": 45.0,
    "tag": 1.0,
    "ssh_roundtrip": 1.5,           # key/hosts distribution per node
    "pkg_install_agent": 95.0,      # ambari-agent download+install
    "pkg_install_server": 160.0,    # ambari-server install+start
    "service_install": 120.0,       # per service, parallel across nodes
    "service_start": 30.0,
}


class InstanceState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    STOPPED = "stopped"
    TERMINATED = "terminated"


@dataclasses.dataclass
class Instance:
    instance_id: str
    instance_type: str
    region: str
    image_id: str
    private_ip: str
    user_data: Dict[str, Any]
    state: InstanceState = InstanceState.PENDING
    tags: Dict[str, str] = dataclasses.field(default_factory=dict)
    spot: bool = False
    launched_at: float = 0.0
    # host-level resources (TPU-host flavour): chips per host
    chips: int = 0


class AccessKeyError(RuntimeError):
    pass


class SimCloud:
    """Deterministic EC2-like API over a simulated clock."""

    INSTANCE_TYPES = {
        # type -> (chips per host, hourly $)
        "c4.xlarge": (0, 0.199),
        "tpu-host-v5e-8": (8, 9.60),
        "tpu-host-v5e-4": (4, 4.80),
    }

    def __init__(self, seed: int = 0):
        self.clock = 0.0
        self._rng = random.Random(seed)
        self._ids = itertools.count(1)
        self.instances: Dict[str, Instance] = {}
        self.active_keys: Dict[str, str] = {}   # access_key_id -> secret
        self.api_log: List[str] = []
        self._preempt_hooks: List[Callable[[Instance], None]] = []

    # ----------------------------------------------------------- helpers --
    def _advance(self, seconds: float) -> None:
        self.clock += seconds

    def _new_ip(self) -> str:
        return ("10.%d.%d.%d" % (self._rng.randrange(256),
                                 self._rng.randrange(256),
                                 self._rng.randrange(2, 255)))

    def _check_key(self, access_key_id: str) -> None:
        if access_key_id not in self.active_keys:
            raise AccessKeyError(f"inactive or unknown AWS key {access_key_id}")

    # --------------------------------------------------------------- auth --
    def register_key(self, access_key_id: str, secret: str) -> None:
        self.active_keys[access_key_id] = secret

    def deactivate_key(self, access_key_id: str) -> None:
        """Paper §3: optional auto-deactivation after slave discovery."""
        self.active_keys.pop(access_key_id, None)
        self.api_log.append(f"deactivate_key {access_key_id}")

    # ---------------------------------------------------------------- api --
    def run_instances(self, *, count: int, instance_type: str, region: str,
                      image_id: str, user_data: Dict[str, Any],
                      access_key_id: str, spot: bool = False) -> List[Instance]:
        self._check_key(access_key_id)
        chips = self.INSTANCE_TYPES.get(instance_type, (0, 0.0))[0]
        out = []
        for _ in range(count):
            iid = f"i-{next(self._ids):08x}"
            inst = Instance(instance_id=iid, instance_type=instance_type,
                            region=region, image_id=image_id,
                            private_ip=self._new_ip(), user_data=dict(user_data),
                            spot=spot, launched_at=self.clock, chips=chips)
            self.instances[iid] = inst
            out.append(inst)
        # instances boot in parallel: one boot latency for the batch
        self._advance(LATENCY["boot_instance"])
        for inst in out:
            inst.state = InstanceState.RUNNING
        self.api_log.append(f"run_instances x{count} {instance_type} {region}")
        return out

    def describe_instances(self, *, region: str, access_key_id: str,
                           filters: Optional[Dict[str, str]] = None
                           ) -> List[Instance]:
        self._check_key(access_key_id)
        self._advance(LATENCY["describe"])
        out = []
        for inst in self.instances.values():
            if inst.region != region or inst.state == InstanceState.TERMINATED:
                continue
            if filters and any(inst.tags.get(k) != v
                               for k, v in filters.items()):
                continue
            out.append(inst)
        return sorted(out, key=lambda i: i.instance_id)

    def create_tags(self, ids: List[str], tags: Dict[str, str],
                    access_key_id: str) -> None:
        self._check_key(access_key_id)
        self._advance(LATENCY["tag"])
        for iid in ids:
            self.instances[iid].tags.update(tags)
        self.api_log.append(f"create_tags {ids} {tags}")

    def stop_instances(self, ids: List[str], access_key_id: str) -> None:
        self._check_key(access_key_id)
        self._advance(LATENCY["stop_instance"])
        for iid in ids:
            self.instances[iid].state = InstanceState.STOPPED
        self.api_log.append(f"stop_instances {ids}")

    def start_instances(self, ids: List[str], access_key_id: str) -> None:
        """Restart: private IPs change — the paper's re-discovery trigger."""
        self._check_key(access_key_id)
        self._advance(LATENCY["start_instance"])
        for iid in ids:
            inst = self.instances[iid]
            if inst.state != InstanceState.STOPPED:
                continue
            inst.private_ip = self._new_ip()
            inst.state = InstanceState.RUNNING
        self.api_log.append(f"start_instances {ids}")

    def terminate_instances(self, ids: List[str], access_key_id: str) -> None:
        self._check_key(access_key_id)
        for iid in ids:
            self.instances[iid].state = InstanceState.TERMINATED
        self.api_log.append(f"terminate_instances {ids}")

    # --------------------------------------------------- failure injection --
    def on_preempt(self, fn: Callable[[Instance], None]) -> None:
        self._preempt_hooks.append(fn)

    def preempt_spot(self, instance_id: str) -> None:
        """Spot preemption (the paper's cost-saving mode has this risk)."""
        inst = self.instances[instance_id]
        assert inst.spot, "only spot instances are preemptible"
        inst.state = InstanceState.TERMINATED
        self.api_log.append(f"preempt {instance_id}")
        for fn in self._preempt_hooks:
            fn(inst)

    def fail_instance(self, instance_id: str) -> None:
        inst = self.instances[instance_id]
        inst.state = InstanceState.TERMINATED
        self.api_log.append(f"hw_failure {instance_id}")
        for fn in self._preempt_hooks:
            fn(inst)

    # ------------------------------------------------------------- billing --
    def hourly_cost(self, ids: List[str]) -> float:
        total = 0.0
        for iid in ids:
            inst = self.instances[iid]
            if inst.state == InstanceState.RUNNING:
                rate = self.INSTANCE_TYPES.get(inst.instance_type, (0, 0.0))[1]
                total += rate * (0.3 if inst.spot else 1.0)
        return total
