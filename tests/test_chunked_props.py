"""Chunked-prefill scheduler invariants (hypothesis stateful).

``ChunkedSchedulerMachine`` drives the REAL ``ContinuousBatchingScheduler``
control plane — admission ledger, page allocator, chunk fifo, decode
masking — through random submit/tick interleavings, with the jit'd compute
paths stubbed out (prefill/suffix/decode return token 0 and pass the cache
through untouched). Tokens are irrelevant here; what the machine pins down
is the *bookkeeping* the byte-identity sweep in tests/test_chunked_prefill.py
builds on:

* a tick never lands more than ``prefill_budget`` prompt tokens, no matter
  how many prefills are in flight (the SLO knob is a hard cap);
* the chunk fifo is FCFS and the head always advances — an admitted
  prefill can never starve behind later arrivals;
* the admission ledger stays exact at every step: ``pages_in_use`` equals
  the allocator's refcount ledger, ``reserved_pages`` equals the per-slot
  reservations, and reservations never undershoot pages actually held;
* every PREFILLING slot is on the fifo and vice versa, and PREFILLING
  slots sit out of decode (their seq_lens stay 0 — masked like empty
  slots);
* draining the machine returns every page and every reservation to zero.

The stub subclass overrides only the compiled-function *getters* — every
line of host-side scheduling logic under test is the production code.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

from repro.configs.registry import get_reduced
from repro.serving.scheduler import ContinuousBatchingScheduler

PAGE = 4
SLOTS = 3
POOL = 40
MAX_SEQ = 64
BUDGET = 3


class _StubSched(ContinuousBatchingScheduler):
    """Production scheduler with the jit compute stubbed to no-ops."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._decode_fn = lambda params, cache, toks, lens, bt, k: (
            np.zeros((k, toks.shape[0]), np.int32), cache)
        self._cow_fn = lambda cache, src, dst: cache

    def _prefill_fn(self, n):
        return lambda params, tokens, plen: (np.int32(0), None)

    def _insert_fn(self, n):
        return lambda cache, pre, row, slot, plen: cache

    def _suffix_fn(self, n):
        return lambda params, cache, toks, start, c, row: (np.int32(0),
                                                           cache)

    def _chunk_fn(self, n):
        return lambda params, cache, toks, start, c, row: (np.int32(0),
                                                           cache)

    def _seq_suffix_fn(self, c):
        return (lambda params, cache, state, toks, start, row, slot:
                (np.int32(0), cache))


class ChunkedSchedulerMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.sched = _StubSched(
            get_reduced("qwen3-32b"), None, max_slots=SLOTS,
            page_size=PAGE, num_pages=POOL, max_seq_len=MAX_SEQ,
            prefix_cache=False, prefill_budget=BUDGET)

    # ------------------------------------------------------------- rules --
    @rule(plen=st.integers(min_value=1, max_value=20),
          gen=st.integers(min_value=1, max_value=6))
    def submit(self, plen, gen):
        prompt = (np.arange(plen, dtype=np.int32) % 97)
        self.sched.submit(prompt, gen, arrival_step=0)

    @precondition(lambda self: self.sched.waiting or self.sched.num_active)
    @rule(max_fuse=st.sampled_from([1, 4, 16]))
    def tick(self, max_fuse):
        s = self.sched
        head = s._prefill_fifo[0] if s._prefill_fifo else None
        head_req = s.slot_req[head] if head is not None else None
        head_pos = head_req.prefill_pos if head_req is not None else None
        before = s.stats["prefill_chunk_tokens"]
        s.step(max_fuse=max_fuse)
        landed = s.stats["prefill_chunk_tokens"] - before
        assert landed <= BUDGET, \
            f"tick landed {landed} chunk tokens > budget {BUDGET}"
        if head is not None:
            # FCFS head must have advanced: cursor moved, or it left
            # PREFILLING entirely (last chunk landed / finished)
            if head_req.prefill_pos is not None:
                assert head_req.prefill_pos > head_pos, \
                    "fifo head starved (cursor did not advance)"

    # -------------------------------------------------------- invariants --
    @invariant()
    def ledger_exact(self):
        s = self.sched
        assert s.pages_in_use == s.alloc.num_allocated, \
            "slot pages and allocator refcounts disagree"
        assert s.reserved_pages == sum(s.slot_reserve), \
            "reservation ledger drifted from per-slot reservations"
        assert s.reserved_pages >= s.pages_in_use, \
            "reservation undershoots pages actually held"
        assert s.alloc.num_free + s.alloc.num_allocated == POOL - 1

    @invariant()
    def fifo_matches_prefilling_slots(self):
        s = self.sched
        prefilling = [i for i, r in enumerate(s.slot_req)
                      if r is not None and r.prefill_pos is not None]
        assert sorted(s._prefill_fifo) == prefilling
        assert len(set(s._prefill_fifo)) == len(s._prefill_fifo)
        for slot in prefilling:
            # masked out of decode until the last chunk lands
            assert s.seq_lens[slot] == 0
            assert 0 <= s.slot_req[slot].prefill_pos \
                < s.slot_req[slot].plen

    def teardown(self):
        s = self.sched
        for _ in range(500):
            if not (s.waiting or s.num_active):
                break
            s.step(max_fuse=4)
        assert not s.waiting and not s.num_active, "machine failed to drain"
        assert s.alloc.num_allocated == 0, "drained scheduler leaked pages"
        assert s.reserved_pages == 0, "drained scheduler leaked reservations"
        super().teardown()


TestChunkedSchedulerProps = ChunkedSchedulerMachine.TestCase
TestChunkedSchedulerProps.settings = settings(max_examples=40,
                                              stateful_step_count=40,
                                              deadline=None)
