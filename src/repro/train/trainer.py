"""Fault-tolerant training loop: the "train service" the cluster provisions.

Integrates the InstaCluster control plane with the JAX substrate:
  * heartbeats per step feed the Ambari-analogue monitor (dead/straggler
    detection);
  * periodic async checkpoints (atomic commits);
  * on failure (injected preemption / thrown SimFailure) the loop restores
    the latest committed step and replays — with the deterministic data
    pipeline this reproduces the uninterrupted run exactly;
  * elastic resume: restoring onto a different mesh reshards the state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import OptimConfig
from repro.train.steps import init_train_state, make_train_step


class SimFailure(RuntimeError):
    """Injected node failure / spot preemption during a step."""


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_step: int
    losses: List[float]
    restores: int
    wall_seconds: float


class Trainer:
    def __init__(self, cfg: ModelConfig, ocfg: OptimConfig, *,
                 batch: int, seq: int,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50,
                 mesh=None, act_rules=None, remat: str = "none",
                 data_cfg: DataConfig = DataConfig(),
                 heartbeat_cb: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.ocfg = ocfg
        self.batch = batch
        self.seq = seq
        self.mesh = mesh
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.data = SyntheticLM(cfg, batch, seq, data_cfg)
        self.heartbeat_cb = heartbeat_cb
        step_fn = make_train_step(cfg, ocfg, remat=remat, mesh=mesh,
                                  act_rules=act_rules)
        self.train_step = jax.jit(step_fn, donate_argnums=(0,))

    # ------------------------------------------------------------ plumbing --
    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        return init_train_state(self.cfg, jax.random.PRNGKey(seed))

    def _batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        b = self.data.extras(self.data.global_batch(step))
        return {k: jnp.asarray(v) for k, v in b.items()}

    def restore_or_init(self, seed: int = 0) -> Dict[str, Any]:
        if self.ckpt and self.ckpt.latest_step() is not None:
            template = self.init_state(seed)
            return self.ckpt.restore(target=template)
        return self.init_state(seed)

    # ---------------------------------------------------------------- run --
    def run(self, n_steps: int, *, state: Optional[Dict[str, Any]] = None,
            seed: int = 0,
            failure_at: Optional[Dict[int, Exception]] = None,
            max_restores: int = 8) -> TrainReport:
        """Run to global step ``n_steps`` with restore-on-failure.

        ``failure_at`` maps global step -> exception to inject *once* (after
        the forward/step completes, modelling a node loss mid-run).
        """
        t0 = time.time()
        state = state if state is not None else self.restore_or_init(seed)
        losses: List[float] = []
        restores = 0
        injected = set()
        failure_at = dict(failure_at or {})

        while int(state["step"]) < n_steps:
            step = int(state["step"])
            try:
                batch = self._batch_at(step)
                t_step = time.time()
                state, metrics = self.train_step(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                losses.append(loss)
                if self.heartbeat_cb:
                    self.heartbeat_cb(step, time.time() - t_step)
                if failure_at and step in failure_at and step not in injected:
                    injected.add(step)
                    raise failure_at[step]
                new_step = step + 1
                if self.ckpt and (new_step % self.ckpt_every == 0
                                  or new_step == n_steps):
                    self.ckpt.save(state, new_step)
            except SimFailure:
                restores += 1
                if restores > max_restores or self.ckpt is None:
                    raise
                self.ckpt.wait()
                state = self.ckpt.restore(target=self.init_state(seed)) \
                    if self.ckpt.latest_step() is not None \
                    else self.init_state(seed)
        if self.ckpt:
            self.ckpt.wait()
        return TrainReport(steps_run=len(losses), final_step=int(state["step"]),
                           losses=losses, restores=restores,
                           wall_seconds=time.time() - t0)
