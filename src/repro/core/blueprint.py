"""Blueprint planner — the Ambari-"suggested configuration" analogue.

Given (architecture, input shape, mesh) the planner *suggests* a deployment
plan: parameter/activation sharding rules, remat policy, and memory
estimates that justify the choices. Exactly like Ambari, the suggestion is
a starting point the user can override (`overrides=`), and the provisioning
layer validates it by lowering (the dry-run) before any "service" starts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.schema import DEFAULT_RULES
from repro.parallel.context import ACT_RULES

GiB = 1024 ** 3
HBM_PER_CHIP = 16 * GiB          # v5e-class
HBM_BUDGET = 0.85 * HBM_PER_CHIP


@dataclasses.dataclass(frozen=True)
class Plan:
    param_rules: Dict[str, Tuple[str, ...]]
    act_rules: Dict[str, Tuple[str, ...]]
    remat: str                       # none | dots | full
    loss_chunk: int
    est: Dict[str, float]            # memory estimates (bytes/chip)
    notes: Tuple[str, ...]
    serve_param_dtype: str = "float32"   # §Perf: bf16 params for serving


def _mesh_sizes(mesh) -> Dict[str, int]:
    """Accepts a Mesh, an AbstractMesh, or a plain {axis: size} dict — the
    planner reasons about topology shape only, never devices."""
    if isinstance(mesh, dict):
        return dict(mesh)
    if hasattr(mesh, "devices"):
        return dict(zip(mesh.axis_names, mesh.devices.shape))
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def suggest_plan(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 overrides: Optional[Dict[str, Any]] = None,
                 optimize: bool = False) -> Plan:
    """Suggest a deployment plan (Ambari's "suggested configuration").

    ``optimize=False`` (default) gives the paper-faithful v1 suggestions —
    the baseline every dry-run cell was measured with. ``optimize=True``
    additionally applies the configuration-optimization rules learned from
    the §Perf hillclimb (paper §2.2 "advanced CPS requirements"):

      * small-dense-model training on a wide mesh -> DP-heavy layout (TP off,
        model axis joins the batch): gemma2-2b train_4k bound 7.57s -> 1.51s;
      * serving -> no-FSDP 2-axis tensor parallelism + bf16 params + int8
        KV cache: qwen1.5-110b decode_32k bound 250ms -> 77ms;
      * MoE -> scatter combine; MLA -> head-sharded up-projections; large
        models -> dots remat: deepseek-v2 train_4k bound 105.6s -> (§Perf).
    """
    sizes = _mesh_sizes(mesh)
    model_par = sizes.get("model", 1)
    data_par = sizes.get("data", 1)
    pod_par = sizes.get("pod", 1)
    n_dev = model_par * data_par * pod_par
    notes = []

    param_rules = {k: tuple(v) for k, v in DEFAULT_RULES.items()}
    act_rules = {k: tuple(v) for k, v in ACT_RULES.items()}

    # ---- parameter/optimizer memory: decide FSDP span ---------------------
    n_params = cfg.param_count()
    state_bytes = n_params * 4 * 3            # fp32 params + adam m + v
    per_chip = state_bytes / (model_par * data_par)
    if shape.kind == "train" and per_chip > 0.55 * HBM_BUDGET and pod_par > 1:
        param_rules["embed"] = ("data", "pod")   # span FSDP across pods
        per_chip /= pod_par
        notes.append("FSDP spans pod axis (state would not fit in-pod)")
    est = {"opt_state_bytes": per_chip}

    # ---- activation memory -> remat policy --------------------------------
    if shape.kind == "train":
        dp = data_par * pod_par
        b_local = max(shape.global_batch // dp, 1)
        act_per_layer = b_local * shape.seq_len * cfg.d_model * 2  # bf16 resid
        total_layers = cfg.n_layers + cfg.n_enc_layers
        full_acts = act_per_layer * total_layers / model_par if model_par else 0
        # checkpointed residuals only under "full" remat
        if cfg.name.endswith("reduced") or n_params < 4e9:
            remat = "none"
        elif full_acts * 12 > 0.35 * HBM_BUDGET:
            remat = "full"
            notes.append("full remat: unsaved activations would exceed HBM")
        else:
            remat = "dots"
        est["ckpt_act_bytes"] = full_acts
    else:
        remat = "none"

    # ---- serving cache placement ------------------------------------------
    if shape.kind == "decode":
        if shape.global_batch < data_par:
            # long-context single stream: shard cache sequence on data axes
            act_rules["cache_seq"] = ("data", "pod")
            notes.append("cache sequence sharded on data axes (SP decode)")
        else:
            act_rules["cache_seq"] = ("model",)
        est["cache_bytes"] = _cache_bytes(cfg, shape) / (
            model_par * data_par * pod_par)
        try:
            pp = serving_page_plan(cfg, shape, sizes)
        except ValueError as e:
            # suggest_plan is advisory: surface the unviable pool as a note
            # (provision_serving, the enforcing caller, still raises)
            pp = None
            notes.append(f"paged-KV pool not viable: {e}")
        if pp is not None:
            est["page_size"] = pp["page_size"]
            est["num_pages"] = pp["num_pages"]
            est["pages_per_seq"] = pp["pages_per_seq"]
            est["pool_bytes_per_chip"] = pp["pool_bytes"] / n_dev
            notes.append(
                f"paged-KV pool: {pp['num_pages']} pages x "
                f"{pp['page_size']} tok (fits {pp['max_concurrent_seqs']} "
                f"full-length seqs vs {shape.global_batch} capacity-padded)")

    serve_dtype = "float32"
    if optimize:
        if shape.kind == "train":
            # DP-heavy: profitable when the whole optimizer state fits under
            # data-axis FSDP alone and the batch covers every device.
            fits_dp = (n_params * 12 / (data_par * pod_par)) < 0.25 * HBM_BUDGET
            if cfg.n_routed_experts == 0 and fits_dp \
                    and shape.global_batch % n_dev == 0:
                for k in ("ff", "heads", "kv_heads", "lora", "ssm_inner",
                          "ssm_heads"):
                    param_rules[k] = ()
                for k in ("heads_act", "ff_act", "experts_act"):
                    act_rules[k] = ()
                act_rules["batch"] = ("pod", "data", "model")
                notes.append("optimize: DP-heavy layout (TP off, model axis "
                             "joined batch) — per-layer TP all-reduces removed")
            if remat == "full":
                # measured headroom: every full-remat cell peaks <= 13.2 GiB
                # of 16 GiB; saving dot outputs removes recompute re-gathers
                remat = "dots"
                notes.append("optimize: dots remat (recompute re-gathers cost "
                             "more than saved activations at this scale)")
        elif cfg.attn_impl != "mla":
            # serving: params need no FSDP if 2-axis TP keeps them resident
            serve_dtype = "bfloat16"
            param_rules["embed"] = ()
            for k in ("ff", "heads", "kv_heads", "lora", "expert_ff"):
                param_rules[k] = ("model", "data")
            notes.append("optimize: serve-TP over both axes, bf16 params "
                         "(no per-step FSDP gather)")
        else:
            # measured: 2-axis TP *regresses* MLA decode (the absorbed path
            # contracts over the compressed dim; input-sharded up-projections
            # force per-layer ARs) — keep the v1 serving plan
            notes.append("optimize: v1 plan retained (2-axis serve-TP "
                         "regresses absorbed MLA decode, measured 0.82x)")

    plan = Plan(param_rules=param_rules, act_rules=act_rules, remat=remat,
                loss_chunk=1024, est=est, notes=tuple(notes),
                serve_param_dtype=serve_dtype)
    if overrides:
        plan = dataclasses.replace(plan, **overrides)
    return plan


def optimized_cfg_overrides(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ModelConfig-level levers the optimizing planner recommends."""
    out: Dict[str, Any] = {}
    if cfg.n_routed_experts:
        out["moe_combine"] = "scatter"
    if cfg.attn_impl == "mla" and shape.kind == "train":
        # decode uses the weight-absorbed path, which contracts over the
        # compressed kv dim — lora sharding is the right layout there
        out["mla_shard"] = "heads"
    if shape.kind == "train" and shape.seq_len >= 8192:
        out["attn_mask_opt"] = True
    if shape.kind == "decode" and cfg.attn_impl == "gqa":
        out["cache_quant"] = True
    return out


def serving_page_plan(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                      page_size: int = 16, replicas: int = 1,
                      shared_prefix_len: int = 0,
                      users_per_prefix: int = 1,
                      tp: int = 1, prefill_replicas: int = 0,
                      prompt_len: Optional[int] = None,
                      host_ram: Optional[int] = None
                      ) -> Optional[Dict[str, Any]]:
    """Size the paged-KV page pool for the continuous-batching scheduler.

    The Ambari-style suggested config for the "serve" service
    (``repro.core.services.AmbariServer.provision_serving``): whatever HBM
    is left after bf16 serving params becomes one shared page pool, and the
    scheduler's admission control (worst-case page reservation) keeps
    occupancy inside it. Returns None for archs the paged engine does not
    cover (MLA / enc-dec — they keep the dense engine). A pool too small
    to ever admit one full-length sequence raises a ``ValueError`` naming
    the minimum viable pool — a "serve" service that can serve nothing
    must fail at planning time, not admit-time.

    With ``replicas=k`` the plan additionally carries a coherent per-replica
    split for the serving fabric (``repro.serving.router``): each replica
    is an independent scheduler with its own page pool, so the fleet-wide
    slot budget divides into k pools of ``slots_per_replica`` slots and
    ``pages_per_replica`` pages (+ each pool's own sink page). The split is
    floored at one full-length sequence per replica — a fabric member that
    could never admit a max-length request would be routing dead weight —
    so ``k * pages_per_replica`` may exceed ``num_pages`` when k is large
    relative to the HBM fit; ``max_replicas`` is the largest k for which
    the split stays inside the budget.

    With ``prefill_replicas=p`` (and ``replicas > p``) the plan adds a
    ``disagg`` section splitting the fleet into prefill and decode roles:
    a prefill replica's pool turns over at *prompt* granularity — its
    admission reserves ``ceil((prompt_len + 1) / page_size)`` pages per
    stream instead of the prompt+generation worst case — so the same slot
    count needs a smaller pool, and the freed pages go to the decode side
    where generations actually accumulate. ``prompt_len`` bounds the
    longest routed prompt (defaults to the shape's full ``seq_len`` —
    conservative, no saving assumed).

    With ``host_ram=b`` (bytes per replica group) the plan adds a
    ``host_tier`` section sizing the host-RAM swap plane: how many page
    slots the host budget holds and the resulting open-session ceiling
    (decoding sessions bounded by HBM, parked ones by host RAM).

    With ``tp=k`` each replica is a *shard group*: pages are logical, each
    member stores the ``1/k`` kv-head slice of every page, and params
    shard ``k`` ways too, so the pool is sized by what one member's HBM
    share can hold of its slices: ``num_pages = (budget/k) //
    (shard_page_bytes)``. Expressed in whole-page equivalents the k
    per-shard budgets (``pages_budget_per_shard = (budget/k) //
    page_bytes``) sum back to the unsharded ``num_pages`` within one page
    per shard — only integer flooring separates them (the acceptance
    check in tests/test_sharding.py). See docs/sharding.md for the math.

    All quantities are *global* (whole mesh); divide ``pool_bytes`` by the
    device count for the per-chip footprint. The suggestion, as everywhere
    in the planner, is a starting point the user may override.
    """
    if cfg.attn_impl == "mla" or cfg.is_encdec:
        return None
    if replicas < 1:
        raise ValueError("replicas must be >= 1")
    if tp < 1:
        raise ValueError("tp must be >= 1")
    from repro.serving.paged_cache import (page_bytes_per_token,
                                           shard_page_bytes_per_token)
    if page_bytes_per_token(cfg) == 0:
        return None                 # pure-SSM arch: O(1) state, no KV pages
    sizes = _mesh_sizes(mesh) if mesh is not None else {}
    n_dev = 1
    for v in sizes.values():
        n_dev *= v
    param_bytes = cfg.param_count() * 2            # bf16 serving params
    budget = max(n_dev * HBM_BUDGET - param_bytes, 0)
    tok_bytes = page_bytes_per_token(cfg)
    # raises for tp not dividing n_kv_heads — the same divisibility rule
    # the sharded decode path enforces (ShardGroup.validate_model)
    shard_tok_bytes = shard_page_bytes_per_token(cfg, tp)
    # the pool is bounded by one shard-group member: its 1/tp share of the
    # budget must hold its 1/tp slice of every page (tp=1: the whole pool)
    num_pages = int((budget // tp) // (shard_tok_bytes * page_size))
    pages_budget_per_shard = int((budget // tp) // (tok_bytes * page_size))
    pages_per_seq = -(-shape.seq_len // page_size)
    max_seqs = max(num_pages - 1, 0) // max(pages_per_seq, 1)
    if max_seqs < 1:
        # a tight pool silently flooring to zero full-length sequences used
        # to provision a service that could admit nothing (classic trigger:
        # page_size not dividing max_len rounds pages_per_seq up past the
        # fit) — name the minimum viable pool instead
        need_pages = pages_per_seq + 1          # one full seq + sink page
        need_bytes = need_pages * page_size * tok_bytes + param_bytes
        raise ValueError(
            f"{cfg.name} on {shape.name}: pool of {num_pages} pages cannot "
            f"hold one full-length sequence ({shape.seq_len} tokens = "
            f"{pages_per_seq} pages of {page_size} + sink); minimum viable "
            f"pool is {need_pages} pages — {need_bytes / GiB:.1f} GiB of "
            f"HBM incl. bf16 params (have {n_dev * HBM_BUDGET / GiB:.1f}); "
            f"provision more chips or shrink page_size/max_len")
    # capacity bands for the elastic control plane (repro.autoscale): the
    # autoscaler may move slot count / pool size anywhere inside them. The
    # max band is the HBM fit above; the min band keeps one full-length
    # sequence admissible so the service never scales to zero.
    min_slots = 1 if max_seqs else 0
    # ---- per-replica split (the fabric's reservation floor) ---------------
    # each replica must admit >= 1 full-length stream: pages_per_seq pages
    # of KV plus its pool's sink page
    slots_per_replica = max(max_seqs // replicas, min_slots)
    pages_per_replica = max(num_pages // replicas,
                            slots_per_replica * pages_per_seq + 1
                            if slots_per_replica else 0)
    # largest k whose split stays inside the HBM budget: every replica
    # pays its own sink page on top of one full-length seq's reservation
    max_replicas = num_pages // (pages_per_seq + 1) if max_seqs else 0
    plan = {
        "page_size": page_size,
        "num_pages": num_pages,
        "pages_per_seq": pages_per_seq,
        # page 0 of the pool is the scheduler's sink page, never allocated
        "max_concurrent_seqs": max_seqs,
        "page_bytes_per_token": tok_bytes,
        "pool_bytes": num_pages * page_size * tok_bytes,
        "min_slots": min_slots,
        "max_slots": max_seqs,
        "min_pages": min(pages_per_seq + 1, num_pages),
        "max_pages": num_pages,
        "replicas": replicas,
        "slots_per_replica": slots_per_replica,
        "pages_per_replica": pages_per_replica,
        "max_replicas": max_replicas,
        # ---- shard-group split (tensor-parallel replicas) ------------------
        "tp": tp,
        "pages_budget_per_shard": pages_budget_per_shard,
        "shard_page_bytes": shard_tok_bytes * page_size,
        "shard_pool_bytes": num_pages * page_size * shard_tok_bytes,
    }
    # ---- prefill/decode role split (disaggregated fabric) -----------------
    if prefill_replicas > 0:
        if prefill_replicas >= replicas:
            raise ValueError(
                f"disaggregation needs at least one decode replica: "
                f"prefill_replicas={prefill_replicas} >= "
                f"replicas={replicas}")
        p_len = shape.seq_len if prompt_len is None \
            else min(prompt_len, shape.seq_len)
        prompt_pages = -(-(p_len + 1) // page_size)
        prefill_pool = min(slots_per_replica * prompt_pages + 1,
                           pages_per_replica)
        plan["disagg"] = {
            "prefill_replicas": prefill_replicas,
            "decode_replicas": replicas - prefill_replicas,
            "prompt_len": p_len,
            "prompt_pages_per_seq": prompt_pages,
            # prompt-granularity reservation: a prefill replica's pool only
            # ever holds prompts (+1 position for the first output token)
            "prefill_pages_per_replica": prefill_pool,
            "decode_pages_per_replica": pages_per_replica,
            "prefill_pool_savings_frac": round(
                1 - prefill_pool / max(pages_per_replica, 1), 3),
        }
    # ---- host-RAM page tier (swap-out/swap-in second plane) ---------------
    # with ``host_ram`` bytes of host memory per replica group, idle
    # sessions' chains park in host pages instead of pinning HBM: open
    # (mostly-idle) session capacity is bounded by host pages, while
    # *concurrent* decode stays bounded by the HBM pool — InstaCluster's
    # size-to-the-working-set argument applied to the KV cache
    if host_ram is not None:
        if host_ram < 1:
            raise ValueError("host_ram must be >= 1 byte (or None)")
        host_pages = int(host_ram // (tok_bytes * page_size))
        plan["host_tier"] = {
            "host_ram_bytes": int(host_ram),
            "host_pages": host_pages,
            "host_pages_per_replica": max(host_pages // replicas, 0),
            # sessions whose whole chain can park on host, per replica
            "resident_sessions_per_replica": (
                max(host_pages // replicas, 0) // max(pages_per_seq, 1)),
            # open-session ceiling: decoding in HBM + parked on host
            "max_open_sessions": max_seqs + host_pages
            // max(pages_per_seq, 1),
        }
    # ---- shared-prefix capacity model (copy-on-write page cache) ----------
    # with N-way prefix sharing a sequence's *marginal* footprint is its
    # uncached suffix plus an amortised 1/N share of the prefix chain —
    # that is what sets concurrency once the scheduler's prefix cache is on
    # (repro.serving.paged_cache.PrefixIndex), and what the fleet router's
    # prefix-affinity policy tries to preserve across replicas
    if shared_prefix_len > 0:
        if users_per_prefix < 1:
            raise ValueError("users_per_prefix must be >= 1")
        prefix_pages = min(-(-shared_prefix_len // page_size), pages_per_seq)
        eff = (pages_per_seq - prefix_pages
               + prefix_pages / users_per_prefix)
        max_shared = int(max(num_pages - 1, 0) // max(eff, 1e-9))
        plan["shared_prefix"] = {
            "prefix_len": shared_prefix_len,
            "users_per_prefix": users_per_prefix,
            "prefix_pages": prefix_pages,
            "pages_per_seq_effective": round(eff, 2),
            "max_concurrent_seqs": max_shared,
            "page_savings_frac": round(1 - eff / max(pages_per_seq, 1), 3),
        }
    return plan


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> int:
    B, S = shape.global_batch, shape.seq_len
    total = 0
    for i in range(cfg.n_layers):
        kind = cfg.block_kind(i)
        if kind == "ssm":
            total += B * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4
        else:
            cap = S
            if kind == "attn_local" and cfg.sliding_window:
                cap = min(S, cfg.sliding_window)
            if cfg.attn_impl == "mla":
                total += B * cap * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
            else:
                total += 2 * B * cap * cfg.n_kv_heads * cfg.resolved_head_dim * 2
    return total
