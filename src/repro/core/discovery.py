"""Node directory: stable logical naming over unstable infrastructure.

The paper's master enumerates slaves once, assigns hostnames, and re-binds
hostname -> private IP after every cluster restart (EC2 changes private IPs).
We keep the same invariant for a TPU fleet: *logical ranks are stable*,
physical instance ids/IPs are not — checkpoints, mesh coordinates and service
placement all reference logical ranks only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.simcloud import Instance


@dataclasses.dataclass
class Node:
    hostname: str            # stable: "master", "slave-0", ...
    logical_rank: int        # master = -1, slaves = 0..N-1
    instance_id: str
    private_ip: str
    chips: int


class NodeDirectory:
    def __init__(self) -> None:
        self.nodes: Dict[str, Node] = {}

    # ------------------------------------------------------------ assembly --
    def enumerate(self, master: Instance, slaves: List[Instance]) -> None:
        """Initial hostname assignment (paper: master names slaves by its
        discovery enumeration order)."""
        self.nodes = {"master": Node("master", -1, master.instance_id,
                                     master.private_ip, master.chips)}
        for rank, inst in enumerate(sorted(slaves,
                                           key=lambda i: i.instance_id)):
            hn = f"slave-{rank}"
            self.nodes[hn] = Node(hn, rank, inst.instance_id,
                                  inst.private_ip, inst.chips)

    def add_slaves(self, new: List[Instance]) -> List[Node]:
        """Cluster extension (use case 4): new slaves get the next ranks."""
        base = 1 + max((n.logical_rank for n in self.nodes.values()),
                       default=-1)
        out = []
        for off, inst in enumerate(sorted(new, key=lambda i: i.instance_id)):
            hn = f"slave-{base + off}"
            node = Node(hn, base + off, inst.instance_id, inst.private_ip,
                        inst.chips)
            self.nodes[hn] = node
            out.append(node)
        return out

    def remove(self, hostname: str) -> Node:
        return self.nodes.pop(hostname)

    def replace_instance(self, hostname: str, inst: Instance) -> None:
        """Spare substitution: same logical rank, new hardware."""
        n = self.nodes[hostname]
        n.instance_id = inst.instance_id
        n.private_ip = inst.private_ip
        n.chips = inst.chips

    # ----------------------------------------------------------- rediscovery --
    def remap_ips(self, instances: List[Instance]) -> List[str]:
        """After restart: rebind hostnames to fresh private IPs by instance
        id (the paper uses EC2 tags for exactly this). Returns hostnames whose
        IP changed."""
        by_id = {i.instance_id: i for i in instances}
        changed = []
        for node in self.nodes.values():
            inst = by_id.get(node.instance_id)
            if inst is not None and inst.private_ip != node.private_ip:
                node.private_ip = inst.private_ip
                changed.append(node.hostname)
        return changed

    # -------------------------------------------------------------- exports --
    def hosts_file(self) -> str:
        lines = [f"{n.private_ip}\t{n.hostname}"
                 for n in sorted(self.nodes.values(),
                                 key=lambda n: n.logical_rank)]
        return "\n".join(lines) + "\n"

    def slaves(self) -> List[Node]:
        return sorted((n for n in self.nodes.values() if n.logical_rank >= 0),
                      key=lambda n: n.logical_rank)

    def total_chips(self) -> int:
        return sum(n.chips for n in self.slaves())
