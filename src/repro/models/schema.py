"""Parameter schema: single source of truth for shapes, init and sharding.

A model declares its parameters once as a tree of ``ParamSpec`` (shape +
logical axis names + init rule). From that one tree we derive:

  * concrete initialised parameters (``init_params``) for smoke tests,
  * abstract ``jax.ShapeDtypeStruct`` stand-ins (``abstract_params``) for the
    multi-pod dry-run — no allocation,
  * ``PartitionSpec`` trees (``partition_specs``) for pjit in_shardings,

so init/dry-run/sharding can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim
    init: str = "normal"                     # normal | zeros | ones | embed
    dtype: str = "float32"
    fan_in: Optional[int] = None             # override init scale fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# Logical-axis -> mesh-axis rules.
#
# Values are *preference lists* of mesh axes; at resolution time we keep only
# axes present in the mesh, unused so far in this param, and evenly dividing
# the dim. This gives automatic fallbacks (e.g. qwen2-moe's 60 experts do not
# divide a 16-wide "model" axis, so sharding falls through to the expert-ff
# dim) without per-arch special cases.
# ---------------------------------------------------------------------------

# FSDP rules: d_model/"embed" dims sharded over the data axis (ZeRO-3).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),           # FSDP axis
    "embed_pod": ("pod", "data"),  # planner may rewrite "embed" -> this
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "expert_ff": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "lora": ("model",),
    "layers": (),                 # scan stack dim: never sharded
    "conv": (),
    "pos": (),
}


def resolve_pspec(axes: Tuple[Optional[str], ...],
                  shape: Tuple[int, ...],
                  rules: Dict[str, Tuple[str, ...]],
                  mesh: Mesh) -> PartitionSpec:
    """Map logical axes -> PartitionSpec honouring divisibility & uniqueness."""
    used: set = set()
    out = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, name in zip(shape, axes):
        entry: Any = None
        if name is not None:
            picked = []
            prod = 1
            for ax in rules.get(name, ()):  # preference order
                if ax in sizes and ax not in used and dim % (prod * sizes[ax]) == 0:
                    picked.append(ax)
                    prod *= sizes[ax]
                    used.add(ax)
            if len(picked) == 1:
                entry = picked[0]
            elif picked:
                entry = tuple(picked)
        out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


# ---------------------------------------------------------------------------
# Tree traversal (params are nested dicts of ParamSpec)
# ---------------------------------------------------------------------------

def _map_with_path(tree: Any, fn, path: Tuple[str, ...] = ()) -> Any:
    if is_spec(tree):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: _map_with_path(v, fn, path + (str(k),)) for k, v in tree.items()}
    if tree is None:
        return None
    raise TypeError(f"bad schema node at {path}: {type(tree)}")


def init_params(schema: Any, key: jax.Array, dtype: Optional[str] = None) -> Any:
    """Materialise concrete parameters (smoke tests / examples only)."""

    def init_one(path, spec: ParamSpec):
        # crc32, not builtin hash(): str hashing is salted per process
        # (PYTHONHASHSEED), which made every process draw *different*
        # parameters from the same PRNG key — the source of cross-process
        # flakiness in the fp32 token-identity tests, and a lie in every
        # "--seed drives parameter init" claim. crc32 is stable everywhere.
        k = jax.random.fold_in(key,
                               zlib.crc32("/".join(path).encode()) % (2**31))
        dt = jnp.dtype(dtype or spec.dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init == "embed":
            return (jax.random.normal(k, spec.shape, jnp.float32) * 0.02).astype(dt)
        fan_in = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)

    return _map_with_path(schema, init_one)


def abstract_params(schema: Any, mesh: Mesh,
                    rules: Dict[str, Tuple[str, ...]] = DEFAULT_RULES) -> Any:
    """ShapeDtypeStruct tree with NamedShardings attached (dry-run inputs)."""

    def mk(path, spec: ParamSpec):
        pspec = resolve_pspec(spec.axes, spec.shape, rules, mesh)
        return jax.ShapeDtypeStruct(spec.shape, jnp.dtype(spec.dtype),
                                    sharding=NamedSharding(mesh, pspec))

    return _map_with_path(schema, mk)


def partition_specs(schema: Any, mesh: Mesh,
                    rules: Dict[str, Tuple[str, ...]] = DEFAULT_RULES) -> Any:
    return _map_with_path(
        schema, lambda p, s: resolve_pspec(s.axes, s.shape, rules, mesh))


def param_count(schema: Any) -> int:
    total = 0

    def add(path, spec: ParamSpec):
        nonlocal total
        total += int(np.prod(spec.shape))
        return spec

    _map_with_path(schema, add)
    return total


def param_bytes(schema: Any) -> int:
    total = 0

    def add(path, spec: ParamSpec):
        nonlocal total
        total += int(np.prod(spec.shape)) * jnp.dtype(spec.dtype).itemsize
        return spec

    _map_with_path(schema, add)
    return total
