"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs import (chatglm3_6b, deepseek_v2_236b, gemma2_2b,
                           jamba_v01_52b, mamba2_13b, qwen2_moe_a27b,
                           qwen2_vl_72b, qwen3_32b, qwen15_110b, whisper_tiny)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_is_runnable

_MODULES = {
    "gemma2-2b": gemma2_2b,
    "chatglm3-6b": chatglm3_6b,
    "qwen1.5-110b": qwen15_110b,
    "qwen3-32b": qwen3_32b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "qwen2-moe-a2.7b": qwen2_moe_a27b,
    "mamba2-1.3b": mamba2_13b,
    "whisper-tiny": whisper_tiny,
    "qwen2-vl-72b": qwen2_vl_72b,
}

ARCHS: Dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
REDUCED: Dict[str, ModelConfig] = {k: m.REDUCED for k, m in _MODULES.items()}

# rough expected parameter counts (sanity band for config tests), in billions
EXPECTED_PARAMS_B = {
    "gemma2-2b": (2.0, 3.5),
    "chatglm3-6b": (5.5, 7.5),
    "qwen1.5-110b": (95.0, 120.0),
    "qwen3-32b": (28.0, 36.0),
    "jamba-v0.1-52b": (45.0, 58.0),
    "deepseek-v2-236b": (210.0, 250.0),
    "qwen2-moe-a2.7b": (12.0, 16.5),
    "mamba2-1.3b": (1.1, 1.6),
    "whisper-tiny": (0.02, 0.08),
    "qwen2-vl-72b": (65.0, 80.0),
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_reduced(name: str) -> ModelConfig:
    return REDUCED[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells(include_skipped: bool = True):
    """Yield (arch_name, shape_name, runnable) for the 40-cell grid."""
    for a in ARCHS:
        for s in SHAPES:
            ok = cell_is_runnable(a, s)
            if ok or include_skipped:
                yield a, s, ok
