"""Deterministic synthetic data pipeline with per-DP-rank sharding.

Reproducibility is a paper pillar (researchers re-run each other's
experiments), so batches are a pure function of (seed, step, rank): any
restart or elastic resize regenerates identical global batches. Host-level
sharding matches the mesh's data axes; prefetch is a bounded lookahead
queue.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab_cap: int = 0          # 0 -> cfg.vocab_size


class SyntheticLM:
    """Zipf-ish synthetic token stream; batch = f(seed, step) exactly."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 dcfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.vocab = dcfg.vocab_cap or cfg.vocab_size
        self.seed = dcfg.seed

    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        """rows: global row indices -> (len(rows), seq+1) tokens."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed + 7919 * step))
        # one draw for the full global batch keeps restarts/resizes exact
        full = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        full = np.minimum(full - 1, self.vocab - 1).astype(np.int32)
        return full[rows]

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._tokens(step, np.arange(self.batch))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard_batch(self, step: int, dp_rank: int, dp_size: int
                    ) -> Dict[str, np.ndarray]:
        assert self.batch % dp_size == 0, (self.batch, dp_size)
        per = self.batch // dp_size
        rows = np.arange(dp_rank * per, (dp_rank + 1) * per)
        toks = self._tokens(step, rows)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def extras(self, batch_np: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Arch-specific extra inputs (mrope ids, encoder frames)."""
        out = dict(batch_np)
        B, S = batch_np["tokens"].shape
        if self.cfg.rope_variant == "mrope":
            pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None, None],
                                  (3, B, S)).copy()
            out["positions"] = pos
        if self.cfg.is_encdec:
            rng = np.random.Generator(np.random.Philox(key=self.seed + 13))
            out["enc_embeds"] = rng.standard_normal(
                (B, self.cfg.enc_positions, self.cfg.d_model),
                dtype=np.float32)
        return out


class Prefetcher:
    """Bounded background prefetch (overlaps host data gen with device step)."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._it = it
        self._sem = threading.Semaphore(depth)
        self._out: list = []
        self._done = False
        self._lock = threading.Condition()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            self._sem.acquire()
            with self._lock:
                self._out.append(item)
                self._lock.notify()
        with self._lock:
            self._done = True
            self._lock.notify()

    def __iter__(self):
        return self

    def __next__(self):
        with self._lock:
            while not self._out and not self._done:
                self._lock.wait()
            if self._out:
                item = self._out.pop(0)
                self._sem.release()
                return item
            raise StopIteration
