"""Subprocess SPMD checks — run with 8 fake CPU devices.

Executed by tests/test_spmd.py via subprocess so the main pytest process
keeps its single-device view. Each check prints 'PASS <name>' on success.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import REDUCED
from repro.checkpoint.manager import CheckpointManager
from repro.core.blueprint import suggest_plan
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh_for
from repro.models import model as M
from repro.models.schema import abstract_params, partition_specs
from repro.optim.adamw import OptimConfig
from repro.train.steps import init_train_state, make_train_step


def check_sharded_train_step_matches_single_device():
    """Same batch, same init: a (2 data x 2 model)-sharded train step must
    reproduce the single-device loss."""
    cfg = REDUCED["qwen3-32b"]
    ocfg = OptimConfig(warmup_steps=1, total_steps=10)
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    state = init_train_state(cfg, key)
    step_1d = jax.jit(make_train_step(cfg, ocfg))
    _, m1 = step_1d(jax.tree.map(jnp.copy, state), batch)

    mesh = make_mesh_for(2, 2)
    shape = ShapeConfig("t", 32, 8, "train")
    plan = suggest_plan(cfg, shape, mesh)
    specs = partition_specs(M.schema(cfg), mesh, plan.param_rules)
    shard_state = {
        "params": jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            state["params"], specs),
        "m": jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            state["m"], specs),
        "v": jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            state["v"], specs),
        "step": jax.device_put(state["step"], NamedSharding(mesh, P())),
    }
    sb = {k: jax.device_put(v, NamedSharding(mesh, P(("data",))))
          for k, v in batch.items()}
    step_2d = jax.jit(make_train_step(cfg, ocfg, mesh=mesh,
                                      act_rules=plan.act_rules))
    with mesh:
        _, m2 = step_2d(shard_state, sb)
    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert abs(l1 - l2) / abs(l1) < 2e-3, (l1, l2)
    print("PASS sharded_train_step_matches_single_device")


def check_elastic_reshard_resume():
    """Checkpoint on a (4 data x 2 model) mesh, restore on (2 data x 2
    model) — loss trajectory continues identically (elastic resize)."""
    cfg = REDUCED["gemma2-2b"]
    ocfg = OptimConfig(warmup_steps=1, total_steps=50)
    key = jax.random.PRNGKey(1)
    tokens = np.asarray(jax.random.randint(key, (8, 32), 0, cfg.vocab_size))
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}

    def run_steps(mesh, state, n):
        plan = suggest_plan(cfg, ShapeConfig("t", 32, 8, "train"), mesh)
        step = jax.jit(make_train_step(cfg, ocfg, mesh=mesh,
                                       act_rules=plan.act_rules))
        losses = []
        with mesh:
            for _ in range(n):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        return state, losses

    def place(state, mesh):
        plan = suggest_plan(cfg, ShapeConfig("t", 32, 8, "train"), mesh)
        specs = partition_specs(M.schema(cfg), mesh, plan.param_rules)
        out = {}
        for k in ("params", "m", "v"):
            out[k] = jax.tree.map(
                lambda a, s: jax.device_put(np.asarray(a),
                                            NamedSharding(mesh, s)),
                state[k], specs)
        out["step"] = jax.device_put(np.asarray(state["step"]),
                                     NamedSharding(mesh, P()))
        return out

    state0 = init_train_state(cfg, key)

    # reference: 6 uninterrupted steps on the big mesh
    mesh_big = make_mesh_for(4, 2)
    ref_state = place(state0, mesh_big)
    _, ref_losses = run_steps(mesh_big, ref_state, 6)

    # elastic: 3 steps on big mesh -> checkpoint -> restore on small mesh
    state_a = place(state0, mesh_big)
    state_a, losses_a = run_steps(mesh_big, state_a, 3)
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, async_writes=False)
        ck.save(state_a, 3, blocking=True)
        mesh_small = make_mesh_for(2, 2)
        template = place(init_train_state(cfg, key), mesh_small)
        state_b = ck.restore(target=template)
    state_b, losses_b = run_steps(mesh_small, state_b, 3)
    got = losses_a + losses_b
    np.testing.assert_allclose(got, ref_losses, rtol=2e-3)
    print("PASS elastic_reshard_resume")


def check_compressed_psum():
    from repro.parallel.collectives import (compressed_psum,
                                            compression_error_bound,
                                            make_compressed_grad_sync)
    from jax.experimental.shard_map import shard_map
    mesh = make_mesh_for(2, 2, 2)   # pod x data x model
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64), jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("pod")))
    sync = make_compressed_grad_sync(mesh, axis="pod")
    with mesh:
        got = sync({"g": xs})["g"]
    # every pod slice holds the mean over pod shards
    want = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
    err = float(jnp.max(jnp.abs(got - want)))
    bound = 2 * compression_error_bound(x)  # sum of 2 quantised operands / 2
    assert err <= bound + 1e-6, (err, bound)
    assert err < 0.05, err
    print("PASS compressed_psum")


def check_decode_cache_stays_sharded():
    """Sequence-sharded decode: lowering keeps the kv cache sharded (no
    all-gather of the cache itself)."""
    import re
    cfg = REDUCED["qwen3-32b"]
    mesh = make_mesh_for(2, 4)
    from repro.core.blueprint import suggest_plan
    from repro.launch.specs import decode_specs
    from repro.train.steps import make_serve_step
    shape = ShapeConfig("d", 4096, 8, "decode")
    plan = suggest_plan(cfg, shape, mesh)
    params, cache, tokens, cur = decode_specs(cfg, shape, mesh, plan)
    step = make_serve_step(cfg, mesh=mesh, act_rules=plan.act_rules)
    with mesh:
        compiled = jax.jit(step).lower(params, cache, tokens, cur).compile()
    hlo = compiled.as_text()
    cache_bytes = 4096 * cfg.n_kv_heads * 128 * 2  # per batch row, bf16
    # no all-gather output as large as a full cache leaf
    big = 0
    for m in re.finditer(r"bf16\[([\d,]+)\][^ ]* all-gather", hlo):
        dims = [int(d) for d in m.group(1).split(",")]
        n = 1
        for d in dims:
            n *= d
        big = max(big, n * 2)
    assert big < cache_bytes, (big, cache_bytes)
    print("PASS decode_cache_stays_sharded")


def check_gpipe_matches_sequential():
    """Pipeline-parallel execution over 4 stages == sequential layer loop."""
    from repro.parallel.pipeline import gpipe_forward, pipeline_bubble_fraction
    L, B, D, F = 8, 8, 32, 64
    key = jax.random.PRNGKey(3)
    w1 = jax.random.normal(key, (L, D, F), jnp.float32) * 0.2
    w2 = jax.random.normal(jax.random.fold_in(key, 1), (L, F, D)) * 0.2
    params = {"w1": w1, "w2": w2}
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

    def body(pl, h):
        return h + jnp.tanh(h @ pl["w1"]) @ pl["w2"]

    ref = x
    for i in range(L):
        ref = body(jax.tree.map(lambda a: a[i], params), ref)

    from repro.launch.mesh import _axis_kwargs
    mesh = jax.make_mesh((4,), ("stage",), **_axis_kwargs(1))
    ps = jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P("stage"))), params)
    with mesh:
        out = gpipe_forward(ps, x, body=body, mesh=mesh, axis="stage",
                            n_micro=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(pipeline_bubble_fraction(4, 4) - 3 / 7) < 1e-9
    print("PASS gpipe_matches_sequential")


def check_shard_group_paged_decode():
    """Tensor-parallel shard group under real shard_map (2 devices on the
    "model" axis): per-shard pools + head-sliced weights, one program per
    device, head all_gather on the wire — tokens match the single-device
    tp=1 decode and the in-program unrolled-loop tp=2 path."""
    import dataclasses

    from repro.parallel.context import ShardGroup
    from repro.serving import paged_cache as PC

    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh_for(4, 2)              # ("data", "model"): model axis 2
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, size=11).astype(np.int32)
    from repro.models.transformer import lm_forward

    def run(shard):
        tp = 1 if shard is None else shard.tp
        cache = PC.init_paged_cache(cfg, 6, 8, 2, tp=tp)
        _, _, pre = lm_forward(cfg, params, jnp.asarray(prompt[None]),
                               mode="prefill")
        row = np.array([1, 2, 0], np.int32)
        cache = PC.write_prefill(cfg, cache, pre, jnp.asarray(row), 0,
                                 len(prompt), len(prompt), 8, tp=tp)
        bt = np.zeros((2, 3), np.int32)
        bt[0] = row
        lens = np.array([len(prompt), 0], np.int32)
        last = np.array([[3], [0]], np.int32)
        toks = []
        for _ in range(5):
            lg, cache = M.paged_decode_step(
                cfg, params, cache, jnp.asarray(last), jnp.asarray(lens),
                jnp.asarray(bt), shard=shard)
            nxt = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
            toks.append(nxt)
            last[0, 0] = nxt
            lens[0] += 1
        return toks

    want = run(None)
    loop = run(ShardGroup(2))
    with mesh:
        spmd = run(ShardGroup(2, mesh=mesh))
    assert want == loop == spmd, (want, loop, spmd)
    print("PASS shard_group_paged_decode")


def check_chunked_prefill_tp2():
    """Chunked prefill composes with a tp=2 shard group under real
    shard_map: per-tick chunk budgets drive the bucketed prefill and
    suffix programs on head-sliced per-shard pools, one control plane —
    tokens match both single-device monolithic serving and the
    in-program unrolled-loop tp=2 path."""
    import dataclasses

    from repro.serving.scheduler import ContinuousBatchingScheduler

    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh_for(4, 2)              # ("data", "model"): model axis 2
    rng = np.random.RandomState(1)
    trace = [(rng.randint(0, cfg.vocab_size, size=p).astype(np.int32), g)
             for p, g in ((13, 3), (21, 4), (6, 3))]

    def serve(tp, budget, shard_mesh=None):
        s = ContinuousBatchingScheduler(
            cfg, params, max_slots=2, page_size=8, max_seq_len=48,
            prefix_cache=False, tp=tp, shard_mesh=shard_mesh,
            prefill_budget=budget)
        reqs = [s.submit(p, g, arrival_step=i)
                for i, (p, g) in enumerate(trace)]
        s.run()
        assert s.alloc.num_allocated == 0 and s.reserved_pages == 0
        return [list(r.out_tokens) for r in reqs]

    want = serve(1, None)
    assert serve(1, 4) == want              # chunked == monolithic, tp=1
    assert serve(2, 4) == want              # + tp=2 unrolled loop
    with mesh:
        assert serve(2, 4, shard_mesh=mesh) == want   # + real shard_map
    print("PASS chunked_prefill_tp2")


if __name__ == "__main__":
    checks = {name[len("check_"):]: fn
              for name, fn in sorted(globals().items())
              if name.startswith("check_")}
    wanted = sys.argv[1:] or list(checks)
    for name in wanted:
        checks[name]()
    print("ALL_OK")
