"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff(dense)=12288 expert_ff=1536 vocab=102400
[arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,
    vocab_size=102400,
    attn_impl="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_routed_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    expert_d_ff=1536,
    shared_expert_d_ff=3072,
    first_k_dense=1,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b-reduced",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    attn_impl="mla",
    q_lora_rank=32,
    kv_lora_rank=32,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    n_routed_experts=8,
    n_shared_experts=2,
    moe_top_k=2,
    expert_d_ff=32,
    shared_expert_d_ff=64,
    first_k_dense=1,
    tie_embeddings=False,
)
