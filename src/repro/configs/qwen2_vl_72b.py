"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; vision frontend is a STUB
(input_specs supplies M-RoPE position ids; patch embeddings are precomputed).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_variant="mrope",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    rms_eps=1e-6,
)

REDUCED = ModelConfig(
    name="qwen2-vl-72b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
    rope_variant="mrope",
    tie_embeddings=False,
)
