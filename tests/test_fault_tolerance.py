"""Fault tolerance: checkpoint/restore determinism, failure recovery,
heartbeat failure/straggler detection, spare replacement."""
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import REDUCED
from repro.core.cluster import ClusterManager
from repro.core.heartbeat import HeartbeatMonitor, HostState
from repro.optim.adamw import OptimConfig
from repro.train.trainer import SimFailure, Trainer

CFG = REDUCED["gemma2-2b"]
OCFG = OptimConfig(peak_lr=1e-3, warmup_steps=2, total_steps=100)


def make_trainer(tmp_path, name="ck", every=2):
    return Trainer(CFG, OCFG, batch=4, seq=32,
                   ckpt_dir=str(tmp_path / name), ckpt_every=every)


def test_checkpoint_roundtrip_bitwise(tmp_path):
    tr = make_trainer(tmp_path)
    state = tr.init_state()
    tr.ckpt.save(state, 0, blocking=True)
    restored = tr.ckpt.restore(target=tr.init_state())
    flat_a = {k: np.asarray(v) for k, v in
              __import__("repro.checkpoint.manager",
                         fromlist=["_flatten"])._flatten(state).items()}
    flat_b = {k: np.asarray(v) for k, v in
              __import__("repro.checkpoint.manager",
                         fromlist=["_flatten"])._flatten(restored).items()}
    assert set(flat_a) == set(flat_b)
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k])


def test_atomic_commit_ignores_partial(tmp_path):
    tr = make_trainer(tmp_path)
    tr.ckpt.save(tr.init_state(), 0, blocking=True)
    # simulate a crash mid-save: stray .tmp dir must be ignored
    tmp = tr.ckpt.dir / "step_00000009.tmp"
    tmp.mkdir()
    (tmp / "leaf_00000.npy").write_bytes(b"garbage")
    assert tr.ckpt.latest_step() == 0


def test_retention_policy(tmp_path):
    tr = make_trainer(tmp_path)
    st = tr.init_state()
    for s in range(6):
        tr.ckpt.save(st, s, blocking=True)
    assert tr.ckpt.all_steps() == [3, 4, 5]


def test_failure_recovery_matches_uninterrupted_run(tmp_path):
    """A run that dies at step 5 and restores must reproduce the
    uninterrupted loss trajectory exactly (deterministic pipeline)."""
    clean = make_trainer(tmp_path, "clean")
    r_clean = clean.run(8)
    assert r_clean.restores == 0

    faulty = make_trainer(tmp_path, "faulty")
    r_faulty = faulty.run(8, failure_at={5: SimFailure("preempted")})
    assert r_faulty.restores == 1
    assert r_faulty.final_step == 8
    # replayed steps produce identical losses
    def by_step(losses):
        return losses[-3:]
    np.testing.assert_allclose(r_clean.losses[-3:], r_faulty.losses[-3:],
                               rtol=1e-5)


def test_failure_without_checkpoint_raises(tmp_path):
    tr = Trainer(CFG, OCFG, batch=4, seq=32, ckpt_dir=None)
    with pytest.raises(SimFailure):
        tr.run(4, failure_at={1: SimFailure("boom")})


# ------------------------------------------------------------- heartbeats --

def test_heartbeat_dead_detection():
    mon = HeartbeatMonitor(interval=10)
    dead = []
    mon.on_dead(dead.append)
    for h in ("slave-0", "slave-1"):
        mon.register(h, now=0.0)
    for t in range(10, 70, 10):
        mon.beat("slave-0", float(t))
    states = mon.check(70.0)
    assert states["slave-1"] == HostState.DEAD
    assert dead == ["slave-1"]
    assert states["slave-0"] in (HostState.ALIVE, HostState.SUSPECT)


def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(interval=10, straggler_factor=1.5)
    flagged = []
    mon.on_straggler(flagged.append)
    for i in range(4):
        mon.register(f"slave-{i}", now=0.0)
    for t in range(1, 5):
        for i in range(4):
            st = 1.0 if i < 3 else 2.4     # slave-3 is 2.4x slower
            mon.beat(f"slave-{i}", t * 10.0, step_time=st)
    states = mon.check(41.0)
    assert states["slave-3"] == HostState.STRAGGLER
    assert flagged == ["slave-3"]


def test_spare_replacement_keeps_rank():
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=4)
    ic.lifecycle.provision_spares(ic.cluster, 1)
    victim = ic.cluster.directory.nodes["slave-2"]
    old_instance = victim.instance_id
    mgr.cloud.fail_instance(old_instance)
    node = ic.lifecycle.replace_failed(ic.cluster, "slave-2")
    assert node.hostname == "slave-2"          # logical rank stable
    assert node.instance_id != old_instance    # hardware swapped
    assert mgr.cloud.instances[node.instance_id].tags[
        "instacluster:role"] == "slave-2"


def test_spot_preemption_triggers_hook():
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=2, spot=True)
    lost = []
    mgr.cloud.on_preempt(lambda inst: lost.append(inst.instance_id))
    victim = ic.cluster.slaves[0].instance_id
    mgr.cloud.preempt_spot(victim)
    assert lost == [victim]
