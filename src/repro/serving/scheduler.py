"""Continuous-batching serving scheduler over the paged KV cache.

The dense engine (``repro.serving.engine``) decodes one fixed batch until
its *longest* member finishes — occupancy decays as short requests drain,
and a new request waits for the whole batch. This scheduler keeps a fixed
set of decode *slots* and runs one jit-compiled paged decode step per tick:

* **join-on-arrival** — a waiting request is prefilled and inserted into
  any free slot between decode steps (no reshape, no recompile: the step
  function's shapes are fixed at ``(max_slots, 1)``);
* **evict-on-finish** — a finished request frees its pages and its slot the
  same tick, so the next arrival takes over immediately;
* **prefill/decode interleave** — admission runs between decode ticks;
  prefill is batch-1, bucketed to a small set of padded lengths so mixed
  prompt lengths share compilations (right padding is causally invisible).

Greedy sampling, like the dense engine. Admission uses worst-case page
reservation (``ceil((prompt + max_new) / page_size)`` pages), so a request
that is admitted can never hit a mid-flight pool OOM. Page-pool sizing for
a target arch/shape comes from ``repro.core.blueprint.serving_page_plan``,
and the provisioning layer exposes it as the "serve" service
(``repro.core.services.AmbariServer.provision_serving``).

Admission also consults the **shared-prefix cache** (``prefix_cache=True``
default for non-MoE archs): the longest in-flight prompt prefix already
holding the request's tokens is shared page-for-page (refcounted; a
mid-page match is copy-on-write forked), only the uncached suffix is
prefilled, and the reservation charges only that suffix — fleet chat
traffic with N personas × M users pays one persona prefill instead of M.
See docs/serving.md "Shared prefixes" for the COW state diagram and the
determinism contract.

Works for decoder-only archs without MLA attention; SSM/hybrid and MoE
archs are supported with exact-length prefill (an SSM state folds padding
in; MoE routing lets padding compete for expert capacity). One caveat for
MoE at multi-slot: the decode router groups all slots' tokens under one
capacity bound (exactly like the dense engine's batch), so concurrent
requests can influence each other's routing when capacity binds — the
late-join byte-determinism guarantee is for dense/SSM archs. See
docs/serving.md for the API walk-through and tuning knobs.

**Chunked prefill** (``prefill_budget=N``): instead of one monolithic
prefill call that blocks every decode tick behind a long prompt, admission
only allocates the prompt's pages and the prompt then lands in chunks of
at most ``N`` tokens per tick, interleaved with decode ticks — the request
sits in the PREFILLING state (``req.prefill_pos`` is the chunk cursor)
and joins decode the tick its last chunk lands. The first chunk is a
bucketed batch-1 prefill; later chunks ride the shared-prefix suffix
paths (``_suffix_fn`` for dense archs, ``_seq_suffix_fn`` from the slot's
SSM state for hybrid/MoE), so chunked output is byte-identical to
monolithic at fp32 — the same contract the prefix cache proves. Budget is
spent FCFS over in-flight prefills, so the oldest admitted prefill always
advances (no starvation) and per-tick chunk tokens never exceed ``N``.

**Disaggregation** (``role="prefill" | "decode"``): a prefill-role
scheduler admits and prefills but never decodes — a completed prompt
*parks* (``handoff_ready``) until the fabric router migrates its KV pages
verbatim to a decode-role scheduler (``adopt`` / ``surrender_slot``,
refcount- and prefix-index-correct on both sides). Prefill-role admission
reserves only the prompt's pages (the decode side reserves worst-case on
adopt), so a prefill replica's pool turns over at prompt, not
prompt+generation, granularity.

The request dataclass and its lifecycle live in ``repro.serving.request``
(shared with the static engine and the fabric router); this module is the
single-scheduler core only. One scheduler drives one page pool — a fleet
of them behind ``repro.serving.router.ServingRouter`` is the replicated
serving fabric, with each scheduler wrapped as a
``repro.serving.replica.ServingReplica`` placed on a cluster node.

``tp > 1`` makes the scheduler a *shard group*: one logical scheduler
whose page pools split into per-shard kv-head slices across ``tp`` devices
(placed on ``tp`` cluster nodes by ``provision_serving``), with the block
table, allocator, prefix index, and admission ledger staying a single
control plane. Decoded tokens are byte-identical to ``tp=1`` for dense
archs — see docs/sharding.md for the determinism contract and the
per-shard page-budget math.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import flags as model_flags
from repro.models import model as M
from repro.models.transformer import lm_forward
from repro.obs.metrics import MetricsRegistry, StatsView, TICK_BUCKETS
from repro.parallel.context import ShardGroup
from repro.serving import paged_cache as PC
from repro.serving.request import Request, make_request

DEFAULT_BUCKETS = (8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512)

__all__ = ["ContinuousBatchingScheduler", "DEFAULT_BUCKETS", "Request",
           "clear_program_cache", "program_cache_size", "spec_accept",
           "supports_paged"]

_EMPTY_DRAFT = np.zeros((0,), np.int32)


def spec_accept(drafts, targets) -> int:
    """Greedy rejection sampling, argmax edition: the number of leading
    draft tokens equal to the target model's argmax at the same position.

    ``targets[i]`` is the target argmax given the context plus drafts
    ``< i`` — accepted drafts are exactly the tokens spec-off greedy
    decoding would have emitted, so acceptance preserves byte identity by
    construction. The verify tick emits ``accepted + 1`` tokens: the
    accepted prefix plus the target's correction (or bonus) token. Pure
    host-side rule — the hypothesis ledger machine drives it directly.
    """
    j = 0
    for d, t in zip(drafts, targets):
        if int(d) != int(t):
            break
        j += 1
    return j

# Compiled prefill-family programs shared across *every* scheduler instance
# in the process. A fleet of replicas (router / autoscaler / disaggregation
# benches) builds schedulers with identical (cfg, bucket, tp) shapes; before
# this cache each instance held private ``{n: jit fn}`` dicts and re-traced
# the same programs per replica — the direct cause of the chunked-prefill
# throughput gap serve_bench measured (415.8 -> 192.2 tok/s), since a
# benchmark sweep rebuilds its scheduler per scenario. Keyed on everything
# a program closes over: kind, padded length, the (hashable) ModelConfig,
# page size, the shard group's identity, and the baked-in prefill-kernel
# flag. jit itself dedups by argument shape under each entry, so differing
# block-table widths (max_seq_len) share one entry without confusion.
_PROGRAM_CACHE: Dict[Any, Any] = {}


def program_cache_size() -> int:
    return len(_PROGRAM_CACHE)


def clear_program_cache() -> None:
    """Drop every cached prefill program (tests / leak-hunting hook)."""
    _PROGRAM_CACHE.clear()


def supports_paged(cfg: ModelConfig) -> bool:
    return not cfg.is_encdec and cfg.attn_impl != "mla"


@dataclasses.dataclass
class _RetainedChain:
    """A finished stream's page chain kept warm for session resume.

    Device-resident records (``_retained``) hold the allocator refs the
    finished stream held — nothing is freed at finish — so the chain stays
    prefix-shareable at zero cost until HBM pressure preempts it. Host
    records (``_host_chains``) list *host-tier* page ids after a swap-out.
    ``tokens`` is the chain length the cost model prices a resume at;
    ``(priority, step)`` orders eviction (lowest class first, coldest
    first within a class)."""
    pages: List[int]
    tokens: int
    priority: int
    tenant: str
    step: int


class ContinuousBatchingScheduler:
    """Admission + continuous batching loop over ``max_slots`` decode slots.

    Parameters mirror ``serving_page_plan``'s output: ``page_size`` tokens
    per page, ``num_pages`` in the shared pool (page 0 is the sink),
    ``max_seq_len`` bounds prompt+generation and fixes the block-table
    width.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, max_slots: int = 4,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 max_seq_len: int = 512,
                 prefill_buckets: Sequence[int] = DEFAULT_BUCKETS,
                 prefix_cache: Optional[bool] = None, tp: int = 1,
                 shard_mesh=None, prefill_budget: Optional[int] = None,
                 role: str = "mixed", prefill_fused: Optional[bool] = None,
                 prefill_kernel: bool = False,
                 spec_k: Optional[int] = None, spec_draft=None,
                 host_pages: Optional[int] = None,
                 tenant_quotas: Optional[Dict[str, int]] = None,
                 swap_crossover: Optional[int] = None):
        if not supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.name}: paged serving covers decoder-only non-MLA "
                "archs; use repro.serving.engine for this one")
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        # chunked prefill: at most this many prompt tokens land per tick
        # (None = monolithic prefill at admission, the pre-chunking path)
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1 token per tick")
        self.prefill_budget = prefill_budget
        # disaggregation role: "mixed" (default) prefills and decodes;
        # "prefill" parks completed prompts for page handoff; "decode"
        # adopts handed-off streams and only decodes
        if role not in ("mixed", "prefill", "decode"):
            raise ValueError(f"unknown scheduler role {role!r}")
        self.role = role
        # tensor-parallel shard group: one logical scheduler/replica whose
        # page pools, attention heads, and MoE experts split tp ways while
        # the block table / allocator / prefix index stay one control plane
        self.tp = tp
        self.shard = ShardGroup(tp, mesh=shard_mesh) if tp > 1 else None
        if self.shard is not None:
            self.shard.validate_model(cfg)
        self.n_pg = PC.pages_for_len(max_seq_len, page_size)
        if num_pages is None:
            num_pages = max_slots * self.n_pg + 1        # + sink
        # SSM state folds every processed token in, and MoE routing makes
        # tokens compete for expert capacity — bucket padding would change
        # real tokens' results for either, so such archs prefill exact-length
        # (one compile per distinct prompt length).
        self._has_ssm = any(cfg.block_kind(i) == "ssm"
                            for i in range(cfg.n_layers))
        self.exact_prefill = cfg.n_routed_experts > 0 or self._has_ssm
        # fused prefill: land prompt tokens directly in their pages with
        # paged_prefill_step — one dispatch per chunk instead of the
        # prefill+insert pair (first chunk) or the batched-rows suffix trick
        # (every row a full-pool gather). Exact-prefill archs keep the
        # sequential paths: an SSM state must fold tokens in order, and MoE
        # capacity grouping differs between the fused chunk and the decode
        # steps the byte-determinism contract compares against.
        if prefill_fused is None:
            prefill_fused = not self.exact_prefill
        self.prefill_fused = bool(prefill_fused) and not self.exact_prefill
        # bake the Pallas write+attend kernel pair into the fused programs
        # (interpret-mode on CPU; flags.use_prefill_kernel at trace time)
        self.prefill_kernel = bool(prefill_kernel)
        # speculative decoding: each verify tick runs every decoding slot's
        # last token plus up to spec_k draft tokens as parallel rows of one
        # paged decode dispatch, greedy-accepts the longest matching prefix,
        # and rolls the rejected tail back (seq_lens; SSM snapshots for
        # hybrids). Greedy accept keeps emitted tokens byte-identical to
        # spec-off decoding — the serve_bench --spec hard gate.
        if spec_k is not None and not 1 <= spec_k <= 32:
            raise ValueError("spec_k must be in [1, 32] draft tokens per "
                             "tick (bounds the verify row count)")
        if spec_draft is not None and spec_k is None:
            raise ValueError("spec_draft needs spec_k set")
        if spec_k is not None and cfg.n_routed_experts > 0:
            raise ValueError(
                "speculative decoding needs byte-deterministic decode; MoE "
                "capacity grouping couples concurrent tokens (the multi-slot "
                "caveat in docs/serving.md), so spec_k covers dense/SSM "
                "archs only")
        if spec_draft is not None:
            dcfg = spec_draft[0]
            if dcfg.is_encdec:
                raise ValueError("draft model must be decoder-only")
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft model must share the tokenizer: draft vocab "
                    f"{dcfg.vocab_size} != target {cfg.vocab_size}")
            if dcfg.n_routed_experts > 0 or any(
                    dcfg.block_kind(i) == "ssm"
                    for i in range(dcfg.n_layers)):
                raise ValueError(
                    "draft model must be attention-only: the incremental "
                    "draft cache rolls rejected positions back by length "
                    "masking, which has no SSM-state or MoE analogue")
        self.spec_k = spec_k
        self.spec_draft = spec_draft            # (draft_cfg, draft_params)
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_seq_len))
        # shared-prefix cache: admission shares the longest cached prefix's
        # pages and prefills only the uncached suffix. Hybrid archs can only
        # resume where an SSM state snapshot exists (exact-entry hits).
        # Default: on, except for MoE archs — a cached suffix steps through
        # the decode router one token at a time, grouping expert capacity
        # differently than the full prefill it replaces, which breaks the
        # byte-determinism contract the fleet's re-prefill path relies on.
        # MoE archs may still opt in (prefix_cache=True) where approximate
        # token identity under capacity pressure is acceptable.
        if prefix_cache is None:
            prefix_cache = cfg.n_routed_experts == 0
        self.prefix_cache = prefix_cache
        self.index = PC.PrefixIndex(page_size)
        # host-RAM page tier: finished streams' chains are *retained* on
        # device (still prefix-shareable) instead of freed, and under HBM
        # pressure admission preempts the coldest retained chains — the
        # recompute-vs-transfer cost model decides per chain whether its
        # bytes move to host RAM (long chains: PCIe transfer beats prefill
        # FLOPs) or are dropped for re-prefill on resume (short chains).
        # Retention requires the prefix cache: a retained chain is only
        # reachable through the index. ``tenant_quotas`` caps the pages a
        # tenant's *live* streams may reserve (retained chains are not
        # charged: they are reclaimable, so they cost the tenant nothing).
        if host_pages is not None and host_pages < 1:
            raise ValueError("host_pages must be >= 1 (or None to disable "
                             "the host tier)")
        self.host_tier = (PC.HostPageTier(host_pages)
                          if host_pages is not None else None)
        # recompute-vs-transfer decision point, in chain tokens: chains at
        # least this long swap to host (PCIe transfer beats re-prefill
        # FLOPs), shorter ones drop and re-prefill on resume. Default:
        # derived from the cfg's roofline cost model — the comparison is
        # monotone in chain length, so the smallest length where transfer
        # wins summarizes it exactly (None: transfer never wins at this
        # model scale, every preemption re-prefills). Benches/operators may
        # override to place the crossover inside their workload.
        if swap_crossover is not None:
            self._swap_crossover: Optional[int] = int(swap_crossover)
        else:
            self._swap_crossover = PC.swap_crossover_tokens(cfg, page_size)
        self.tenant_quotas = dict(tenant_quotas) if tenant_quotas else None
        self._tenant_reserved: Dict[str, int] = {}
        self._retained: Dict[int, _RetainedChain] = {}
        self._host_chains: Dict[int, _RetainedChain] = {}
        self._host_page_chain: Dict[int, int] = {}   # host page -> chain key
        self._retain_seq = 0
        if self.host_tier is not None:
            # freed host pages invalidate index entries by their *tagged*
            # id — same one-control-plane rule as the device allocator
            self.host_tier.alloc.on_free = (
                lambda p: self.index.invalidate_page(PC.as_host_page(p)))

        self.cache = PC.init_paged_cache(cfg, num_pages, page_size, max_slots,
                                         tp=tp)
        # incremental draft-model cache: a parallel (unsharded) page pool at
        # the DRAFT's dims mirroring the target's page geometry 1:1 — the
        # draft reuses the target's block tables verbatim, so page alloc /
        # free / COW need no second ledger. Per tick the draft advances by
        # one teacher-forced token (the stream's last committed token, the
        # same input verify row 0 gets) plus spec_k greedy steps: O(k) draft
        # work per tick instead of re-prefilling the context. The cache is
        # best-effort state: stale or collided bytes (COW sharing, a
        # migration) only lower the accept rate — every draft token is
        # target-verified, so emitted tokens never depend on it.
        if spec_draft is not None:
            self._draft_cache = PC.init_paged_cache(
                spec_draft[0], num_pages, page_size, max_slots)
            self._draft_ready = [False] * max_slots
        self.alloc = PC.PageAllocator(num_pages)
        self.alloc.on_free = self.index.invalidate_page
        self.block_table = np.full((max_slots, self.n_pg), PC.SINK_PAGE,
                                   np.int32)
        self.seq_lens = np.zeros((max_slots,), np.int32)
        self.last_tokens = np.zeros((max_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        # per-slot admission bookkeeping: pages charged against the pool
        # (net of shared prefix pages) and the shared-page count itself
        self.slot_reserve: List[int] = [0] * max_slots
        self.slot_shared: List[int] = [0] * max_slots
        # chunked-prefill bookkeeping: SSM resume snapshot for a slot's next
        # chunk (set by a prefix hit; None = read the slot's live state),
        # parked flag (prefill role: done, awaiting page handoff), and the
        # FCFS order budget is spent in (slot ids, admit order)
        self.slot_resume_state: List[Any] = [None] * max_slots
        self.slot_parked: List[bool] = [False] * max_slots
        self._prefill_fifo: List[int] = []
        self.waiting: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self._admit_done: List[Request] = []
        self.step_idx = 0
        self.reserved_pages = 0
        # live resize (repro.autoscale): slots above target_slots are
        # draining — no new admissions; the arrays shrink once they empty
        self.target_slots = max_slots
        # a controller may promise future pool growth up to this many pages
        # so submit() validates against the band ceiling, not today's pool
        self.capacity_hint: Optional[int] = None
        # observability plane (repro.obs): every legacy ``stats`` key is
        # backed by a typed registry metric — StatsView keeps the dict
        # idioms (``stats["x"] += 1``, ``dict(stats)``) working while the
        # registry gains Prometheus exposition and latency histograms.
        # All hooks are read-only over scheduler state: tracing/metrics/
        # profiling on vs off is byte-identical in emitted tokens.
        self.replica_id: Optional[int] = None   # set by ServingReplica
        self.tracer = None                      # set via set_tracer
        self._trace_own_clock = True            # router flips: fleet clock
        self.profiler = None                    # set via enable_profiling
        self.registry = MetricsRegistry()
        _gauges = ("peak_pages", "spec_accept_rate", "host_pages_used",
                   "retained_pages")
        self.stats = StatsView({
            k: (self.registry.gauge if k in _gauges
                else self.registry.counter)(f"serving_{k}", unit=u)
            for k, u in (("decode_steps", "ticks"), ("tokens_out", "tokens"),
                         ("prefills", "requests"), ("peak_pages", "pages"),
                         ("admit_blocked", "ticks"), ("resizes", ""),
                         ("prefix_hits", "requests"),
                         ("prefix_misses", "requests"),
                         ("cached_tokens", "tokens"), ("cow_forks", "pages"),
                         ("prefill_chunk_tokens", "tokens"),
                         ("migrations_in", "streams"),
                         ("migrations_out", "streams"),
                         ("prefill_compiles", "programs"),
                         ("prefill_dispatches", "dispatches"),
                         ("spec_ticks", "ticks"),
                         ("spec_drafted", "tokens"),
                         ("spec_accepted", "tokens"),
                         ("spec_accept_rate", ""),
                         ("swap_outs", "chains"),
                         ("swap_out_pages", "pages"),
                         ("swap_ins", "chains"),
                         ("swap_in_pages", "pages"),
                         ("swap_reprefills", "chains"),
                         ("host_evictions", "chains"),
                         ("quota_blocked", "requests"),
                         ("index_evictions", "entries"),
                         ("host_pages_used", "pages"),
                         ("retained_pages", "pages"))})
        self.index.on_evict = self._on_index_evict
        self.h_queue_wait = self.registry.histogram(
            "serving_queue_wait_ticks", TICK_BUCKETS, unit="ticks",
            help="ticks from due arrival to admission")
        self.h_ttft = self.registry.histogram(
            "serving_ttft_ticks", TICK_BUCKETS, unit="ticks",
            help="ticks from due arrival to first output token")
        self.h_latency = self.registry.histogram(
            "serving_latency_ticks", TICK_BUCKETS, unit="ticks",
            help="ticks from due arrival to finish")
        # integer unit-width bounds: emitted-per-verify is a small integer,
        # so quantile() is exact (boundary-valued data, cf. log_buckets)
        self.h_spec_accept = self.registry.histogram(
            "serving_spec_accept_tokens",
            tuple(float(b) for b in range(1, 34)), unit="tokens",
            help="tokens emitted per speculative verify (accepted + 1)")
        self.h_resume = self.registry.histogram(
            "serving_resume_ticks", TICK_BUCKETS, unit="ticks",
            help="ticks from due arrival to admission for streams resumed "
                 "via host-tier swap-in")

        # donate the cache: pools are sized to fill HBM, so the step must
        # update them in place rather than double-buffer (cf. trainer.py)
        self._decode_fn = jax.jit(
            functools.partial(self._decode_multi, cfg, self.shard),
            static_argnames=("k",), donate_argnums=(1,))
        # prefill-family programs live in the module-level _PROGRAM_CACHE,
        # shared across instances; this key captures what they close over
        self._shard_key = (None if self.shard is None
                           else (self.shard.tp, self.shard.axis,
                                 self.shard.mesh))
        self._cow_fn = jax.jit(functools.partial(PC.copy_page, tp=tp),
                               donate_argnums=(0,))
        self._rid = 0

    # ------------------------------------------------------------ jit fns --
    @staticmethod
    def _decode_multi(cfg, shard, params, cache, tokens, seq_lens,
                      block_table, *, k: int):
        """``k`` fused greedy decode ticks in one lax.scan (one dispatch).

        The host loop picks ``k`` so that no request finishes and no arrival
        becomes admissible mid-scan — fusion is a pure dispatch-overhead
        optimisation, token-for-token identical to k=1 stepping.
        Returns (tokens (k, B), new_cache).
        """
        def body(carry, _):
            toks, lens, cc = carry
            lg, cc = M.paged_decode_step(cfg, params, cc, toks, lens,
                                         block_table, shard=shard)
            nxt = jnp.argmax(lg[:, -1, :cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            return (nxt[:, None], lens + 1, cc), nxt

        (_, _, new_cache), outs = jax.lax.scan(
            body, (tokens, seq_lens, cache), None, length=k)
        return outs, new_cache

    def _get_program(self, kind: str, n: int, build):
        """Fetch (or build and share) the compiled program ``kind``@``n``.

        Misses count as ``prefill_compiles``; a second scheduler with the
        same (cfg, tp, page size, kernel flag) reuses the entry for free.
        """
        key = (kind, n, self.cfg, self.page_size, self._shard_key,
               self.prefill_kernel)
        fn = _PROGRAM_CACHE.get(key)
        if fn is None:
            fn = _PROGRAM_CACHE[key] = build()
            self.stats["prefill_compiles"] += 1
        return fn

    def _prefill_fn(self, n: int):
        """Batch-1 prefill at padded length ``n``; logits taken at the live
        prompt's last position (right padding is causally invisible)."""
        cfg = self.cfg

        def build():
            def fn(params, tokens, plen):
                positions = None
                if cfg.rope_variant == "mrope":
                    pos = jnp.broadcast_to(
                        jnp.arange(n, dtype=jnp.int32)[None], (1, n))
                    positions = jnp.broadcast_to(pos[None], (3, 1, n))
                hidden, _, pre = lm_forward(cfg, params, tokens,
                                            positions=positions,
                                            mode="prefill")
                h_last = jax.lax.dynamic_slice_in_dim(hidden, plen - 1, 1,
                                                      axis=1)
                lg = M.final_logits(cfg, params, h_last)
                tok = jnp.argmax(lg[0, -1, :cfg.vocab_size]).astype(jnp.int32)
                return tok, pre

            return jax.jit(fn)

        return self._get_program("prefill", n, build)

    def _insert_fn(self, n: int):
        cfg, ps, tp = self.cfg, self.page_size, self.tp

        def build():
            def fn(cache, pre, block_row, slot, plen):
                return PC.write_prefill(cfg, cache, pre, block_row, slot,
                                        plen, n, ps, tp=tp)

            return jax.jit(fn, donate_argnums=(0,))

        return self._get_program("insert", n, build)

    def _chunk_fn(self, n: int):
        """Fused chunk program at padded length ``n`` (dense archs).

        One dispatch lands ``s_live`` prompt tokens at position ``start``
        directly in the sequence's pages (``M.paged_prefill_step``: scatter
        or the Pallas write kernel, then prefix+chunk attention over the
        pages — no contiguous KV intermediate, no separate insert call) and
        reads the next-token logits at the chunk's last live row. Serves
        monolithic admission (start=0, s_live=plen), shared-prefix suffixes,
        and every chunked-prefill chunk — replacing the prefill+insert pair
        and the batched-rows suffix trick (whose ``n`` rows each gathered
        the full pool). ``self.prefill_kernel`` is baked in at trace time.
        """
        cfg, shard, kernel = self.cfg, self.shard, self.prefill_kernel

        def build():
            def fn(params, cache, tokens, start, s_live, row):
                with model_flags.use_prefill_kernel(kernel):
                    hidden, cache = M.paged_prefill_step(
                        cfg, params, cache, tokens[None], start[None],
                        s_live[None], row[None], shard=shard)
                h_last = jax.lax.dynamic_slice_in_dim(hidden[0], s_live - 1,
                                                      1, axis=0)
                lg = M.final_logits(cfg, params, h_last[None])
                tok = jnp.argmax(lg[0, -1, :cfg.vocab_size]).astype(jnp.int32)
                return tok, cache

            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program("chunk", n, build)

    def _suffix_fn(self, n: int):
        """Batched suffix prefill at padded length ``n`` (dense archs).

        The uncached suffix's tokens run as ``n`` parallel rows of one
        paged decode step: row ``i`` carries position ``start + i``, every
        row shares the sequence's block-table row, and all rows' K/V are
        scattered into the pages *before* attention — so row ``i`` attends
        the shared prefix pages plus suffix positions ``<= i``, which is
        exactly causal prefill continued from ``start``. Padding rows are
        routed to the sink page (position 0) and discarded; logits are read
        at the live suffix's last row.
        """
        cfg, shard = self.cfg, self.shard

        def build():
            def fn(params, cache, tokens, start, s_live, row):
                i = jnp.arange(n, dtype=jnp.int32)
                live = i < s_live
                lens = jnp.where(live, start + i, 0).astype(jnp.int32)
                bt = jnp.where(live[:, None], row[None, :],
                               PC.SINK_PAGE).astype(jnp.int32)
                lg, cache = M.paged_decode_step(cfg, params, cache,
                                                tokens[:, None], lens, bt,
                                                shard=shard)
                last = jax.lax.dynamic_slice_in_dim(lg[:, -1, :],
                                                    s_live - 1, 1, axis=0)
                tok = jnp.argmax(last[0, :cfg.vocab_size]).astype(jnp.int32)
                return tok, cache

            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program("suffix", n, build)

    def _seq_suffix_fn(self, s: int):
        """Sequential suffix continuation at exact length ``s`` (SSM and
        MoE archs). A lax.scan of batch-1 paged decode steps threads the
        SSM slot state token by token from the cached snapshot (``state``;
        None for pure-MoE archs, whose suffix still must step one token at
        a time so expert capacity groups match decode's) and writes each
        suffix token's K/V into the sequence's pages."""
        cfg, shard = self.cfg, self.shard

        def build():
            def fn(params, cache, state, tokens, start, row, slot):
                view = PC.ssm_slot_view(cache, state)
                bt = row[None, :].astype(jnp.int32)

                def body(carry, tok):
                    cl, vw = carry
                    lg, vw = M.paged_decode_step(cfg, params, vw,
                                                 tok[None, None],
                                                 cl[None], bt, shard=shard)
                    return (cl + 1, vw), lg[0, -1]

                (_, view), lgs = jax.lax.scan(
                    body, (jnp.asarray(start, jnp.int32), view), tokens)
                tok = jnp.argmax(lgs[-1, :cfg.vocab_size]).astype(jnp.int32)
                if state is None:
                    return tok, view
                return tok, PC.merge_ssm_slot(cache, view, slot)

            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program("seq_suffix", s, build)

    # -------------------------------------------------- speculative decode --
    def _verify_fn(self, n: int):
        """Grouped speculative verify, ``n = spec_k + 1`` rows per slot
        (dense archs). tokens (S, n): slot ``s``'s row 0 carries its last
        real token at position ``seq_lens[s]``, rows ``1..cap`` its draft
        tokens at the following positions; ``live`` (S,) is ``cap + 1``
        (0 masks a non-decoding slot onto the sink page). One fused
        paged-prefill dispatch (``M.paged_verify_step``) gathers each
        stream's pages once, lands all rows' K/V, and returns the per-row
        argmax — the target tokens the host's ``spec_accept`` compares
        drafts against. ``self.prefill_kernel`` is baked in at trace time,
        so verify rides the Pallas write+attend kernels exactly like
        chunked prefill.
        """
        cfg, shard, kernel = self.cfg, self.shard, self.prefill_kernel

        def build():
            def fn(params, cache, tokens, lens, bt, live):
                with model_flags.use_prefill_kernel(kernel):
                    lg, cache = M.paged_verify_step(cfg, params, cache,
                                                    tokens, lens, live, bt,
                                                    shard=shard)
                outs = jnp.argmax(lg[..., :cfg.vocab_size],
                                  axis=-1).astype(jnp.int32)
                return outs, cache

            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program("verify", n, build)

    def _verify_seq_fn(self, n: int):
        """Sequential speculative verify for SSM/hybrid archs: a lax.scan
        of ``n`` full-batch paged decode steps teacher-forced with the
        draft matrix, collecting per-step argmax *and* per-step SSM
        snapshots (``PC.ssm_leaves``). Acceptance is computed in-dispatch
        (cumprod of draft==argmax matches) and ``PC.select_ssm_steps``
        rolls every slot's SSM state back to its accepted step — the PR-6
        snapshot rule per verified token, so a partial reject leaves the
        recurrence exactly where spec-off decoding would have.
        """
        cfg, shard = self.cfg, self.shard

        def build():
            def fn(params, cache, tokens, lens0, bt, live):
                # tokens (S, n); lens0/live (S,); bt (S, n_pg)
                xs = jnp.moveaxis(tokens, 1, 0)[:, :, None]    # (n, S, 1)

                def body(carry, tok):
                    lens, cc = carry
                    lg, cc = M.paged_decode_step(cfg, params, cc, tok, lens,
                                                 bt, shard=shard)
                    out = jnp.argmax(lg[:, -1, :cfg.vocab_size],
                                     axis=-1).astype(jnp.int32)
                    return (lens + 1, cc), (out, PC.ssm_leaves(cc))

                (_, cache), (outs, states) = jax.lax.scan(
                    body, (lens0, cache), xs)
                # draft i (row i of the token matrix) is accepted iff it
                # equals the argmax of row i-1 and sits below the live count
                i = jnp.arange(1, n)[:, None]                  # (n-1, 1)
                match = ((outs[:-1] == jnp.moveaxis(tokens, 1, 0)[1:])
                         & (i < live[None, :]))
                j = jnp.cumprod(match.astype(jnp.int32), axis=0).sum(axis=0)
                cache = PC.select_ssm_steps(cache, states, j)
                return outs, j, cache

            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program("spec_seq", n, build)

    def _draft_prefill_fn(self, n: int):
        """Draft-cache catch-up program at padded length ``n``: land one
        stream's committed context (``s_live`` tokens) into its draft-pool
        pages through the stream's own block table, exactly like a target
        prompt chunk. Runs once per stream per residency — at its first
        speculative tick after admission or adoption — after which the
        per-tick advance keeps the cache current at O(spec_k).
        """
        dcfg = self.spec_draft[0]

        def build():
            def fn(dparams, dcache, tokens, s_live, row):
                _, dcache = M.paged_prefill_step(
                    dcfg, dparams, dcache, tokens[None],
                    jnp.zeros((1,), jnp.int32), s_live[None], row[None])
                return dcache

            # key on the draft cfg too: _get_program's key carries the
            # target cfg, and two schedulers may pair different drafts
            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program(("spec_dpre", dcfg), n, build)

    def _draft_advance_fn(self):
        """Batched draft advance-and-propose program, all slots in one
        dispatch. ``spec_k + 1`` scanned paged decode steps on the draft
        cache: step 0 teacher-forces each live stream's last committed
        token at position ``seq_lens`` (the same input verify row 0 gets),
        steps 1..k feed the previous argmax — the first k outputs are the
        draft tokens. Every step's K/V lands in the stream's pages, so an
        accepted draft's K/V is already correct at its position and the
        next tick teacher-forces only the correction token; a rejected
        tail is masked by ``seq_lens`` and overwritten in place, the same
        rollback the target cache uses. The step-k input (draft k-1)
        writes position ``seq_lens + k`` so a full accept leaves no hole.
        Dead rows route to the sink page.
        """
        dcfg, k = self.spec_draft[0], self.spec_k

        def build():
            def fn(dparams, dcache, last, lens, live, bt):
                btm = jnp.where(live[:, None], bt,
                                PC.SINK_PAGE).astype(jnp.int32)

                def body(carry, i):
                    tok, dc = carry
                    pos = jnp.where(live, lens + i, 0).astype(jnp.int32)
                    lg, dc = M.paged_decode_step(dcfg, dparams, dc,
                                                 tok[:, None], pos, btm)
                    nxt = jnp.argmax(lg[:, -1, :dcfg.vocab_size],
                                     axis=-1).astype(jnp.int32)
                    return (nxt, dc), nxt

                (_, dcache), ds = jax.lax.scan(
                    body, (last, dcache),
                    jnp.arange(k + 1, dtype=jnp.int32))
                return ds[:k].T, dcache          # (S, k) draft tokens

            return jax.jit(fn, donate_argnums=(1,))

        return self._get_program(("spec_adv", dcfg), k, build)

    def _model_drafts(self, decoding: List[int],
                      caps: Dict[int, int]) -> Dict[int, np.ndarray]:
        """Draft-model proposals for every eligible decoding slot, two
        dispatches worst case: catch-up prefills for newly resident
        streams, then one batched advance. Slots whose cap is below
        ``spec_k`` are excluded (their draft K/V would overrun the pages
        grown for ``cap``) and fall back to n-gram drafting.
        """
        dcfg, dparams = self.spec_draft
        k, S = self.spec_k, self.max_slots
        elig = [s for s in decoding if caps[s] == k]
        if not elig:
            return {}
        for slot in elig:
            if self._draft_ready[slot]:
                continue
            req = self.slot_req[slot]
            L = int(self.seq_lens[slot])
            ctx = np.concatenate([req.prompt,
                                  np.asarray(req.out_tokens, np.int32)])[:L]
            b = next((x for x in self.buckets if x >= L),
                     -(-L // self.page_size) * self.page_size)
            toks = np.zeros((b,), np.int32)
            toks[:L] = ctx
            self._draft_cache = self._timed(
                "spec_draft", self._draft_prefill_fn(b), dparams,
                self._draft_cache, jnp.asarray(toks),
                jnp.asarray(L, jnp.int32),
                jnp.asarray(self.block_table[slot]), tokens=L, ctx_tokens=L)
            self._draft_ready[slot] = True
        live = np.zeros((S,), bool)
        live[elig] = True
        ds, self._draft_cache = self._timed(
            "spec_draft", self._draft_advance_fn(), dparams,
            self._draft_cache, jnp.asarray(self.last_tokens[:, 0]),
            jnp.asarray(self.seq_lens), jnp.asarray(live),
            jnp.asarray(self.block_table),
            tokens=(k + 1) * len(elig),
            ctx_tokens=int(np.sum(self.seq_lens[elig])))
        ds = np.asarray(ds)
        return {slot: ds[slot].astype(np.int32) for slot in elig}

    # -------------------------------------------------------- draft sources --
    def _draft(self, req: Request, cap: int) -> np.ndarray:
        """Up to ``cap`` n-gram draft tokens for a decoding stream
        (host-side) — the default speculator, and the fallback for slots
        the draft model skips. A deterministic function of the stream's
        context, so a fleet re-route re-drafts identically.
        """
        if cap <= 0:
            return _EMPTY_DRAFT
        return self._ngram_draft(req, cap)

    def _ngram_draft(self, req: Request, cap: int) -> np.ndarray:
        """Prompt-lookup drafting: find the most recent earlier occurrence
        of the context's final m-gram (m = 3, 2, 1) and propose the tokens
        that followed it. Free (no model call) and strong exactly where
        speculation pays: continuations that repeat prompt or generated
        material."""
        ctx = np.concatenate([req.prompt,
                              np.asarray(req.out_tokens, np.int32)])
        T = int(ctx.shape[0])
        for m in (3, 2, 1):
            if T < m + 1:
                continue
            pat = ctx[T - m:]
            win = np.lib.stride_tricks.sliding_window_view(ctx, m)
            hits = np.flatnonzero((win == pat).all(axis=1))
            hits = hits[hits < T - m]
            if hits.size:
                p = int(hits[-1])
                d = ctx[p + m:p + m + cap]
                if d.size:
                    return d.astype(np.int32)
        return _EMPTY_DRAFT

    # ------------------------------------------------------- observability --
    def set_tracer(self, tracer, *, own_clock: bool = True) -> None:
        """Attach a lifecycle tracer (``repro.obs.trace.Tracer``).

        ``own_clock=False`` means somebody else — the fabric router —
        drives ``tracer.t`` on the fleet clock, so hooks stamp that;
        otherwise they stamp this scheduler's own ``step_idx``.
        """
        self.tracer = tracer
        self._trace_own_clock = own_clock

    def _tnow(self) -> float:
        return (float(self.step_idx) if self._trace_own_clock
                else self.tracer.t)

    def enable_profiling(self, profiler=None):
        """Opt-in kernel dispatch timing (``repro.obs.profile``): every
        prefill/suffix/decode dispatch is wall-timed after
        ``block_until_ready`` with its token/context detail. Read-only —
        profiled runs emit byte-identical tokens."""
        if profiler is None:
            from repro.obs.profile import KernelProfiler
            profiler = KernelProfiler(self.cfg, tp=self.tp)
        self.profiler = profiler
        return profiler

    def _timed(self, kind: str, fn, *args, tokens: int = 0,
               ctx_tokens: int = 0, **kw):
        if self.profiler is None:
            return fn(*args, **kw)
        return self.profiler.timed(kind, fn, *args, tokens=tokens,
                                   ctx_tokens=ctx_tokens, **kw)

    # ---------------------------------------------------------- submission --
    def submit(self, prompt, max_new_tokens: int,
               arrival_step: int = 0, priority: int = 1,
               tenant: str = "default") -> Request:
        req = make_request(self._rid, prompt, max_new_tokens, arrival_step,
                           priority=priority, tenant=tenant)
        self._rid += 1
        return self.submit_request(req)

    def submit_request(self, req: Request) -> Request:
        """Enqueue a pre-built request (the fabric router's entry point: the
        router owns rid assignment, so the same object travels through
        whichever replica scheduler ends up decoding it)."""
        total = req.plen + req.max_new_tokens
        if total > self.max_seq_len:
            raise ValueError(f"request needs {total} positions > "
                             f"max_seq_len {self.max_seq_len}")
        # a prefill-role scheduler only ever holds the prompt (+1 for the
        # first output's logits); generation pages are the adopter's burden
        worst = PC.pages_for_len(
            req.plen + 1 if self.role == "prefill" else total,
            self.page_size)
        cap = self.alloc.capacity
        if self.capacity_hint is not None:
            cap = max(cap, self.capacity_hint - 1)
        if worst > cap:
            raise ValueError(
                f"request reserves {worst} pages but the pool only holds "
                f"{cap} — it could never be admitted")
        self.waiting.append(req)
        if self.tracer is not None:
            # no-op when the fabric router already opened this span at its
            # own submit (first opener wins — fleet clock beats replica's)
            self.tracer.begin("queued", req.rid, t=req.arrival_step,
                              replica=self.replica_id)
        return req

    # ----------------------------------------------------------- admission --
    def _free_slots(self) -> List[int]:
        # slots at or above target_slots are draining (pending shrink)
        return [i for i, r in enumerate(self.slot_req[:self.target_slots])
                if r is None]

    def _try_admit(self) -> None:
        progress = True
        while progress:
            progress = False
            # the due window keeps the original FCFS head gate: requests
            # queued behind a not-yet-due one wait, so priority classes
            # reorder only *simultaneously due* requests (all-equal
            # priorities reduce exactly to the old head-of-line behavior)
            due: List[Request] = []
            for r in self.waiting:
                if r.arrival_step > self.step_idx:
                    break
                due.append(r)
            if not due:
                return
            due.sort(key=lambda r: -r.priority)    # stable: FCFS in class
            for req in due:
                free = self._free_slots()   # _admit may have finished slots
                if not free:
                    self.stats["admit_blocked"] += 1
                    return
                hit = self._prefix_lookup(req)
                reserve, demand = self._admission_demand(req, hit)
                if self._quota_blocked(req, reserve):
                    continue                # other tenants may still fit
                headroom = (self.alloc.num_free
                            - (self.reserved_pages - self.pages_in_use))
                if demand > headroom:
                    # HBM pressure: preempt cold retained chains to the
                    # host tier instead of blocking (protect the hit's own
                    # chain from being evicted out from under us)
                    if not self._reclaim(demand - headroom,
                                         protect=self._hit_pages(hit)):
                        self.stats["admit_blocked"] += 1
                        return              # head of the class blocks
                    # eviction may have remapped or invalidated entries
                    hit = self._prefix_lookup(req)
                    reserve, demand = self._admission_demand(req, hit)
                    if demand > (self.alloc.num_free
                                 - (self.reserved_pages
                                    - self.pages_in_use)):
                        self.stats["admit_blocked"] += 1
                        return
                mat = self._materialize_hit(req, hit)
                if mat is None and hit is not None:
                    # defensive miss: the hit chain vanished; recheck the
                    # full (undiscounted) reservation before admitting
                    reserve, demand = self._admission_demand(req, None)
                    if demand > (self.alloc.num_free
                                 - (self.reserved_pages
                                    - self.pages_in_use)):
                        self.stats["admit_blocked"] += 1
                        return
                hit = mat
                self.waiting.remove(req)
                if self.tenant_quotas is not None:
                    self._tenant_reserved[req.tenant] = (
                        self._tenant_reserved.get(req.tenant, 0) + reserve)
                if self.prefill_budget is not None:
                    self._admit_chunked(req, free[0], reserve, hit)
                else:
                    self._admit(req, free[0], reserve, hit)
                progress = True
                break                       # re-scan with fresh due window

    def _admission_demand(self, req: Request, hit):
        """``(reserve, demand)`` pages for admitting ``req`` against ``hit``.

        ``reserve`` is the worst-case reservation charged to the slot: the
        uncached suffix only — shared full pages are already allocated and
        survive via their refcount, so they are never allocated again. A
        prefill-role scheduler reserves prompt pages only; generation pages
        are reserved by whichever decode scheduler adopts the stream.

        ``demand`` is what the admission ledger must cover *now*: the
        reservation plus one fresh device page per host-resident hit page
        (full or tail), since materializing the hit allocates those
        immediately.
        """
        reserve = PC.pages_for_len(
            req.plen + 1 if self.role == "prefill"
            else req.plen + req.max_new_tokens, self.page_size)
        demand = reserve
        if hit is not None:
            reserve -= len(hit.full_pages)
            n_host = sum(1 for p in hit.full_pages if PC.is_host_page(p))
            if hit.tail_len and PC.is_host_page(hit.tail_page):
                n_host += 1
            demand = reserve + n_host
        return reserve, demand

    def _quota_blocked(self, req: Request, reserve: int) -> bool:
        if self.tenant_quotas is None:
            return False
        quota = self.tenant_quotas.get(req.tenant)
        if quota is None:
            return False
        if self._tenant_reserved.get(req.tenant, 0) + reserve <= quota:
            return False
        self.stats["quota_blocked"] += 1
        return True

    @staticmethod
    def _hit_pages(hit) -> List[int]:
        if hit is None:
            return []
        pages = list(hit.full_pages)
        if hit.tail_len:
            pages.append(hit.tail_page)
        return pages

    # ----------------------------------------------------- host page tier --
    def _on_index_evict(self, entry) -> None:
        self.stats["index_evictions"] += 1

    @property
    def retained_page_count(self) -> int:
        """Device pages held by retained (cold) chains, with multiplicity."""
        return sum(len(c.pages) for c in self._retained.values())

    @property
    def hot_pages(self) -> int:
        """Physical pages referenced by live slots — the hot working set
        the autoscaler should size HBM to (cold retained pages are
        reclaimable at a swap or a re-prefill, not a capacity need)."""
        live = set()
        for pages in self.slot_pages:
            live.update(pages)
        live.discard(PC.SINK_PAGE)
        return len(live)

    def _gauge_tiers(self) -> None:
        self.stats["retained_pages"] = self.retained_page_count
        if self.host_tier is not None:
            self.stats["host_pages_used"] = self.host_tier.pages_used

    def _retain_pages(self, pages: List[int], *, tokens: int, priority: int,
                      tenant: str) -> int:
        """Register a device-resident chain with the retention ledger.

        The record inherits the allocator refs its previous owner held —
        the caller must *not* free ``pages`` — so the chain stays alive and
        prefix-shareable until ``_reclaim`` preempts it."""
        key = self._retain_seq
        self._retain_seq += 1
        self._retained[key] = _RetainedChain(list(pages), int(tokens),
                                             int(priority), tenant,
                                             self.step_idx)
        self._gauge_tiers()
        return key

    def _retain_finished(self, slot: int, req: Request) -> None:
        """Keep a finished stream's chain warm instead of freeing it.

        The chain covers the prompt plus all but the last output token —
        the final token was emitted but its K/V never written — so any
        session-style follow-up prompt (previous context + new user turn)
        prefix-hits it. Pages past the chain (speculative growth headroom)
        are freed; hybrid archs snapshot the slot's SSM state into the
        index so the resume point is exact."""
        L = req.plen + max(len(req.out_tokens) - 1, 0)
        keep = PC.pages_for_len(L, self.page_size)
        pages = self.slot_pages[slot]
        extra = pages[keep:]
        kept = pages[:keep]
        if extra:
            self.alloc.free(extra)
        chain = np.concatenate(
            [req.prompt, np.asarray(req.out_tokens[:-1], np.int32)])
        state = PC.extract_ssm_slot(self.cache, slot) if self._has_ssm \
            else None
        self.index.insert(chain, kept, state=state)
        self._retain_pages(kept, tokens=L, priority=req.priority,
                           tenant=req.tenant)

    def _reclaim(self, short: int, protect: Sequence[int] = ()) -> bool:
        """Free >= ``short`` device pages by preempting retained chains,
        lowest priority class first, coldest first within a class. Each
        chain's private pages either move to the host tier or are dropped
        for re-prefill — ``swap_resume_cost`` decides. Returns whether the
        shortfall was covered."""
        if short <= 0:
            return True
        if not self._retained:
            return False
        prot = set(protect)
        order = sorted(self._retained,
                       key=lambda k: (self._retained[k].priority,
                                      self._retained[k].step))
        freed = 0
        for key in order:
            if freed >= short:
                break
            if prot and not prot.isdisjoint(self._retained[key].pages):
                continue                    # the admission's own hit chain
            freed += self._evict_chain(key)
        self._gauge_tiers()
        return freed >= short

    def _evict_chain(self, key: int) -> int:
        """Preempt one retained chain; returns device pages freed.

        Only *dying* pages (refcount 1, held solely by retention) carry
        bytes to host — pages shared with live slots survive on device,
        and the index entries that straddle the freed/survived boundary
        are invalidated through the allocator's on_free hook. ``swap_chain``
        runs before ``free`` so wholly-covered entries move buckets first
        and never observe a half-swapped chain."""
        ch = self._retained.pop(key)
        dying = [p for p in ch.pages if self.alloc.ref(p) == 1]
        if not dying:                       # fully shared: nothing to move
            self.alloc.free(ch.pages)
            return 0
        store = False
        if (self.host_tier is not None and self._swap_crossover is not None
                and ch.tokens >= self._swap_crossover):
            if not self.host_tier.can_hold(len(dying)):
                self._host_reclaim(len(dying))
            store = self.host_tier.can_hold(len(dying))
        if store:
            host = PC.swap_out_pages(self.cache, self.host_tier, dying,
                                     tp=self.tp, owner=key)
            mapping = {p: PC.as_host_page(h) for p, h in zip(dying, host)}
            self.index.swap_chain(mapping)
            self._host_chains[key] = _RetainedChain(
                list(host), ch.tokens, ch.priority, ch.tenant, self.step_idx)
            for h in host:
                self._host_page_chain[h] = key
            self.stats["swap_outs"] += 1
            self.stats["swap_out_pages"] += len(dying)
            if self.tracer is not None:
                self.tracer.instant(
                    "swap_out", t=self._tnow(), replica=self.replica_id,
                    pages=len(dying), chain_tokens=ch.tokens,
                    bytes=PC.migration_bytes(self.cfg, len(dying),
                                             self.page_size))
        else:
            # cost model (or a full host tier) says drop: a resume will
            # re-prefill this chain from tokens instead of moving bytes
            self.stats["swap_reprefills"] += 1
            if self.tracer is not None:
                self.tracer.instant(
                    "swap_out", t=self._tnow(), replica=self.replica_id,
                    pages=0, chain_tokens=ch.tokens, decision="reprefill")
        self.alloc.free(ch.pages)           # dying pages die; shared survive
        self._gauge_tiers()
        return len(dying)

    def _host_reclaim(self, n: int) -> None:
        """Drop the coldest host chains until ``n`` pages fit."""
        order = sorted(self._host_chains,
                       key=lambda k: (self._host_chains[k].priority,
                                      self._host_chains[k].step))
        for key in order:
            if self.host_tier.can_hold(n):
                return
            self._drop_host_chain(key)

    def _drop_host_chain(self, key: int) -> None:
        ch = self._host_chains.pop(key)
        for h in ch.pages:
            self._host_page_chain.pop(h, None)
        self.host_tier.free(ch.pages)       # on_free invalidates entries
        self.stats["host_evictions"] += 1
        self._gauge_tiers()

    def _materialize_hit(self, req: Request, hit):
        """Swap a host-resident hit chain back into device pages.

        The *whole* owning chain is restored (not just the matched prefix)
        so no host page is left orphaned when its index entries remap; the
        restored pages re-enter the device tier as a fresh retained record
        holding refcount 1, and the admission below shares them exactly
        like any device-resident hit — refcount-clean, still preemptible.
        """
        if hit is None or self.host_tier is None:
            return hit
        tagged = [p for p in self._hit_pages(hit) if PC.is_host_page(p)]
        if not tagged:
            return hit
        # the hit may touch one chain (full pages) plus possibly a second
        # (tail page); restore every chain involved
        keys = set()
        for p in tagged:
            key = self._host_page_chain.get(PC.host_page_id(p))
            if key is None:     # orphaned entry (should not happen): miss
                return None
            keys.add(key)
        mapping: Dict[int, int] = {}
        for key in sorted(keys):
            ch = self._host_chains.pop(key)
            for h in ch.pages:
                self._host_page_chain.pop(h, None)
            dst = self.alloc.alloc(len(ch.pages), owner=("swapin", req.rid))
            m = {PC.as_host_page(h): d for h, d in zip(ch.pages, dst)}
            # remap entries to device ids *before* the swap-in frees the
            # host pages — the on_free invalidation then finds nothing
            # under the tagged ids and the chain is never half-swapped
            self.index.swap_chain(m)
            self.cache = PC.swap_in_pages(self.cache, self.host_tier,
                                          ch.pages, dst, tp=self.tp)
            mapping.update(m)
            self._retain_pages(dst, tokens=ch.tokens, priority=ch.priority,
                               tenant=ch.tenant)
            self.stats["swap_ins"] += 1
            self.stats["swap_in_pages"] += len(dst)
            if self.tracer is not None:
                self.tracer.instant(
                    "swap_in", rid=req.rid, t=self._tnow(),
                    replica=self.replica_id, pages=len(dst),
                    chain_tokens=ch.tokens,
                    bytes=PC.migration_bytes(self.cfg, len(dst),
                                             self.page_size))
        hit.full_pages = [mapping.get(p, p) for p in hit.full_pages]
        if hit.tail_len:
            hit.tail_page = mapping.get(hit.tail_page, hit.tail_page)
        req.swap_ins += 1
        self.h_resume.observe(self.step_idx - req.arrival_step)
        self._gauge_tiers()
        return hit

    def drop_tier_state(self) -> None:
        """Forget both tiers' cold state (replica failure: the node's HBM
        and host RAM die together). Retained device chains release their
        refs, host rows are dropped, per-tenant ledgers reset."""
        for key in list(self._retained):
            ch = self._retained.pop(key)
            self.alloc.free(ch.pages)
        self._host_chains.clear()
        self._host_page_chain.clear()
        if self.host_tier is not None:
            self.host_tier.clear()
        self._tenant_reserved.clear()
        self._gauge_tiers()

    def _prefix_lookup(self, req: Request):
        if not self.prefix_cache:
            return None
        return self.index.lookup(req.prompt, limit=req.plen - 1,
                                 need_state=self._has_ssm)

    def prefix_match_len(self, prompt) -> int:
        """Tokens of ``prompt`` this scheduler's prefix cache could serve —
        the router's prefix-affinity signal (read-only)."""
        if not self.prefix_cache:
            return 0
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        return self.index.match_len(prompt, limit=prompt.shape[0] - 1,
                                    need_state=self._has_ssm)

    @property
    def pages_in_use(self) -> int:
        """Pages charged privately to live slots (net of shared prefix
        pages) — the in-use term of the admission ledger. Physical
        occupancy, which sharing makes smaller, is ``pages_allocated``."""
        return sum(len(p) for p in self.slot_pages) - sum(self.slot_shared)

    @property
    def pages_allocated(self) -> int:
        """Physical pages held (each shared page counted once)."""
        return self.alloc.num_allocated

    def shard_stats(self) -> Dict[str, Any]:
        """Per-shard page-pool occupancy for a ``tp``-way group.

        One allocator ledger governs every shard's storage plane, so the
        per-shard numbers are equal by construction — that lockstep (no
        shard can run out of pages before its peers) is the design point
        the sharded rule set in tests/test_allocator_props.py checks.
        """
        one = {
            "pages_allocated": self.alloc.num_allocated,
            "pages_free": self.alloc.num_free,
            "peak_pages": self.stats["peak_pages"],
            "pool_bytes": PC.pool_bytes(self.cfg, self.alloc.num_pages,
                                        self.page_size, self.tp),
        }
        cap = max(self.alloc.capacity, 1)
        one["peak_utilization"] = round(self.stats["peak_pages"] / cap, 3)
        return {"tp": self.tp, "per_shard": [dict(one)
                                             for _ in range(self.tp)]}

    def _bucket(self, plen: int) -> int:
        if self.exact_prefill:
            return plen
        for b in self.buckets:
            if plen <= b:
                return b
        return self.max_seq_len

    def _admit(self, req: Request, slot: int, reserve: int,
               hit=None) -> None:
        plen = req.plen
        if hit is None:
            first, pages, shared, row = self._admit_full(req, slot)
        else:
            first, pages, shared, row = self._admit_shared(req, slot, hit)
        self.reserved_pages += reserve
        self.block_table[slot] = row
        self.seq_lens[slot] = plen
        self.last_tokens[slot, 0] = first
        self.slot_req[slot] = req
        self.slot_pages[slot] = pages
        self.slot_reserve[slot] = reserve
        self.slot_shared[slot] = shared
        if self.spec_draft is not None:
            self._draft_ready[slot] = False
        req.admit_step = self.step_idx
        req.out_tokens.append(first)
        self.stats["prefills"] += 1
        self.stats["tokens_out"] += 1
        self.h_queue_wait.observe(req.admit_step - req.arrival_step)
        self.h_ttft.observe(self.step_idx - req.arrival_step)
        tr = self.tracer
        if tr is not None:
            now = self._tnow()
            tr.end("queued", req.rid, t=now)
            tr.instant("admitted", rid=req.rid, t=now,
                       replica=self.replica_id, slot=slot, pages=len(pages),
                       shared_pages=shared, cached_tokens=req.cached_tokens,
                       prefix_hit=hit is not None)
            tr.span("prefill", req.rid, now, now + 1,
                    replica=self.replica_id, tokens=plen,
                    cached_tokens=req.cached_tokens, pages=len(pages),
                    shared_pages=shared)
        if req.done:                        # max_new_tokens == 1
            self._finish(slot)
            self._admit_done.append(req)
        elif self.role == "prefill":
            self.slot_parked[slot] = True   # awaiting page handoff
            if tr is not None:
                tr.begin("parked", req.rid, t=now, replica=self.replica_id)
        elif tr is not None:
            tr.begin("decode", req.rid, t=now, replica=self.replica_id)

    def _admit_full(self, req: Request, slot: int):
        """Prefix-cache miss (or caching off): full bucketed prefill.

        Fused: pages are allocated *first* and the whole prompt lands in
        them through one ``_chunk_fn`` dispatch (start=0). Legacy: dense
        prefill to a contiguous cache, then the ``write_prefill`` copy.
        """
        plen = req.plen
        n = self._bucket(plen)
        pages = self.alloc.alloc(PC.pages_for_len(plen + 1, self.page_size),
                                 owner=req.rid)
        row = np.full((self.n_pg,), PC.SINK_PAGE, np.int32)
        row[:len(pages)] = pages
        if self.prefill_fused:
            toks = np.zeros((n,), np.int32)
            toks[:plen] = req.prompt
            self.stats["prefill_dispatches"] += 1
            first, self.cache = self._timed(
                "prefill", self._chunk_fn(n), self.params, self.cache,
                jnp.asarray(toks), jnp.asarray(0, jnp.int32),
                jnp.asarray(plen, jnp.int32), jnp.asarray(row), tokens=plen)
            state = None
        else:
            tokens = np.zeros((1, n), np.int32)
            tokens[0, :plen] = req.prompt
            self.stats["prefill_dispatches"] += 2    # prefill + insert
            first, pre = self._timed("prefill", self._prefill_fn(n),
                                     self.params, jnp.asarray(tokens),
                                     jnp.asarray(plen, jnp.int32),
                                     tokens=plen)
            self.cache = self._insert_fn(n)(self.cache, pre,
                                            jnp.asarray(row),
                                            jnp.asarray(slot, jnp.int32),
                                            jnp.asarray(plen, jnp.int32))
            state = PC.extract_ssm_state(pre) if self._has_ssm else None
        if self.prefix_cache:
            self.index.insert(req.prompt, pages, state=state)
            self.stats["prefix_misses"] += 1
        return int(first), pages, 0, row

    def _admit_shared(self, req: Request, slot: int, hit):
        """Prefix-cache hit: share the matched full pages, COW-fork the
        partially-matched page, and prefill only the uncached suffix."""
        plen, L = req.plen, hit.length
        shared = list(hit.full_pages)
        self.alloc.share(shared)
        own = self.alloc.alloc(
            PC.pages_for_len(plen + 1, self.page_size) - len(shared),
            owner=req.rid)
        if hit.tail_len:
            # the sequence diverges (or continues) inside the matched page:
            # fork a private copy before writing its own tokens there
            self.cache = self._cow_fn(self.cache, hit.tail_page, own[0])
            self.stats["cow_forks"] += 1
        pages = shared + own
        row = np.full((self.n_pg,), PC.SINK_PAGE, np.int32)
        row[:len(pages)] = pages
        suffix = np.asarray(req.prompt[L:], np.int32)
        s = suffix.shape[0]
        self.stats["prefill_dispatches"] += 1
        if self.exact_prefill:
            first, self.cache = self._timed(
                "prefill_seq", self._seq_suffix_fn(s),
                self.params, self.cache, hit.state, jnp.asarray(suffix),
                jnp.asarray(L, jnp.int32), jnp.asarray(row),
                jnp.asarray(slot, jnp.int32), tokens=s, ctx_tokens=L)
        else:
            n = self._bucket(s)
            toks = np.zeros((n,), np.int32)
            toks[:s] = suffix
            fn = (self._chunk_fn(n) if self.prefill_fused
                  else self._suffix_fn(n))
            first, self.cache = self._timed(
                "prefill_suffix", fn,
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(L, jnp.int32), jnp.asarray(s, jnp.int32),
                jnp.asarray(row), tokens=s, ctx_tokens=L)
        if not self._has_ssm:
            # extend the index with this prompt's own (longer) chain; hybrid
            # entries need a state snapshot, which only full prefills have
            self.index.insert(req.prompt, pages)
        req.cached_tokens = L
        self.stats["prefix_hits"] += 1
        self.stats["cached_tokens"] += L
        return int(first), pages, len(shared), row

    # ------------------------------------------------------ chunked prefill --
    def _admit_chunked(self, req: Request, slot: int, reserve: int,
                       hit=None) -> None:
        """Allocate the prompt's pages and enter PREFILLING — no model call.

        The prompt lands chunk by chunk in ``_advance_prefills``; until the
        last chunk the slot is masked out of decode (seq_lens 0, sink block
        row), indistinguishable from an empty slot. A prefix hit shares /
        COW-forks pages exactly like monolithic admission, and the chunk
        cursor starts at the hit length.
        """
        plen = req.plen
        n_own = PC.pages_for_len(plen + 1, self.page_size)
        if hit is None:
            pages = self.alloc.alloc(n_own, owner=req.rid)
            shared = 0
            start = 0
            self.slot_resume_state[slot] = None
            if self.prefix_cache:
                self.stats["prefix_misses"] += 1
        else:
            shared_pages = list(hit.full_pages)
            self.alloc.share(shared_pages)
            own = self.alloc.alloc(n_own - len(shared_pages), owner=req.rid)
            if hit.tail_len:
                self.cache = self._cow_fn(self.cache, hit.tail_page, own[0])
                self.stats["cow_forks"] += 1
            pages = shared_pages + own
            shared = len(shared_pages)
            start = hit.length
            self.slot_resume_state[slot] = hit.state
            req.cached_tokens = start
            self.stats["prefix_hits"] += 1
            self.stats["cached_tokens"] += start
        row = np.full((self.n_pg,), PC.SINK_PAGE, np.int32)
        row[:len(pages)] = pages
        self.reserved_pages += reserve
        self.block_table[slot] = row
        self.seq_lens[slot] = 0             # masked until prefill completes
        self.last_tokens[slot, 0] = 0
        self.slot_req[slot] = req
        self.slot_pages[slot] = pages
        self.slot_reserve[slot] = reserve
        self.slot_shared[slot] = shared
        if self.spec_draft is not None:
            self._draft_ready[slot] = False
        req.admit_step = self.step_idx
        req.prefill_pos = start
        self._prefill_fifo.append(slot)
        self.h_queue_wait.observe(req.admit_step - req.arrival_step)
        tr = self.tracer
        if tr is not None:
            now = self._tnow()
            tr.end("queued", req.rid, t=now)
            tr.instant("admitted", rid=req.rid, t=now,
                       replica=self.replica_id, slot=slot, chunked=True,
                       pages=len(pages), shared_pages=shared,
                       cached_tokens=start, prefix_hit=hit is not None)

    def _advance_prefills(self) -> None:
        """Spend this tick's chunk budget FCFS over in-flight prefills.

        The fifo head (oldest admitted prefill) is funded first, so it
        always advances by at least one token — no admitted prefill can
        starve — and total chunk tokens per tick never exceed the budget.
        """
        budget = self.prefill_budget
        for slot in list(self._prefill_fifo):
            if budget <= 0:
                break
            req = self.slot_req[slot]
            pos = req.prefill_pos
            c = min(budget, req.plen - pos)
            budget -= c
            self._prefill_chunk(slot, req, pos, c)
            self.stats["prefill_chunk_tokens"] += c

    def _prefill_chunk(self, slot: int, req: Request, pos: int,
                       c: int) -> None:
        """Land ``c`` prompt tokens at cursor ``pos`` into the slot's pages.

        ``pos == 0`` runs a bucketed batch-1 prefill of the first chunk
        (which also writes the SSM slot state at ``c``); later chunks are
        suffix continuations — the dense batched-rows path or, for
        hybrid/MoE archs, the sequential scan resumed from the slot's live
        SSM state (or the prefix hit's snapshot for the first post-hit
        chunk). The last chunk's logits yield the first output token,
        exactly where monolithic prefill reads them.
        """
        row = self.block_table[slot]
        chunk = np.asarray(req.prompt[pos:pos + c], np.int32)
        if self.prefill_fused:
            n = self._bucket(c)
            toks = np.zeros((n,), np.int32)
            toks[:c] = chunk
            self.stats["prefill_dispatches"] += 1
            # first chunks keep the "prefill" profiler/metrics kind the
            # monolithic path established; continuations are suffixes
            tok, self.cache = self._timed(
                "prefill" if pos == 0 else "prefill_suffix",
                self._chunk_fn(n),
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32), jnp.asarray(c, jnp.int32),
                jnp.asarray(row), tokens=c, ctx_tokens=pos)
        elif pos == 0:
            n = self._bucket(c)
            tokens = np.zeros((1, n), np.int32)
            tokens[0, :c] = chunk
            self.stats["prefill_dispatches"] += 2    # prefill + insert
            tok, pre = self._timed("prefill", self._prefill_fn(n),
                                   self.params, jnp.asarray(tokens),
                                   jnp.asarray(c, jnp.int32), tokens=c)
            self.cache = self._insert_fn(n)(self.cache, pre,
                                            jnp.asarray(row),
                                            jnp.asarray(slot, jnp.int32),
                                            jnp.asarray(c, jnp.int32))
        elif self.exact_prefill:
            state = self.slot_resume_state[slot]
            if state is None and self._has_ssm:
                state = PC.extract_ssm_slot(self.cache, slot)
            self.stats["prefill_dispatches"] += 1
            tok, self.cache = self._timed(
                "prefill_seq", self._seq_suffix_fn(c),
                self.params, self.cache, state, jnp.asarray(chunk),
                jnp.asarray(pos, jnp.int32), jnp.asarray(row),
                jnp.asarray(slot, jnp.int32), tokens=c, ctx_tokens=pos)
        else:
            n = self._bucket(c)
            toks = np.zeros((n,), np.int32)
            toks[:c] = chunk
            self.stats["prefill_dispatches"] += 1
            tok, self.cache = self._timed(
                "prefill_suffix", self._suffix_fn(n),
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32), jnp.asarray(c, jnp.int32),
                jnp.asarray(row), tokens=c, ctx_tokens=pos)
        tr = self.tracer
        if tr is not None:
            now = self._tnow()
            tr.span("prefill_chunk", req.rid, now, now + 1,
                    replica=self.replica_id,
                    chunk=tr.next_index(req.rid, "prefill_chunk"),
                    pos=pos, tokens=c)
        if pos + c < req.plen:
            req.prefill_pos = pos + c
            if self._has_ssm:
                # the live slot state is NOT safe to resume from: decode
                # ticks for other slots step every slot's SSM recurrence —
                # including this masked one (KV writes land on the sink
                # page, but SSM state lives per slot, not per page). Carry
                # the authoritative post-chunk state host-side and resume
                # the next chunk from the snapshot.
                self.slot_resume_state[slot] = PC.extract_ssm_slot(
                    self.cache, slot)
            return
        # ---- last chunk: the request leaves PREFILLING this tick --------
        self._prefill_fifo.remove(slot)
        self.slot_resume_state[slot] = None
        req.prefill_pos = None
        self.seq_lens[slot] = req.plen
        first = int(tok)
        self.last_tokens[slot, 0] = first
        req.out_tokens.append(first)
        self.stats["prefills"] += 1
        self.stats["tokens_out"] += 1
        self.h_ttft.observe(self.step_idx - req.arrival_step)
        if self.prefix_cache:
            state = (PC.extract_ssm_slot(self.cache, slot)
                     if self._has_ssm else None)
            self.index.insert(req.prompt, self.slot_pages[slot], state=state)
        if req.done:                        # max_new_tokens == 1
            self._finish(slot)
            self._admit_done.append(req)
        elif self.role == "prefill":
            self.slot_parked[slot] = True   # awaiting page handoff
            if tr is not None:
                tr.begin("parked", req.rid, t=now, replica=self.replica_id)
        elif tr is not None:
            tr.begin("decode", req.rid, t=now, replica=self.replica_id)

    # ------------------------------------------------- disaggregation hand --
    def handoff_ready(self) -> List[int]:
        """Slots parked after prefill, awaiting page migration (admit
        order — the router drains the oldest first)."""
        return [s for s in range(self.max_slots) if self.slot_parked[s]]

    def can_adopt(self, req: Request) -> bool:
        """Room for a migrated stream: a free slot plus the worst-case
        reservation the stream's remaining generation needs."""
        if not self._free_slots():
            return False
        need = PC.pages_for_len(req.plen + req.max_new_tokens,
                                self.page_size)
        return (self.alloc.num_free
                - (self.reserved_pages - self.pages_in_use) >= need)

    def adopt(self, req: Request, donor: "ContinuousBatchingScheduler",
              donor_slot: int) -> int:
        """Adopt a prefilled stream from ``donor``: copy its KV pages
        verbatim into freshly allocated pages here (``PC.migrate_pages`` —
        every layer, every shard slice, one call), carry the SSM slot state
        across, and seat the request in a free slot with the full
        worst-case reservation. The caller must still
        ``donor.surrender_slot`` to release the source pages. Returns the
        adopting slot."""
        assert self.can_adopt(req)
        slot = self._free_slots()[0]
        src_pages = donor.slot_pages[donor_slot]
        need = PC.pages_for_len(req.plen + req.max_new_tokens,
                                self.page_size)
        pages = self.alloc.alloc(len(src_pages), owner=req.rid)
        self.cache = PC.migrate_pages(donor.cache, self.cache, src_pages,
                                      pages, tp=self.tp)
        state = None
        if self._has_ssm:
            state = PC.extract_ssm_slot(donor.cache, donor_slot)
            self.cache = PC.merge_ssm_slot(
                self.cache, PC.ssm_slot_view(self.cache, state), slot)
        row = np.full((self.n_pg,), PC.SINK_PAGE, np.int32)
        row[:len(pages)] = pages
        self.reserved_pages += need
        if self.tenant_quotas is not None:
            self._tenant_reserved[req.tenant] = (
                self._tenant_reserved.get(req.tenant, 0) + need)
        self.block_table[slot] = row
        self.seq_lens[slot] = req.plen
        self.last_tokens[slot, 0] = int(req.out_tokens[-1])
        self.slot_req[slot] = req
        self.slot_pages[slot] = list(pages)
        self.slot_reserve[slot] = need
        self.slot_shared[slot] = 0
        if self.spec_draft is not None:
            # the draft cache did not travel with the migration; the next
            # speculative tick re-prefills it here. It may draft different
            # tokens than the donor would have — acceptance may dip for a
            # tick, emitted tokens cannot change (every draft is verified)
            self._draft_ready[slot] = False
        if self.prefix_cache:
            self.index.insert(req.prompt, pages, state=state)
        req.migrations += 1
        # ownership transfers at the copy point, not at surrender: if the
        # donor dies inside the adopt→surrender window, its fail() sees the
        # stream already belongs elsewhere and must not requeue it (the
        # adopter owns the only live copy of its pages)
        if self.replica_id is not None:
            req.replica = self.replica_id
        self.stats["migrations_in"] += 1
        tr = self.tracer
        if tr is not None:
            now = self._tnow()
            tr.end("parked", req.rid, t=now, pages=len(pages))
            tr.begin("decode", req.rid, t=now, replica=self.replica_id)
        return slot

    def surrender_slot(self, slot: int) -> Request:
        """Release a handed-off slot on the donor side: free its pages
        (refcount-correct — shared prefix pages survive for their other
        owners, and ``on_free`` drops any index entry whose last page
        owner this was), drop the reservation, clear the slot. The request
        object itself lives on at the adopter; no finish is recorded."""
        req = self.slot_req[slot]
        self.alloc.free(self.slot_pages[slot])
        if self.tenant_quotas is not None:
            t = req.tenant
            self._tenant_reserved[t] = max(
                0, self._tenant_reserved.get(t, 0) - self.slot_reserve[slot])
        self.reserved_pages -= self.slot_reserve[slot]
        self.slot_reserve[slot] = 0
        self.slot_shared[slot] = 0
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.block_table[slot] = PC.SINK_PAGE
        self.seq_lens[slot] = 0
        self.last_tokens[slot, 0] = 0
        self.slot_parked[slot] = False
        self.slot_resume_state[slot] = None
        self.stats["migrations_out"] += 1
        return req

    @property
    def prefill_backlog(self) -> int:
        """Prompt tokens not yet landed: due queued prompts plus in-flight
        chunk remainders — the prefill-role autoscaling signal."""
        t = sum(r.plen for r in self.waiting
                if r.arrival_step <= self.step_idx)
        t += sum(r.plen - r.prefill_pos for r in self.slot_req
                 if r is not None and r.prefill_pos is not None)
        return t

    # -------------------------------------------------------------- finish --
    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.finish_step = self.step_idx
        if (self.host_tier is not None and self.prefix_cache
                and not self.slot_parked[slot]
                and req.prefill_pos is None):
            # host tier on: retain the chain for session resume instead of
            # freeing it — HBM pressure reclaims it later via _reclaim
            self._retain_finished(slot, req)
        else:
            self.alloc.free(self.slot_pages[slot])
        if self.tenant_quotas is not None:
            t = req.tenant
            self._tenant_reserved[t] = max(
                0, self._tenant_reserved.get(t, 0) - self.slot_reserve[slot])
        self.reserved_pages -= self.slot_reserve[slot]
        self.slot_reserve[slot] = 0
        self.slot_shared[slot] = 0
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.block_table[slot] = PC.SINK_PAGE
        self.seq_lens[slot] = 0
        self.last_tokens[slot, 0] = 0
        self.slot_parked[slot] = False
        self.slot_resume_state[slot] = None
        if slot in self._prefill_fifo:
            self._prefill_fifo.remove(slot)
        self.finished.append(req)
        self.h_latency.observe(req.finish_step - req.arrival_step)
        tr = self.tracer
        if tr is not None:
            now = self._tnow()
            tr.end("decode", req.rid, t=now, tokens=len(req.out_tokens))
            tr.end("parked", req.rid, t=now)    # safety: finish while parked
            tr.instant("finish", rid=req.rid, t=now,
                       replica=self.replica_id, tokens=len(req.out_tokens))

    def _grow_pages(self, k: int = 1) -> None:
        """Ensure each active slot owns the pages its next ``k`` tokens land
        in (admission reserved them, so allocation cannot fail here)."""
        for slot, req in enumerate(self.slot_req):
            if req is None or req.prefill_pos is not None \
                    or self.slot_parked[slot]:
                continue                    # not decoding this tick
            needed = (int(self.seq_lens[slot]) + k - 1) // self.page_size + 1
            while len(self.slot_pages[slot]) < needed:
                new = self.alloc.alloc(1, owner=req.rid)[0]
                self.block_table[slot, len(self.slot_pages[slot])] = new
                self.slot_pages[slot].append(new)

    def _fuse_k(self, max_fuse: int,
                decoding: Optional[List[int]] = None) -> int:
        """Largest tick count that changes nothing mid-scan: bounded by the
        earliest finish among decoding requests and the next future
        arrival."""
        if decoding is None:
            reqs = [r for r in self.slot_req if r is not None]
        else:
            reqs = [self.slot_req[i] for i in decoding]
        k = min(r.max_new_tokens - len(r.out_tokens) for r in reqs)
        future = [r.arrival_step - self.step_idx for r in self.waiting
                  if r.arrival_step > self.step_idx]
        if future:
            k = min(k, min(future))
        return max(1, min(k, max_fuse))

    # ------------------------------------------------- speculative verify --
    def _spec_step(self, decoding: List[int],
                   done_now: List[Request]) -> List[Request]:
        """One draft-and-verify tick over every decoding slot.

        Each stream proposes up to ``spec_k`` draft tokens (n-gram lookup
        or the draft model), the target verifies last-token + drafts in a
        single paged dispatch, and the longest matching prefix plus the
        target's correction token is emitted — ``accepted + 1`` tokens per
        stream per tick, byte-identical to spec-off decoding. Rollback:
        ``seq_lens`` advances only past accepted positions (rejected K/V
        stays masked and is overwritten in place), per-slot draft caps
        route overshoot rows to the sink page so the admission reservation
        is never exceeded, and hybrid archs restore the SSM state of the
        accepted step in-dispatch (``PC.select_ssm_steps``).
        """
        k, n, S = self.spec_k, self.spec_k + 1, self.max_slots
        caps: Dict[int, int] = {}
        for slot in decoding:
            req = self.slot_req[slot]
            # cap < remaining: emitting cap+1 tokens can never overrun the
            # token budget (nor the worst-case page reservation)
            caps[slot] = min(k, req.remaining_tokens - 1)
        for slot in decoding:                # pages for positions L..L+cap
            req = self.slot_req[slot]
            needed = (int(self.seq_lens[slot]) + caps[slot]) \
                // self.page_size + 1
            while len(self.slot_pages[slot]) < needed:
                new = self.alloc.alloc(1, owner=req.rid)[0]
                self.block_table[slot, len(self.slot_pages[slot])] = new
                self.slot_pages[slot].append(new)
        # pages must exist before drafting: the draft model writes its own
        # K/V at positions L..L+k through the same (just-grown) block table
        model_drafts = (self._model_drafts(decoding, caps)
                        if self.spec_draft is not None else {})
        drafts: Dict[int, np.ndarray] = {}
        for slot in decoding:
            req = self.slot_req[slot]
            d = model_drafts.get(slot)
            if d is None:
                d = self._draft(req, caps[slot])
            caps[slot] = len(d)
            drafts[slot] = d
            req.speculating = bool(len(d))
            req.spec_drafted += len(d)
            self.stats["spec_drafted"] += len(d)
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.alloc.num_allocated)
        ctx = int(np.sum(self.seq_lens))
        toks = np.zeros((S, n), np.int32)
        lens0 = np.zeros((S,), np.int32)
        bt = np.full((S, self.n_pg), PC.SINK_PAGE, np.int32)
        live = np.zeros((S,), np.int32)
        for slot in decoding:
            cap = caps[slot]
            toks[slot, 0] = self.last_tokens[slot, 0]
            if cap:
                toks[slot, 1:1 + cap] = drafts[slot]
            lens0[slot] = self.seq_lens[slot]
            bt[slot] = self.block_table[slot]
            live[slot] = cap + 1
        if self._has_ssm:
            outs, js, self.cache = self._timed(
                "verify", self._verify_seq_fn(n), self.params, self.cache,
                jnp.asarray(toks), jnp.asarray(lens0), jnp.asarray(bt),
                jnp.asarray(live), tokens=n * len(decoding), ctx_tokens=ctx)
            outs = np.asarray(outs).T                      # (S, n)
            js = np.asarray(js)
        else:
            outs, self.cache = self._timed(
                "verify", self._verify_fn(n), self.params, self.cache,
                jnp.asarray(toks), jnp.asarray(lens0), jnp.asarray(bt),
                jnp.asarray(live), tokens=n * len(decoding), ctx_tokens=ctx)
            outs = np.asarray(outs)                        # (S, n)
            js = None
        self.stats["decode_steps"] += 1
        self.stats["spec_ticks"] += 1
        self.step_idx += 1                  # before _finish (cf. step())
        tr = self.tracer
        for slot in decoding:
            req = self.slot_req[slot]
            cap = caps[slot]
            row = outs[slot]
            j = (int(js[slot]) if js is not None
                 else spec_accept(drafts[slot], row[:cap]))
            emitted = [int(t) for t in row[:j + 1]]
            req.out_tokens.extend(emitted)
            req.spec_accepted += j
            self.stats["spec_accepted"] += j
            self.stats["tokens_out"] += len(emitted)
            self.h_spec_accept.observe(len(emitted))
            self.seq_lens[slot] += j + 1
            self.last_tokens[slot, 0] = emitted[-1]
            if tr is not None and cap:
                now = self._tnow()
                tr.span("spec_verify", req.rid, now - 1, now,
                        replica=self.replica_id, drafted=cap, accepted=j)
            if req.done:
                req.speculating = False
                done_now.append(req)
                self._finish(slot)
        if self.stats["spec_drafted"]:
            self.stats["spec_accept_rate"] = round(
                self.stats["spec_accepted"] / self.stats["spec_drafted"], 4)
        return done_now

    # -------------------------------------------------------------- resize --
    def resize(self, *, max_slots: Optional[int] = None,
               num_pages: Optional[int] = None) -> None:
        """Live capacity change (the autoscaler's actuation point).

        Growth is immediate: slot-state rows / page pools are zero-padded,
        which leaves every live sequence's pages and tokens untouched.
        Shrink is drain-before-shrink: slots >= the new target stop
        admitting and the arrays slice down once those slots empty; pages
        >= the new pool size are retired from the free list now and the
        pools slice once their last owner finishes. A page shrink is
        clamped so the pool always covers every outstanding admission
        reservation — an admitted request can never hit a mid-flight OOM,
        resize or not. Each distinct (slots, pages) shape costs one jit
        re-trace, so callers should bucket targets (see
        ``repro.autoscale.controller``).
        """
        if max_slots is not None:
            if max_slots < 1:
                raise ValueError("max_slots must be >= 1")
            if max_slots > self.max_slots:
                self._grow_slots(max_slots)
            self.target_slots = max_slots
        if num_pages is not None:
            # cold retained chains are reclaimable — preempt them to the
            # host tier first so they never pin the pool against a shrink
            floor = (self.alloc.num_allocated + self.reserved_pages
                     - self.pages_in_use + 1)
            if num_pages < floor and self._retained:
                self._reclaim(floor - num_pages)
            # reservation-aware floor (+1 for the sink page): the pool must
            # cover every physically held page plus every outstanding
            # admission reservation's future growth
            num_pages = max(num_pages,
                            self.alloc.num_allocated + self.reserved_pages
                            - self.pages_in_use + 1, 2)
            if num_pages > self.alloc.num_pages:
                self.cache = PC.resize_cache_pages(self.cache, num_pages,
                                                   tp=self.tp)
                if self.spec_draft is not None:
                    self._draft_cache = PC.resize_cache_pages(
                        self._draft_cache, num_pages)
                self.alloc.grow(num_pages)
            else:
                self.alloc.request_shrink(num_pages)
        self.stats["resizes"] += 1
        self._settle_resize()

    def _grow_slots(self, new: int) -> None:
        pad = new - self.max_slots
        self.block_table = np.vstack(
            [self.block_table,
             np.full((pad, self.n_pg), PC.SINK_PAGE, np.int32)])
        self.seq_lens = np.concatenate(
            [self.seq_lens, np.zeros((pad,), np.int32)])
        self.last_tokens = np.vstack(
            [self.last_tokens, np.zeros((pad, 1), np.int32)])
        self.slot_req.extend([None] * pad)
        self.slot_pages.extend([] for _ in range(pad))
        self.slot_reserve.extend([0] * pad)
        self.slot_shared.extend([0] * pad)
        self.slot_resume_state.extend([None] * pad)
        self.slot_parked.extend([False] * pad)
        self.cache = PC.resize_cache_slots(self.cache, new)
        if self.spec_draft is not None:
            self._draft_cache = PC.resize_cache_slots(self._draft_cache, new)
            self._draft_ready.extend([False] * pad)
        self.max_slots = new

    def _settle_resize(self) -> None:
        """Complete any drained shrink (called between decode ticks)."""
        n = self.target_slots
        if n < self.max_slots and all(r is None for r in self.slot_req[n:]):
            self.block_table = self.block_table[:n]
            self.seq_lens = self.seq_lens[:n]
            self.last_tokens = self.last_tokens[:n]
            del self.slot_req[n:]
            del self.slot_pages[n:]
            del self.slot_reserve[n:]
            del self.slot_shared[n:]
            del self.slot_resume_state[n:]
            del self.slot_parked[n:]
            self.cache = PC.resize_cache_slots(self.cache, n)
            if self.spec_draft is not None:
                self._draft_cache = PC.resize_cache_slots(
                    self._draft_cache, n)
                del self._draft_ready[n:]
            self.max_slots = n
        if self.alloc.shrink_pending and self._retained:
            # retained chains holding pages above the shrink target would
            # stall the drain forever — they are cold, so preempt them now
            tgt = self.alloc._shrink_target
            for key in [k for k, c in list(self._retained.items())
                        if any(p >= tgt for p in c.pages)]:
                self._evict_chain(key)
            self._gauge_tiers()
        if self.alloc.shrink_ready():
            new_pages = self.alloc.complete_shrink()
            self.cache = PC.resize_cache_pages(self.cache, new_pages,
                                               tp=self.tp)
            if self.spec_draft is not None:
                self._draft_cache = PC.resize_cache_pages(
                    self._draft_cache, new_pages)

    # ---------------------------------------------------------------- step --
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    @property
    def pending(self) -> int:
        return len(self.waiting)

    @property
    def pending_due(self) -> int:
        """Waiting requests whose arrival time has passed — the real queue
        depth (benchmarks submit whole traces upfront with future
        ``arrival_step``s; those must not read as present load)."""
        return sum(r.arrival_step <= self.step_idx for r in self.waiting)

    def step(self, max_fuse: int = 16) -> List[Request]:
        """Admit what fits, run up to ``max_fuse`` fused decode ticks, evict
        finished requests.

        Fusing runs several ticks in one jit dispatch (a lax.scan) but only
        when nothing could change mid-scan — no active request finishes and
        no waiting arrival becomes due — so the schedule (and every token)
        is identical to single-stepping. Returns the requests that finished.
        A tick with no active slots (arrival gap) only advances the clock.
        """
        self._settle_resize()
        self._try_admit()
        if self.prefill_budget is not None and self._prefill_fifo:
            self._advance_prefills()
        done_now: List[Request] = self._admit_done
        self._admit_done = []
        # slots still landing chunks (PREFILLING) or parked for handoff sit
        # out of decode: masked below, they look exactly like empty slots
        decoding = [i for i, r in enumerate(self.slot_req)
                    if r is not None and r.prefill_pos is None
                    and not self.slot_parked[i]]
        if not decoding:
            # the idle fast-forward may only fire when the scheduler is
            # TRULY idle: any resident stream — including a PREFILLING
            # backlog or a parked handoff slot, neither of which decodes —
            # must see the clock advance one tick at a time, or queue-wait
            # and TTFT histograms under-count the wait that backlog caused
            busy = (self.num_active > 0 or bool(self._prefill_fifo)
                    or any(self.slot_parked))
            arrivals = [r.arrival_step for r in self.waiting]
            if not busy and arrivals and min(arrivals) > self.step_idx:
                # idle gap: skip toward the next arrival instead of spinning
                # ticks — capped at max_fuse so a control loop driving this
                # scheduler still samples (and can scale in) inside the gap
                self.step_idx = min(min(arrivals), self.step_idx + max_fuse)
            else:
                self.step_idx += 1
            return done_now
        if self.spec_k is not None:
            return self._spec_step(decoding, done_now)
        k = self._fuse_k(max_fuse, decoding)
        if self._prefill_fifo:
            k = 1                           # chunks land between single ticks
        k = 1 << (k.bit_length() - 1)       # pow2 buckets bound compiles
        self._grow_pages(k)
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.alloc.num_allocated)
        toks, lens, bt = self.last_tokens, self.seq_lens, self.block_table
        if len(decoding) < self.num_active:
            dec = set(decoding)
            toks, lens, bt = toks.copy(), lens.copy(), bt.copy()
            for i, r in enumerate(self.slot_req):
                if r is not None and i not in dec:
                    toks[i, 0] = 0          # identical to an empty slot: the
                    lens[i] = 0             # garbage token lands on the sink
                    bt[i] = PC.SINK_PAGE    # page, masked out of attention
        outs, self.cache = self._timed(
            "decode", self._decode_fn, self.params, self.cache,
            jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(bt), k=k,
            tokens=k * len(decoding), ctx_tokens=int(np.sum(lens)))
        outs = np.asarray(outs)             # (k, max_slots)
        self.stats["decode_steps"] += k
        self.step_idx += k                  # before _finish: finish_step must
        for slot in decoding:               # not depend on max_fuse
            req = self.slot_req[slot]
            req.out_tokens.extend(int(t) for t in outs[:, slot])
            self.stats["tokens_out"] += k
            self.last_tokens[slot, 0] = int(outs[-1, slot])
            self.seq_lens[slot] += k
            if req.done:
                done_now.append(req)
                self._finish(slot)
        return done_now

    def run(self, max_steps: int = 100_000,
            max_fuse: int = 32) -> List[Request]:
        """Drive ``step`` until every submitted request has finished."""
        while (self.waiting or self.num_active) and max_steps:
            self.step(max_fuse=max_fuse)
            max_steps -= 1
        if self.waiting or self.num_active:
            raise RuntimeError(
                f"run() exhausted max_steps with {len(self.waiting)} waiting "
                f"and {self.num_active} active requests")
        return self.finished
