"""Request-lifecycle tracing on the sim tick clock.

A ``Tracer`` collects, per request, the spans of its lifecycle —

    queued -> [prefill | prefill_chunk[k]...] -> (parked -> page_migration
    ->) decode -> finish

— plus fleet-level instant events (``routed``, ``admitted``,
``page_migration``, ``reroute``, ``failover``, ``autoscale``), each
annotated with the replica / shard group it ran on and page / prefix /
migration detail. Time is the simulation tick clock: the scheduler stamps
its own ``step_idx`` when standalone, and the fabric router stamps the
*fleet* clock for every replica it drives (replica clocks drift through
idle-gap skipping, so per-replica ticks would not line up on one
timeline).

Tracing is read-only by contract: hooks observe scheduler state and never
touch it, so a traced run emits byte-identical tokens to an untraced one
(asserted in tests/test_obs_plane.py).

Exports:

* ``write_chrome`` — Chrome trace-event JSON (open in Perfetto or
  ``chrome://tracing``): spans are ``"X"`` complete events with
  ``pid``/``process_name`` per replica and ``tid`` per request, one tick
  rendered as 1 ms;
* ``write_jsonl`` / ``from_jsonl`` — a lossless JSON-lines round trip
  with the same fail-loud contract as ``repro.core.events.EventLog``
  (malformed input raises ``ValueError`` naming the 1-based line);
* ``to_event_log`` — the trace as an ``EventLog`` so any serving run
  (autoscaled or not) can export ``--events-out`` and replay it with the
  existing assertion helpers.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.core.events import EventLog

__all__ = ["Span", "Instant", "Tracer", "TICK_US"]

# one sim tick rendered as 1000 trace-event microseconds (= 1 ms), so a
# few-hundred-tick serve run spans a readable fraction of a second
TICK_US = 1000.0


@dataclasses.dataclass
class Span:
    name: str
    rid: int
    t0: float
    t1: float
    replica: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "span", "name": self.name, "rid": self.rid,
                "t0": self.t0, "t1": self.t1, "replica": self.replica,
                "attrs": self.attrs}


@dataclasses.dataclass
class Instant:
    name: str
    t: float
    rid: Optional[int] = None
    replica: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "instant", "name": self.name, "t": self.t,
                "rid": self.rid, "replica": self.replica,
                "attrs": self.attrs}


class Tracer:
    """Span/instant collector on a settable tick clock.

    The clock (``t``) is pushed by whoever owns the timeline —
    ``set_tick`` from the scheduler's or router's step loop — so hook
    sites just call ``begin``/``end``/``span``/``instant`` without
    plumbing a time argument. ``begin`` on an already-open ``(rid, name)``
    and ``end`` on a never-opened one are silent no-ops: a request may
    predate the tracer's attachment, and the fleet path opens ``queued``
    at the router while the replica path would open it again.
    """

    def __init__(self) -> None:
        self.t = 0.0
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self.process_names: Dict[int, str] = {}
        self._open: Dict[Tuple[int, str], Span] = {}
        self._indices: Dict[Tuple[int, str], int] = {}

    def set_tick(self, t: float) -> None:
        self.t = float(t)

    # ------------------------------------------------------------- record --
    def begin(self, name: str, rid: int, *, t: Optional[float] = None,
              replica: Optional[int] = None, **attrs: Any) -> None:
        key = (rid, name)
        if key in self._open:
            return                        # first opener wins (fleet submit)
        self._open[key] = Span(name, rid, float(self.t if t is None else t),
                               -1.0, replica, dict(attrs))

    def end(self, name: str, rid: int, *, t: Optional[float] = None,
            **attrs: Any) -> None:
        span = self._open.pop((rid, name), None)
        if span is None:
            return                        # unmatched end: tolerated no-op
        span.t1 = float(self.t if t is None else t)
        span.attrs.update(attrs)
        self.spans.append(span)

    def span(self, name: str, rid: int, t0: float, t1: float, *,
             replica: Optional[int] = None, **attrs: Any) -> None:
        """A complete span in one call (e.g. a prefill chunk landing
        within a single tick)."""
        self.spans.append(Span(name, rid, float(t0), float(t1), replica,
                               dict(attrs)))

    def instant(self, name: str, *, rid: Optional[int] = None,
                t: Optional[float] = None, replica: Optional[int] = None,
                **attrs: Any) -> None:
        self.instants.append(Instant(name, float(self.t if t is None else t),
                                     rid, replica, dict(attrs)))

    def next_index(self, rid: int, name: str) -> int:
        """Per-(request, name) running index — chunk numbering."""
        key = (rid, name)
        self._indices[key] = self._indices.get(key, -1) + 1
        return self._indices[key]

    def set_process_name(self, pid: int, label: str) -> None:
        self.process_names[int(pid)] = str(label)

    def finish_open(self) -> int:
        """Close every still-open span at the current tick (export time on
        a run that was interrupted or is mid-flight), marking it
        ``open=True``; returns how many were flushed."""
        n = 0
        for key in sorted(self._open, key=lambda k: (str(k[1]), k[0])):
            span = self._open.pop(key)
            span.t1 = max(self.t, span.t0)
            span.attrs["open"] = True
            self.spans.append(span)
            n += 1
        return n

    # ----------------------------------------------------- chrome export --
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto / chrome://tracing).

        ``pid`` = replica id (+1; pid 0 is the fleet/router plane so
        replica 0 keeps its own lane), ``tid`` = request id, ``ts``/
        ``dur`` in microseconds at ``TICK_US`` per tick. Span/instant
        attrs (including ``replica``) travel in ``args``.
        """
        events: List[Dict[str, Any]] = []
        events.append({"ph": "M", "name": "process_name", "pid": 0,
                       "args": {"name": "fleet"}})
        for pid, label in sorted(self.process_names.items()):
            events.append({"ph": "M", "name": "process_name", "pid": pid + 1,
                           "args": {"name": label}})

        def _pid(replica):
            return 0 if replica is None else int(replica) + 1

        for s in self.spans:
            events.append({
                "ph": "X", "name": s.name, "cat": "request",
                "pid": _pid(s.replica), "tid": int(s.rid),
                "ts": s.t0 * TICK_US,
                "dur": max(s.t1 - s.t0, 0.0) * TICK_US,
                "args": {"rid": s.rid, "replica": s.replica, **s.attrs},
            })
        for i in self.instants:
            events.append({
                "ph": "i", "name": i.name, "cat": "fleet",
                "pid": _pid(i.replica),
                "tid": int(i.rid) if i.rid is not None else 0,
                "ts": i.t * TICK_US,
                "s": "g" if i.rid is None else "t",
                "args": {"rid": i.rid, "replica": i.replica, **i.attrs},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"clock": f"sim tick = {TICK_US} us"}}

    def write_chrome(self, path: str) -> int:
        """Write Chrome trace JSON; returns the number of trace events."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])

    # ------------------------------------------------------ jsonl roundtrip --
    def to_jsonl(self) -> str:
        lines = [json.dumps({"kind": "meta", "pid": pid, "label": label},
                            sort_keys=True)
                 for pid, label in sorted(self.process_names.items())]
        lines += [json.dumps(s.to_dict(), sort_keys=True, default=str)
                  for s in self.spans]
        lines += [json.dumps(i.to_dict(), sort_keys=True, default=str)
                  for i in self.instants]
        return "".join(line + "\n" for line in lines)

    def write_jsonl(self, path: str) -> int:
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return len(self.spans) + len(self.instants)

    _REQUIRED = {"span": ("name", "rid", "t0", "t1"),
                 "instant": ("name", "t"),
                 "meta": ("pid", "label")}

    @classmethod
    def from_jsonl(cls, path: str) -> "Tracer":
        """Load an exported trace; same fail-loud contract as
        ``EventLog.from_jsonl`` — malformed input raises ``ValueError``
        naming the offending 1-based line, so a truncated or hand-edited
        trace fails loud instead of replaying silently wrong."""
        tr = cls()
        with open(path) as f:
            for lineno, line in enumerate(f, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError as e:
                    raise ValueError(
                        f"{path}: line {lineno} is not valid JSON "
                        f"({e.msg} at column {e.colno})") from e
                if not isinstance(d, dict):
                    raise ValueError(
                        f"{path}: line {lineno} holds a JSON "
                        f"{type(d).__name__}, not a trace record")
                kind = d.get("kind")
                if kind not in cls._REQUIRED:
                    raise ValueError(
                        f"{path}: line {lineno} has unknown trace record "
                        f"kind {kind!r} (expected one of "
                        f"{sorted(cls._REQUIRED)})")
                missing = [k for k in cls._REQUIRED[kind] if k not in d]
                if missing:
                    raise ValueError(
                        f"{path}: line {lineno} ({kind}) is missing "
                        f"field(s) {missing} (has {sorted(d)})")
                attrs = d.get("attrs", {})
                if not isinstance(attrs, dict):
                    raise ValueError(
                        f"{path}: line {lineno} has a non-object 'attrs' "
                        f"({type(attrs).__name__})")
                if kind == "meta":
                    tr.process_names[int(d["pid"])] = str(d["label"])
                elif kind == "span":
                    tr.spans.append(Span(d["name"], d["rid"], d["t0"],
                                         d["t1"], d.get("replica"),
                                         dict(attrs)))
                else:
                    tr.instants.append(Instant(d["name"], d["t"],
                                               d.get("rid"),
                                               d.get("replica"),
                                               dict(attrs)))
        return tr

    # ----------------------------------------------------------- EventLog --
    def to_event_log(self) -> EventLog:
        """The trace as an ``EventLog`` (time-ordered; spans keyed at their
        start): lets any serving run export ``--events-out`` and reuse the
        existing replay/assertion machinery, autoscaled or not."""
        log = EventLog()
        records: List[Tuple[float, int, str, str, Dict[str, Any]]] = []
        for n, s in enumerate(self.spans):
            actor = "fleet" if s.replica is None else f"replica-{s.replica}"
            records.append((s.t0, n, actor, s.name,
                            {"rid": s.rid, "dur": s.t1 - s.t0, **s.attrs}))
        base = len(self.spans)
        for n, i in enumerate(self.instants):
            actor = "fleet" if i.replica is None else f"replica-{i.replica}"
            detail = dict(i.attrs)
            if i.rid is not None:
                detail["rid"] = i.rid
            records.append((i.t, base + n, actor, i.name, detail))
        for t, _, actor, action, detail in sorted(records,
                                                  key=lambda r: (r[0], r[1])):
            log.emit(t, actor, action, **detail)
        return log
