"""Autoscaling benchmark: elastic vs static-peak provisioning on arrival
traces, measured in instance-seconds cost and p99 latency.

Run:  PYTHONPATH=src python benchmarks/autoscale_bench.py [--trace burst]
      PYTHONPATH=src python benchmarks/autoscale_bench.py --smoke

Both runs serve the *same* trace through the same continuous-batching
paged engine; only provisioning differs:

* **static peak** — decode slots (and the nodes backing them) fixed at
  the trace's peak demand for the whole run: the classic over-provisioned
  deployment whose cost the paper's extend/shrink use cases attack.
* **autoscale** — `repro.autoscale.AutoscaleController` moves slots/pages
  inside the blueprint capacity bands, tracking demand per slot; nodes
  follow slots (`--slots-per-node`), scale-out capacity arrives after
  `--boot-ticks` (0 = attach from a warm pool — InstaCluster's
  minutes-not-hours provisioning pitch taken to its limit; raise it to
  price in cold boots and watch p99 degrade).

Everything runs on the simulated tick clock, so cost (node-ticks x
tick-seconds) and per-request latency (finish - arrival ticks) are exact
and deterministic — no wall-clock noise in the comparison.

Traces:
* **diurnal** — arrival density follows (1 + sin)/2 over the horizon: the
  day/night cycle where static peak burns money all night.
* **burst**   — a low baseline with clumped arrival spikes: the worst
  case for reactive scaling (and the trace the acceptance criterion in
  tests/test_autoscale.py pins).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math

import jax
import numpy as np

from repro.autoscale import AutoscaleController, CapacityBands
from repro.configs.registry import REDUCED
from repro.core.events import EventLog
from repro.models import model as M
from repro.serving import paged_cache as PC
from repro.serving.scheduler import ContinuousBatchingScheduler


# ------------------------------------------------------------------ traces --

def diurnal_trace(rng, vocab, *, requests, horizon, p_lo, p_hi, g_lo, g_hi):
    """Arrival ticks whose density follows (1 + sin)/2 over the horizon."""
    t = np.arange(horizon)
    w = 1.0 + np.sin(2 * np.pi * t / horizon - np.pi / 2)  # trough at t=0
    cdf = np.cumsum(w) / np.sum(w)
    out = []
    for i in range(requests):
        arrival = int(np.searchsorted(cdf, (i + 0.5) / requests))
        out.append(_req(rng, vocab, arrival, p_lo, p_hi, g_lo, g_hi))
    return sorted(out, key=lambda r: r[0])


def bursty_trace(rng, vocab, *, requests, horizon, n_bursts, burst_frac,
                 p_lo, p_hi, g_lo, g_hi):
    """Low uniform baseline plus ``n_bursts`` clumps holding ``burst_frac``
    of all requests (each clump lands within a few ticks)."""
    n_burst = int(requests * burst_frac)
    n_base = requests - n_burst
    out = [_req(rng, vocab, int(rng.randint(0, horizon)),
                p_lo, p_hi, g_lo, g_hi) for _ in range(n_base)]
    starts = [int((k + 1) * horizon / (n_bursts + 1))
              for k in range(n_bursts)]
    for j in range(n_burst):
        start = starts[j % n_bursts]
        out.append(_req(rng, vocab, start + int(rng.randint(0, 3)),
                        p_lo, p_hi, g_lo, g_hi))
    return sorted(out, key=lambda r: r[0])


def _req(rng, vocab, arrival, p_lo, p_hi, g_lo, g_hi):
    plen = int(rng.randint(p_lo, p_hi + 1))
    gen = int(rng.randint(g_lo, g_hi + 1))
    return (arrival, rng.randint(0, vocab, size=plen).astype(np.int32), gen)


def peak_demand(trace, window: int = 8) -> int:
    """Max arrivals in any ``window`` ticks — what static peak provisions
    for (a fixed deployment sized below this queues at every burst)."""
    arrivals = [a for a, _, _ in trace]
    return max(sum(1 for a in arrivals if t <= a < t + window)
               for t in range(0, max(arrivals) + 1))


# -------------------------------------------------------------------- runs --

def _submit(sched, trace):
    for arrival, prompt, gen in trace:
        sched.submit(prompt, gen, arrival_step=arrival)


def _latencies(reqs):
    return np.asarray([r.finish_step - r.arrival_step for r in reqs], float)


def run_static(cfg, params, trace, *, slots, page_size, max_seq,
               slots_per_node, tick_seconds):
    """Fixed peak capacity for the whole run."""
    n_pg = PC.pages_for_len(max_seq, page_size)
    sched = ContinuousBatchingScheduler(
        cfg, params, max_slots=slots, page_size=page_size,
        num_pages=slots * n_pg + 1, max_seq_len=max_seq)
    _submit(sched, trace)
    done = sched.run()
    lat = _latencies(done)
    nodes = math.ceil(slots / slots_per_node)
    duration = sched.step_idx
    return {
        "slots": slots,
        "nodes": nodes,
        "duration_ticks": duration,
        "instance_seconds": nodes * duration * tick_seconds,
        "p50_latency_s": float(np.percentile(lat, 50)) * tick_seconds,
        "p99_latency_s": float(np.percentile(lat, 99)) * tick_seconds,
    }, done


def run_autoscale(cfg, params, trace, *, bands, page_size, max_seq,
                  slots_per_node, boot_ticks, eval_interval, tick_seconds,
                  log=None):
    """Elastic capacity under the autoscale control loop."""
    n_pg = PC.pages_for_len(max_seq, page_size)
    sched = ContinuousBatchingScheduler(
        cfg, params, max_slots=bands.min_slots, page_size=page_size,
        num_pages=bands.min_slots * n_pg + 1, max_seq_len=max_seq)
    ctl = AutoscaleController(
        sched, bands, eval_interval=eval_interval,
        tick_seconds=tick_seconds, slots_per_node=slots_per_node,
        node_boot_ticks=boot_ticks, log=log)
    _submit(sched, trace)
    done = ctl.run()
    lat = _latencies(done)
    out = ctl.summary()
    out.update({
        "duration_ticks": sched.step_idx,
        "p50_latency_s": float(np.percentile(lat, 50)) * tick_seconds,
        "p99_latency_s": float(np.percentile(lat, 99)) * tick_seconds,
    })
    return out, done, ctl


def compare(cfg, params, trace, *, page_size=8, max_seq=64,
            slots_per_node=2, boot_ticks=0, eval_interval=1,
            tick_seconds=1.0, max_slots=None, log=None):
    """Static-peak vs autoscale on one trace; returns the comparison dict
    (imported by tests/test_autoscale.py for the acceptance criterion)."""
    peak = max_slots or min(peak_demand(trace), 32)
    n_pg = PC.pages_for_len(max_seq, page_size)
    bands = CapacityBands(min_slots=1, max_slots=peak,
                          min_pages=n_pg + 1, max_pages=peak * n_pg + 1)
    static, _ = run_static(
        cfg, params, trace, slots=peak, page_size=page_size,
        max_seq=max_seq, slots_per_node=slots_per_node,
        tick_seconds=tick_seconds)
    auto, _, ctl = run_autoscale(
        cfg, params, trace, bands=bands, page_size=page_size,
        max_seq=max_seq, slots_per_node=slots_per_node,
        boot_ticks=boot_ticks, eval_interval=eval_interval,
        tick_seconds=tick_seconds, log=log)
    return {
        "requests": len(trace),
        "peak_slots": peak,
        "static": static,
        "autoscale": auto,
        "cost_ratio": round(static["instance_seconds"]
                            / max(auto["instance_seconds"], 1e-9), 2),
        "p99_ratio": round(auto["p99_latency_s"]
                           / max(static["p99_latency_s"], 1e-9), 3),
    }


# -------------------------------------------------------------------- main --

def build_trace(name, rng, vocab, *, requests, horizon, p_lo, p_hi,
                g_lo, g_hi):
    if name == "diurnal":
        return diurnal_trace(rng, vocab, requests=requests, horizon=horizon,
                             p_lo=p_lo, p_hi=p_hi, g_lo=g_lo, g_hi=g_hi)
    return bursty_trace(rng, vocab, requests=requests, horizon=horizon,
                        n_bursts=2, burst_frac=0.5,
                        p_lo=p_lo, p_hi=p_hi, g_lo=g_lo, g_hi=g_hi)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b", choices=sorted(REDUCED))
    ap.add_argument("--trace", default="burst",
                    choices=("burst", "diurnal", "both"))
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--horizon", type=int, default=480,
                    help="trace length in ticks")
    ap.add_argument("--prompt-lo", type=int, default=4)
    ap.add_argument("--prompt-hi", type=int, default=16)
    ap.add_argument("--gen-lo", type=int, default=4)
    ap.add_argument("--gen-hi", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--slots-per-node", type=int, default=2)
    ap.add_argument("--boot-ticks", type=int, default=0,
                    help="ticks before scaled-out nodes serve (0 = warm "
                    "pool attach)")
    ap.add_argument("--eval-interval", type=int, default=1)
    ap.add_argument("--tick-seconds", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events-out", default=None,
                    help="write the autoscale decision log as JSON lines")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic run for CI (both traces)")
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.horizon, args.trace = 24, 120, "both"

    cfg = dataclasses.replace(REDUCED[args.arch], dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_hi + args.gen_hi + 1

    out = {"arch": cfg.name, "boot_ticks": args.boot_ticks}
    traces = (("burst", "diurnal") if args.trace == "both"
              else (args.trace,))
    for name in traces:
        rng = np.random.RandomState(args.seed)
        trace = build_trace(name, rng, cfg.vocab_size,
                            requests=args.requests, horizon=args.horizon,
                            p_lo=args.prompt_lo, p_hi=args.prompt_hi,
                            g_lo=args.gen_lo, g_hi=args.gen_hi)
        log = EventLog()                     # one log per trace: each run's
        out[name] = compare(                 # clock starts at 0
            cfg, params, trace, page_size=args.page_size, max_seq=max_seq,
            slots_per_node=args.slots_per_node, boot_ticks=args.boot_ticks,
            eval_interval=args.eval_interval,
            tick_seconds=args.tick_seconds, log=log)
        if args.events_out:
            path = (args.events_out if len(traces) == 1
                    else f"{args.events_out}.{name}")
            out.setdefault("events_out", {})[name] = {
                "path": path, "events": log.write_jsonl(path)}
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
