"""Paged KV cache: shared page pools + block tables for the serving engine.

The dense engine (``repro.serving.engine``) gives every sequence a
capacity-padded ring buffer — memory scales with ``batch * capacity`` even
when most sequences are short. Here K/V live in per-layer *page pools* of
shape ``(num_pages, page_size, KVH, head_dim)``; a sequence owns just the
pages its tokens fill, recorded in a block table row. Allocation and
freeing are O(pages) host-side list operations, so the continuous-batching
scheduler (``repro.serving.scheduler``) can admit and evict sequences
mid-flight without reshaping any device buffer.

Layout invariants
-----------------
* Page 0 is the **sink page**: never allocated, and every unused block-table
  entry points at it. Idle decode slots write their garbage token there and
  the attention mask (``seq_lens``) keeps it out of every real sequence's
  softmax.
* Token ``t`` of a sequence lives at ``(block_table[t // page_size],
  t % page_size)`` — pages are filled densely in order, so a sequence of
  length ``n`` owns exactly ``ceil(n / page_size)`` pages.
* With ``cfg.cache_quant`` the pools hold int8 K/V plus fp32
  per-(position, kv-head) scale pages — the same quantisation contract as
  the dense engine's ring buffers (``repro.models.attention.quantize_kv``).

SSM layers need no paging (their state is O(1) per sequence); they keep a
dense ``(max_slots, ...)`` state row per scheduler slot in the same cache
pytree, so hybrid archs (jamba, mamba2) flow through the same decode step.

Shared prefixes: pages carry refcounts, ``PrefixIndex`` maps token-hash
chains of in-flight prompts to the pages holding their K/V, and
``copy_page`` is the copy-on-write fork for a sequence diverging inside a
shared page — see docs/serving.md "Shared prefixes" for the state diagram
and the admission contract built on top in ``repro.serving.scheduler``.

Shard groups (``tp > 1``): pages are *logical*, storage is *per shard*.
Every attention pool leaf grows a leading shard axis — shard ``s`` stores
the ``KVH/tp`` kv-head slice ``[s*KVH/tp, (s+1)*KVH/tp)`` of every page —
while the page-id space, the allocator's refcounts, the block tables, and
the prefix index stay a single shared control plane: page ``p`` addresses
the same slot in every shard's pool the same way it already addresses the
same slot in every layer's pool. Cache ops that move whole pages
(``write_prefill``, ``copy_page``, ``resize_cache_pages``) take ``tp`` and
touch every shard's slice in one call, so a COW fork or prefill insert can
never leave shards disagreeing about a page's contents — the invariant
the sharded rule set in tests/test_allocator_props.py drives. SSM slot
state is O(1) per sequence and stays replicated (unsharded).

Host-RAM tier: ``HostPageTier`` is a second page plane in host memory
with its own allocator; ``swap_out_pages`` / ``swap_in_pages`` move page
chains between tiers byte-exactly (int8/fp8 pools and scale pages
included), ``HOST_BIT`` tags host-resident ids wherever they sit in the
shared id spaces (saved block-table rows, prefix-index chains), and
``swap_resume_cost`` is the modeled recompute-vs-transfer decision the
scheduler resumes preempted streams with — see docs/serving.md "Memory
tiers & preemption".
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import kv_quant_mode, quantize_kv
from repro.models.transformer import depth_plan

SINK_PAGE = 0

# leaves whose first axis is the page-pool axis
PAGE_LEAVES = ("k_pages", "v_pages", "k_scale_pages", "v_scale_pages")

# Residency bit: a page id with HOST_BIT set addresses the HostPageTier's
# id space instead of the device pools. Block-table rows of swapped-out
# streams and prefix-index chains preempted to host carry tagged ids; the
# device allocator, the decode kernels, and every live block table only
# ever see untagged ids — swap-in strips the bit before a page re-enters
# the control plane. 2^30 keeps tagged ids positive in int32 block tables.
HOST_BIT = 1 << 30


def is_host_page(page: int) -> bool:
    """True if ``page`` is a host-tier id (residency bit set)."""
    return bool(int(page) & HOST_BIT)


def host_page_id(page: int) -> int:
    """Strip the residency bit: the HostPageTier-plane id."""
    return int(page) & ~HOST_BIT


def as_host_page(page: int) -> int:
    """Tag a host-plane id for storage in index chains / saved rows."""
    return int(page) | HOST_BIT


def pages_for_len(n_tokens: int, page_size: int) -> int:
    """Pages a sequence of ``n_tokens`` occupies (dense fill from page 0)."""
    return -(-n_tokens // page_size)


class PageAllocator:
    """Host-side refcounted free-list allocator over the shared page-id space.

    One allocator serves every layer: layer pools are shaped identically, so
    page id ``p`` addresses the same slot in each. Page 0 (the sink) is
    never handed out.

    Pages are *refcounted* so the prefix cache can share one physical page
    between sequences: ``alloc`` hands out pages at refcount 1, ``share``
    adds an owner, and ``free`` drops one reference per page — a shared page
    survives until its last owner releases it. ``on_free`` (when set) fires
    once per page as its refcount reaches zero, before the page re-enters
    the free list; the scheduler wires it to prefix-index invalidation.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one allocatable page + sink"
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, SINK_PAGE, -1))
        self._owner: Dict[int, Any] = {}      # page -> first owner (debug aid)
        self._ref: Dict[int, int] = {}        # page -> live reference count
        self.on_free = None                   # callback(page_id) at ref == 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._ref)

    def ref(self, page: int) -> int:
        """Live reference count of ``page`` (0 if free/retired)."""
        return self._ref.get(page, 0)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, owner: Any = None) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, free {len(self._free)} "
                f"of {self.num_pages - 1}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._owner[p] = owner
            self._ref[p] = 1
        return out

    def share(self, pages: List[int]) -> None:
        """Add one reference per page (prefix sharing across sequences)."""
        for p in pages:
            if p == SINK_PAGE:
                raise ValueError("sink page cannot be shared")
            if p not in self._ref:
                raise ValueError(f"cannot share unallocated page {p}")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; pages reaching refcount 0 return to
        the free list (or retire into a pending shrink).

        Raises on the sink page, on a page with no live reference, and on a
        duplicate page id within one call (one owner releasing the same
        page twice in a single ``free`` is always a caller bug — with
        refcounts it would silently steal another owner's reference).
        Validation runs before any mutation, so a raising call leaves the
        allocator untouched.
        """
        seen = set()
        for p in pages:
            if p == SINK_PAGE:
                raise ValueError("sink page cannot be freed")
            if p not in self._ref:
                raise ValueError(f"double free of page {p}")
            if p in seen:
                raise ValueError(
                    f"page {p} appears twice in one free() call")
            seen.add(p)
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p]:
                continue                      # surviving sharers
            del self._ref[p]
            self._owner.pop(p, None)
            if self.on_free is not None:
                self.on_free(p)
            if p < self._shrink_target:
                self._free.append(p)
            # else: the page is being retired by a pending shrink

    # --------------------------------------------------------- live resize --
    # _shrink_target defaults past any page id, i.e. "no shrink pending";
    # set as a class attribute so allocators pickled/built before this field
    # existed keep working.
    _shrink_target: int = 1 << 62

    @property
    def shrink_pending(self) -> bool:
        return self._shrink_target < self.num_pages

    def grow(self, new_num_pages: int) -> None:
        """Add pages ``[num_pages, new_num_pages)`` to the free list; cancels
        any pending shrink (its retired pages return to the pool). The
        shrink target is cleared unconditionally — a stale target below the
        new size would read as a phantom pending shrink and let a later
        ``complete_shrink`` slice the grown pool out from under the free
        list."""
        assert new_num_pages >= self.num_pages
        old_target = min(self._shrink_target, self.num_pages)
        in_free = set(self._free)
        self._free.extend(p for p in range(old_target, self.num_pages)
                          if p not in self._ref and p not in in_free)
        self._shrink_target = 1 << 62
        self._free.extend(range(self.num_pages, new_num_pages))
        self.num_pages = new_num_pages

    def request_shrink(self, new_num_pages: int) -> None:
        """Retire free pages with id >= ``new_num_pages`` immediately; pages
        still owned keep their owner and block ``complete_shrink`` until
        freed (drain-before-shrink). Raising a pending target un-retires the
        pages between the two targets."""
        assert 2 <= new_num_pages <= self.num_pages
        old = min(self._shrink_target, self.num_pages)
        if new_num_pages > old:
            in_free = set(self._free)
            self._free.extend(p for p in range(old, new_num_pages)
                              if p not in self._ref
                              and p not in in_free)
        # relaxing all the way back to the pool size is a cancellation, not
        # a pending shrink — leave no stale target behind
        self._shrink_target = (new_num_pages if new_num_pages < self.num_pages
                               else 1 << 62)
        self._free = [p for p in self._free if p < new_num_pages]

    def shrink_ready(self) -> bool:
        # a page with live sharers (ref > 0) always blocks the shrink
        return self.shrink_pending and all(p < self._shrink_target
                                           for p in self._ref)

    def complete_shrink(self) -> int:
        """Finish a drained shrink; returns the new pool size."""
        assert self.shrink_ready()
        self.num_pages = self._shrink_target
        self._shrink_target = 1 << 62
        return self.num_pages

    @property
    def effective_pages(self) -> int:
        """Pool size after any pending shrink lands (including sink)."""
        return min(self.num_pages, self._shrink_target)

    @property
    def capacity(self) -> int:
        """Allocatable pages after any pending shrink lands (minus sink)."""
        return self.effective_pages - 1


# ---------------------------------------------------------------------------
# prefix index: token-hash -> page chain (shared-prefix cache)
# ---------------------------------------------------------------------------

def _digest(tokens: np.ndarray) -> bytes:
    return hashlib.blake2b(np.ascontiguousarray(tokens, np.int32).tobytes(),
                           digest_size=16).digest()


def _boundary_digests(prompt: np.ndarray, n_full: int,
                      page_size: int) -> List[bytes]:
    """``_digest(prompt[:k * page_size])`` for k = 1..n_full, computed in
    one O(plen) pass: blake2b over concatenated page chunks equals the
    one-shot hash of the whole prefix, so keys are identical to per-prefix
    digests without re-hashing O(plen^2 / page_size) bytes per admission."""
    arr = np.ascontiguousarray(prompt, np.int32)
    h = hashlib.blake2b(digest_size=16)
    out = []
    for k in range(n_full):
        h.update(arr[k * page_size:(k + 1) * page_size].tobytes())
        out.append(h.copy().digest())
    return out


@dataclasses.dataclass
class PrefixHit:
    """Result of a prefix lookup, pre-capped at ``limit`` tokens.

    ``full_pages`` hold exactly ``len(full_pages) * page_size`` matched
    tokens and are shared as-is (refcount++). ``tail_page`` (if any) holds
    ``tail_len`` further matched tokens mid-page; the admitting sequence
    copy-on-write forks it before writing its own tokens into the same
    page. ``state`` is the SSM slot state at ``length`` for hybrid archs.
    """
    length: int                       # total cached tokens usable
    full_pages: List[int]
    tail_page: Optional[int] = None
    tail_len: int = 0
    state: Any = None


@dataclasses.dataclass(eq=False)          # identity equality: fields hold arrays
class _Entry:
    kind: str                         # "full" | "tail" | "exact"
    key: bytes
    tokens: np.ndarray                # the exact token prefix this entry maps
    pages: List[int]                  # page chain backing those tokens
    state: Any = None                 # SSM slot state at len(tokens) ("exact")
    dead: bool = False


class PrefixIndex:
    """Token-hash → page-chain index over the *in-flight* page pool.

    Entries reference pages owned by live sequences (the index holds no
    refcount of its own): the allocator's ``on_free`` hook invalidates
    every entry touching a page the moment its last owner releases it, so
    a hit can always be shared safely. Three entry kinds:

    * ``full`` — a full-page-aligned prefix (``k * page_size`` tokens →
      ``k`` pages), keyed by the token hash. The workhorse for dense archs.
    * ``tail`` — up to ``page_size - 1`` extra tokens inside the page after
      a ``full`` boundary; matched by longest-common-prefix so sequences
      that diverge *inside* a page still share it (COW on the hit side).
    * ``exact`` — a whole prompt with an SSM state snapshot at its length;
      hybrid archs can only resume from positions where a state exists, so
      their hits are exact-entry matches rather than per-page ones.

    A match must cover at least one full page (``page_size`` tokens):
    shorter overlaps are not worth a fork and keep accidental sharing out
    of unrelated workloads.

    Two tier-related extensions:

    * ``exact`` entries each pin a full SSM state snapshot host-side, and
      with chain retention (host tier) pages live long enough for every
      session turn to add one — unbounded growth on long persona runs.
      ``max_exact`` caps them with LRU eviction (refreshed on hit);
      ``evictions`` counts drops and ``on_evict`` (if set) observes them.
    * chains may be *host-resident*: ``swap_chain`` re-points entries at
      ``HOST_BIT``-tagged ids when a cold chain is preempted to the host
      tier (and back on swap-in). Only entries whose whole chain moves are
      remapped — the index never holds a half-swapped chain.
    """

    def __init__(self, page_size: int, max_exact: Optional[int] = 512):
        self.page_size = page_size
        self.max_exact = max_exact
        self.evictions = 0
        self.on_evict = None                      # callback(entry) on LRU drop
        self._full: Dict[bytes, _Entry] = {}
        self._tails: Dict[bytes, List[_Entry]] = {}
        self._exact: Dict[bytes, _Entry] = {}     # insertion-ordered = LRU
        self._exact_lens: Dict[int, int] = {}     # length -> entry count
        self._by_page: Dict[int, List[_Entry]] = {}

    def __len__(self) -> int:
        return len(self._full) + len(self._exact) + sum(
            len(v) for v in self._tails.values())

    # ----------------------------------------------------------- insert --
    def _track(self, e: _Entry) -> None:
        for p in e.pages:
            self._by_page.setdefault(p, []).append(e)

    def _untrack(self, e: _Entry) -> None:
        for p in e.pages:
            lst = self._by_page.get(p)
            if lst is None:
                continue
            if e in lst:
                lst.remove(e)
            if not lst:
                del self._by_page[p]

    def _drop_exact_len(self, plen: int) -> None:
        n = self._exact_lens[plen] - 1
        if n:
            self._exact_lens[plen] = n
        else:
            del self._exact_lens[plen]

    def _evict_exact(self, e: _Entry) -> None:
        e.dead = True
        del self._exact[e.key]
        self._drop_exact_len(len(e.tokens))
        self._untrack(e)
        self.evictions += 1
        if self.on_evict is not None:
            self.on_evict(e)

    def insert(self, prompt: np.ndarray, pages: List[int],
               state: Any = None) -> None:
        """Index a freshly prefilled prompt's page chain.

        With ``state`` (hybrid archs) one ``exact`` entry is added at the
        full prompt length. Without it, one ``full`` entry per page
        boundary plus a ``tail`` entry for the mid-page remainder; existing
        entries win ties (they are already shared more broadly).
        """
        ps = self.page_size
        plen = int(prompt.shape[0])
        if state is not None:
            key = _digest(prompt)
            if key in self._exact and not self._exact[key].dead:
                self._exact[key] = self._exact.pop(key)   # LRU refresh
                return
            e = _Entry("exact", key, np.array(prompt, np.int32),
                       list(pages[:pages_for_len(plen, ps)]), state=state)
            self._exact[key] = e
            self._exact_lens[plen] = self._exact_lens.get(plen, 0) + 1
            self._track(e)
            if self.max_exact is not None:
                while len(self._exact) > self.max_exact:
                    self._evict_exact(next(iter(self._exact.values())))
            return
        n_full = plen // ps
        keys = _boundary_digests(prompt, n_full, ps)
        for k in range(1, n_full + 1):
            key = keys[k - 1]
            if key in self._full and not self._full[key].dead:
                continue
            e = _Entry("full", key, np.array(prompt[:k * ps], np.int32),
                       list(pages[:k]))
            self._full[key] = e
            self._track(e)
        rem = plen % ps
        if rem and n_full >= 1:
            key = keys[n_full - 1]
            tails = self._tails.setdefault(key, [])
            tail = np.array(prompt[n_full * ps:], np.int32)
            for t in tails:
                if not t.dead and t.tokens.shape == tail.shape \
                        and bool(np.all(t.tokens == tail)):
                    return
            e = _Entry("tail", key, tail, [pages[n_full]])
            tails.append(e)
            self._track(e)

    # ----------------------------------------------------------- lookup --
    def lookup(self, prompt: np.ndarray, *, limit: Optional[int] = None,
               need_state: bool = False) -> Optional[PrefixHit]:
        """Longest cached prefix of ``prompt``, capped at ``limit`` tokens
        (callers cap at ``plen - 1`` so a hit always leaves at least one
        suffix token to produce the first output logits from)."""
        ps = self.page_size
        plen = int(prompt.shape[0])
        limit = plen if limit is None else min(limit, plen)
        if need_state:
            for L in sorted(self._exact_lens, reverse=True):
                if L > limit or L < ps:
                    continue
                e = self._exact.get(_digest(prompt[:L]))
                if e is None or e.dead or not bool(
                        np.all(e.tokens == prompt[:L])):
                    continue
                self._exact[e.key] = self._exact.pop(e.key)   # LRU refresh
                n_full, rem = L // ps, L % ps
                return PrefixHit(
                    length=L, full_pages=list(e.pages[:n_full]),
                    tail_page=e.pages[n_full] if rem else None,
                    tail_len=rem, state=e.state)
            return None
        keys = _boundary_digests(prompt, limit // ps, ps)
        for k in range(limit // ps, 0, -1):
            e = self._full.get(keys[k - 1])
            if e is None or e.dead or not bool(
                    np.all(e.tokens == prompt[:k * ps])):
                continue
            hit = PrefixHit(length=k * ps, full_pages=list(e.pages))
            room = limit - k * ps
            best = 0
            for t in self._tails.get(e.key, []):
                if t.dead:
                    continue
                n = min(len(t.tokens), room)
                lcp = int(np.argmin(np.concatenate(
                    [t.tokens[:n] == prompt[k * ps:k * ps + n], [False]])))
                if lcp > best:
                    best, hit.tail_page = lcp, t.pages[0]
            hit.tail_len = best
            hit.length += best
            return hit
        return None

    def match_len(self, prompt: np.ndarray, *, limit: Optional[int] = None,
                  need_state: bool = False) -> int:
        """Length of the longest cached prefix (0 on miss) — the router's
        prefix-affinity signal; never mutates the index."""
        hit = self.lookup(prompt, limit=limit, need_state=need_state)
        return hit.length if hit else 0

    # ------------------------------------------------------- invalidation --
    def invalidate_page(self, page: int) -> None:
        """Drop every entry whose chain contains ``page`` (wired to
        ``PageAllocator.on_free``: the page's last owner just released it,
        so its contents are about to be recycled)."""
        for e in self._by_page.pop(page, []):
            if e.dead:
                continue
            e.dead = True
            if e.kind == "full":
                if self._full.get(e.key) is e:
                    del self._full[e.key]
            elif e.kind == "exact":
                if self._exact.get(e.key) is e:
                    del self._exact[e.key]
                    self._drop_exact_len(len(e.tokens))
            else:
                tails = self._tails.get(e.key, [])
                if e in tails:
                    tails.remove(e)
                if not tails:
                    self._tails.pop(e.key, None)

    # --------------------------------------------------- tier residency --
    def swap_chain(self, mapping: Dict[int, int]) -> int:
        """Re-point entries across a tier move: every page id in
        ``mapping`` keys is about to change identity (device id →
        ``HOST_BIT``-tagged host id on swap-out, the reverse on swap-in).

        Only entries whose page chain lies *entirely* within ``mapping``
        are remapped — an entry is never left half-swapped. Entries that
        straddle the move (some pages staying put because other owners
        still hold them) are left untouched; on swap-out their dying pages
        hit ``invalidate_page`` via the allocator's ``on_free`` as usual.
        Returns the number of entries remapped.
        """
        cand: List[_Entry] = []
        seen: set = set()
        for p in mapping:
            for e in self._by_page.get(p, []):
                if not e.dead and id(e) not in seen:
                    seen.add(id(e))
                    cand.append(e)
        n = 0
        for e in cand:
            if all(p in mapping for p in e.pages):
                self._untrack(e)
                e.pages = [mapping[p] for p in e.pages]
                self._track(e)
                n += 1
        return n

    def clear(self) -> None:
        for e in list(self._full.values()) + list(self._exact.values()):
            e.dead = True
        for tails in self._tails.values():
            for e in tails:
                e.dead = True
        self._full.clear()
        self._tails.clear()
        self._exact.clear()
        self._exact_lens.clear()
        self._by_page.clear()


# ---------------------------------------------------------------------------
# cache pytree construction
# ---------------------------------------------------------------------------

def _attn_pool_leaves(cfg: ModelConfig, num_pages: int, page_size: int,
                      tp: int = 1) -> Dict[str, jnp.ndarray]:
    if cfg.attn_impl == "mla":
        raise NotImplementedError(
            "paged serving covers GQA archs; MLA decode keeps the dense "
            "compressed-cache path (see docs/serving.md)")
    hd = cfg.resolved_head_dim
    KVH = cfg.n_kv_heads
    mode = kv_quant_mode(cfg)
    kv_dt = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn,
             None: jnp.dtype(cfg.dtype)}[mode]
    if tp > 1 and KVH % tp:
        raise ValueError(f"tp={tp} must divide n_kv_heads {KVH}")
    shard = (tp,) if tp > 1 else ()
    KVH_s = KVH // tp if tp > 1 else KVH
    out = {
        "k_pages": jnp.zeros(shard + (num_pages, page_size, KVH_s, hd), kv_dt),
        "v_pages": jnp.zeros(shard + (num_pages, page_size, KVH_s, hd), kv_dt),
    }
    if cfg.cache_quant:
        out["k_scale_pages"] = jnp.zeros(shard + (num_pages, page_size, KVH_s),
                                         jnp.float32)
        out["v_scale_pages"] = jnp.zeros(shard + (num_pages, page_size, KVH_s),
                                         jnp.float32)
    return out


def _ssm_slot_leaves(cfg: ModelConfig, max_slots: int) -> Dict[str, jnp.ndarray]:
    raw = ssm_mod.ssm_cache_spec(cfg, max_slots)
    return {k: jnp.zeros(shape, jnp.dtype(str(dt)))
            for k, (shape, _axes, dt) in raw.items()}


def _layer_leaves(cfg: ModelConfig, idx: int, num_pages: int, page_size: int,
                  max_slots: int, tp: int = 1) -> Dict[str, jnp.ndarray]:
    if cfg.block_kind(idx) == "ssm":
        return _ssm_slot_leaves(cfg, max_slots)
    return _attn_pool_leaves(cfg, num_pages, page_size, tp)


def page_axis(stacked: bool, tp: int = 1) -> int:
    """Index of the page axis in an attention pool leaf: the scanned stack
    adds a leading layers axis, a shard group adds a leading shard axis
    (stack outermost: scan slices it away before model code sees leaves)."""
    return int(stacked) + int(tp > 1)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     max_slots: int, tp: int = 1) -> Any:
    """Zero page pools in the same prefix/stack pytree shape the dense cache
    uses (``repro.models.model.cache_schema``), so the transformer's scanned
    stack threads them identically. With ``tp > 1`` attention pool leaves
    carry a leading shard axis holding each shard's kv-head slice; SSM slot
    leaves stay replicated."""
    if cfg.is_encdec:
        raise NotImplementedError("paged serving targets decoder-only archs")
    prefix, period, n_periods = depth_plan(cfg)
    out: Dict[str, Any] = {}
    if prefix:
        out["prefix"] = {str(i): _layer_leaves(cfg, i, num_pages, page_size,
                                               max_slots, tp)
                         for i in range(prefix)}
    out["stack"] = {
        str(p): jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(),
            _layer_leaves(cfg, prefix + p, num_pages, page_size, max_slots,
                          tp))
        for p in range(period)}
    return out


# ---------------------------------------------------------------------------
# prefill insertion
# ---------------------------------------------------------------------------

def _shard_kv(kv: jnp.ndarray, tp: int, stacked: bool) -> jnp.ndarray:
    """Split a prefill K/V block's kv-head axis into per-shard slices.

    kv: ([L,] n, KVH, hd) -> ([L,] tp, n, KVH/tp, hd) — shard s receives
    the same contiguous head block the sharded decode path owns."""
    lead = kv.shape[:-3] if stacked else ()
    n, KVH, hd = kv.shape[-3:]
    kv = kv.reshape(lead + (n, tp, KVH // tp, hd))
    return jnp.moveaxis(kv, -3, -4)


def _write_attn_prefill(cfg: ModelConfig, node: Dict[str, jnp.ndarray],
                        pre: Dict[str, jnp.ndarray], page_ids: jnp.ndarray,
                        page_slots: jnp.ndarray, stacked: bool,
                        tp: int = 1) -> Dict[str, jnp.ndarray]:
    """Scatter one sequence's prefill K/V (B=1) into its pages.

    ``page_ids``/``page_slots``: (n_write,) int32 — padding positions past
    the live length are routed to the sink page by the caller. With
    ``tp > 1`` the prefill's full-KVH block splits into per-shard head
    slices and every shard's pool is written in this one call."""
    out = dict(node)
    n_write = page_ids.shape[0]
    # leading axes before the page axis: optional stack, optional shard
    lead = (slice(None),) * page_axis(stacked, tp)
    for name in ("k", "v"):
        kv = pre[name][..., 0, :n_write, :, :] if stacked \
            else pre[name][0, :n_write]                   # ([L,]n,KVH,hd)
        if cfg.cache_quant:
            q8, sc = quantize_kv(kv, kv_quant_mode(cfg))
            if tp > 1:
                q8, sc = _shard_kv(q8, tp, stacked), _shard_kv(
                    sc[..., None], tp, stacked)[..., 0]
            out[f"{name}_pages"] = node[f"{name}_pages"].at[
                lead + (page_ids, page_slots)].set(q8)
            out[f"{name}_scale_pages"] = node[f"{name}_scale_pages"].at[
                lead + (page_ids, page_slots)].set(sc)
        else:
            dt = node[f"{name}_pages"].dtype
            if tp > 1:
                kv = _shard_kv(kv, tp, stacked)
            out[f"{name}_pages"] = node[f"{name}_pages"].at[
                lead + (page_ids, page_slots)].set(kv.astype(dt))
    return out


def _write_ssm_prefill(node: Dict[str, jnp.ndarray],
                       pre: Dict[str, jnp.ndarray], slot,
                       stacked: bool) -> Dict[str, jnp.ndarray]:
    out = dict(node)
    for name in node:
        val = pre[name]
        if stacked:
            out[name] = node[name].at[:, slot].set(
                val[:, 0].astype(node[name].dtype))
        else:
            out[name] = node[name].at[slot].set(
                val[0].astype(node[name].dtype))
    return out


def write_prefill(cfg: ModelConfig, paged: Any, pre: Any, block_row,
                  slot, plen, n_write: int, page_size: int,
                  tp: int = 1) -> Any:
    """Insert a freshly prefilled sequence (batch 1) into the paged cache.

    ``pre`` is the cache returned by a batch-1 prefill on an ``n_write``-long
    (possibly right-padded) prompt; ``plen`` (dynamic) is the live length —
    padding positions are scattered to the sink page, so one compilation per
    prefill *bucket* serves every prompt length in it. ``block_row``:
    (n_pg,) int32 page ids for this sequence (unused tail = sink).
    Returns the updated cache pytree; jit with ``n_write``/``page_size``/
    ``tp`` static. For archs with SSM layers the caller must use ``n_write
    == plen`` — an SSM final state folds padding tokens in. Prefill always
    produces full-KVH K/V (it runs replicated across a shard group);
    ``tp > 1`` splits it into per-shard slices on insert.
    """
    t = jnp.arange(n_write)
    live = t < jnp.asarray(plen)
    page_ids = jnp.where(live, jnp.asarray(block_row)[t // page_size],
                         SINK_PAGE).astype(jnp.int32)
    page_slots = (t % page_size).astype(jnp.int32)

    def walk(node: Any, pnode: Any, stacked: bool) -> Any:
        if "k_pages" in node:
            return _write_attn_prefill(cfg, node, pnode, page_ids,
                                       page_slots, stacked, tp)
        if "h" in node and "conv" in node:
            return _write_ssm_prefill(node, pnode, slot, stacked)
        return {k: walk(node[k], pnode[k], stacked or k == "stack")
                for k in node}

    return walk(paged, pre, False)


# ---------------------------------------------------------------------------
# copy-on-write fork + SSM slot views (shared-prefix machinery)
# ---------------------------------------------------------------------------

def _is_attn(node: Any) -> bool:
    return isinstance(node, dict) and "k_pages" in node


def _is_ssm(node: Any) -> bool:
    return isinstance(node, dict) and "h" in node and "conv" in node


def copy_page(cache: Any, src, dst, tp: int = 1) -> Any:
    """COW fork: copy page ``src``'s contents into page ``dst`` in every
    attention pool leaf (all layers — and, for a shard group, every shard's
    slice in the same call: the fork is atomic across shards, so no shard
    can ever hold a forked page the others don't). Jit with the cache
    donated — the fork happens between decode ticks, exactly like a
    prefill insert."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def walk(node: Any, stacked: bool) -> Any:
        if _is_attn(node):
            axis = page_axis(stacked, tp)
            out = dict(node)
            for k in PAGE_LEAVES:
                if k not in node:
                    continue
                leaf = node[k]
                row = jax.lax.dynamic_index_in_dim(leaf, src, axis=axis)
                out[k] = jax.lax.dynamic_update_index_in_dim(
                    leaf, row, dst, axis=axis)
            return out
        if _is_ssm(node):
            return node
        return {k: walk(node[k], stacked or k == "stack") for k in node}

    return walk(cache, False)


def extract_ssm_state(pre: Any) -> Any:
    """Pull the SSM leaves (batch-1 state at the prefilled length) out of a
    prefill-produced cache (or of a stepped ``ssm_slot_view``) — the
    snapshot a hybrid prefix-index entry stores. Returns None when the arch
    has no SSM layers."""
    def walk(node: Any) -> Any:
        if not isinstance(node, dict):
            return None
        if _is_ssm(node):
            return dict(node)
        out = {k: walk(v) for k, v in node.items()}
        out = {k: v for k, v in out.items() if v is not None}
        return out or None
    return walk(pre)


def extract_ssm_slot(cache: Any, slot: int) -> Any:
    """Batch-1 snapshot of ``slot``'s SSM state sliced out of the paged
    cache — the live analogue of ``extract_ssm_state`` (which reads a
    prefill-produced cache). Chunked prefill resumes a mid-prompt sequential
    scan from it, and a disaggregation handoff carries it to the adopting
    replica. Runs eagerly (host round-trip); returns None when the arch has
    no SSM layers."""
    def walk(node: Any, stacked: bool) -> Any:
        if not isinstance(node, dict):
            return None
        if _is_attn(node):
            return None
        if _is_ssm(node):
            if stacked:
                return {k: v[:, slot:slot + 1] for k, v in node.items()}
            return {k: v[slot:slot + 1] for k, v in node.items()}
        out = {k: walk(v, stacked or k == "stack") for k, v in node.items()}
        out = {k: v for k, v in out.items() if v is not None}
        return out or None
    return walk(cache, False)


def migrate_pages(src_cache: Any, dst_cache: Any, src_pages: List[int],
                  dst_pages: List[int], tp: int = 1) -> Any:
    """Verbatim KV-page handoff between two replicas' caches.

    Copies page ``src_pages[i]`` of every attention pool leaf in
    ``src_cache`` into page ``dst_pages[i]`` of the corresponding leaf in
    ``dst_cache`` — all layers and (``tp > 1``) every shard's slice in one
    call, the same atomicity contract as ``copy_page``. The two caches must
    share layout (same arch/page_size/tp); pool *sizes* may differ — only
    the listed page ids are touched, so a prefill replica's prompt pages
    land bit-identically in a decode replica's pool. Partial trailing pages
    copy whole-page: unwritten slots are zeros on both sides. SSM slot
    state moves separately (``extract_ssm_slot`` / ``merge_ssm_slot``).
    Runs eagerly — handoffs are per-request events between ticks.
    """
    assert len(src_pages) == len(dst_pages)
    if not src_pages:
        return dst_cache
    src_ids = jnp.asarray(src_pages, jnp.int32)
    dst_ids = jnp.asarray(dst_pages, jnp.int32)

    def walk(snode: Any, dnode: Any, stacked: bool) -> Any:
        if _is_attn(dnode):
            lead = (slice(None),) * page_axis(stacked, tp)
            out = dict(dnode)
            for k in PAGE_LEAVES:
                if k not in dnode:
                    continue
                rows = snode[k][lead + (src_ids,)]
                out[k] = dnode[k].at[lead + (dst_ids,)].set(
                    rows.astype(dnode[k].dtype))
            return out
        if _is_ssm(dnode):
            return dnode
        return {k: walk(snode[k], dnode[k], stacked or k == "stack")
                for k in dnode}

    return walk(src_cache, dst_cache, False)


# ---------------------------------------------------------------------------
# host-RAM page tier (second tier of the paged pool)
# ---------------------------------------------------------------------------

class HostPageTier:
    """Host-RAM page plane: a second tier of the paged KV pool.

    Same control-plane shape as the device tier — a ``PageAllocator`` over
    its own page-id space (page 0 mirrors the sink and is never handed
    out) — but storage is host-side numpy: each resident page keeps the
    verbatim rows of every attention pool leaf (all layers, all shards,
    including int8/fp8 pools and their fp32 scale pages), keyed by the
    leaf's path in the cache pytree. Rows round-trip bit-exactly, which is
    what makes swap-in byte-identical to never having been preempted.

    Host page ids are tagged with ``HOST_BIT`` wherever they appear in
    shared id spaces (saved block-table rows, prefix-index chains); the
    tier's own allocator works on untagged ids.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 1, "host tier needs at least one page"
        # +1: id 0 mirrors the device sink so `num_pages` is the real budget
        self.alloc = PageAllocator(num_pages + 1)
        self._rows: Dict[int, Dict[str, np.ndarray]] = {}
        self.bytes_used = 0

    @property
    def capacity(self) -> int:
        return self.alloc.capacity

    @property
    def pages_used(self) -> int:
        return self.alloc.num_allocated

    def can_hold(self, n: int) -> bool:
        return self.alloc.can_alloc(n)

    def store(self, page: int, rows: Dict[str, np.ndarray]) -> None:
        """Attach leaf rows to an allocated host page (swap-out path)."""
        assert page in self.alloc._ref, f"store to unallocated host page {page}"
        old = self._rows.get(page)
        if old is not None:
            self.bytes_used -= sum(r.nbytes for r in old.values())
        self._rows[page] = rows
        self.bytes_used += sum(r.nbytes for r in rows.values())

    def rows(self, page: int) -> Dict[str, np.ndarray]:
        return self._rows[page]

    def free(self, pages: List[int]) -> None:
        """Release host pages and drop their row storage."""
        self.alloc.free(pages)
        for p in pages:
            rows = self._rows.pop(p, None)
            if rows is not None:
                self.bytes_used -= sum(r.nbytes for r in rows.values())

    def clear(self) -> None:
        """Drop every resident page (replica failure: the node's host RAM
        is gone with its HBM)."""
        live = list(self.alloc._ref)
        if live:
            self.free(live)


def swap_out_pages(cache: Any, tier: HostPageTier, pages: List[int],
                   tp: int = 1, owner: Any = None) -> List[int]:
    """Move device pages' contents to the host tier.

    Gathers page ``pages[i]`` of every attention pool leaf (all layers
    and, ``tp > 1``, every shard's slice in one pass — the same atomicity
    contract as ``migrate_pages``) into host RAM as verbatim numpy rows
    (int8/fp8 pools and fp32 scale pages byte-preserved), under freshly
    allocated host page ids. Returns the untagged host ids, parallel to
    ``pages``. The device pages are *not* freed here — the caller owns the
    device control plane and releases them (and re-points the prefix index
    via ``swap_chain``) as part of the same preemption step. SSM slot
    state travels separately (``extract_ssm_slot``), exactly as in a
    migration handoff. Runs eagerly — preemptions are between-tick events.
    """
    if not pages:
        return []
    host = tier.alloc.alloc(len(pages), owner)
    ids = jnp.asarray(pages, jnp.int32)
    rows_by_path: Dict[str, np.ndarray] = {}

    def walk(node: Any, stacked: bool, path: str) -> None:
        if _is_attn(node):
            ax = page_axis(stacked, tp)
            lead = (slice(None),) * ax
            for k in PAGE_LEAVES:
                if k not in node:
                    continue
                got = np.asarray(jax.device_get(node[k][lead + (ids,)]))
                # page axis to the front: rows_by_path[p][i] is page i's row
                rows_by_path[path + k] = np.moveaxis(got, ax, 0)
            return
        if _is_ssm(node):
            return
        for k in node:
            walk(node[k], stacked or k == "stack", path + k + "/")

    walk(cache, False, "")
    for i, h in enumerate(host):
        tier.store(h, {p: np.ascontiguousarray(r[i])
                       for p, r in rows_by_path.items()})
    return host


def swap_in_pages(cache: Any, tier: HostPageTier, host_pages: List[int],
                  dst_pages: List[int], tp: int = 1) -> Any:
    """Restore host-resident pages into device pages ``dst_pages``.

    The inverse of ``swap_out_pages``: scatters each host page's stored
    rows into page ``dst_pages[i]`` of every attention pool leaf, dtype-
    preserved, then frees the host pages. The caller allocated
    ``dst_pages`` and re-points the prefix index (``swap_chain`` with the
    tagged-host → device mapping) in the same step, so no block table or
    index entry ever observes the chain mid-move. Returns the updated
    cache pytree.
    """
    assert len(host_pages) == len(dst_pages)
    if not host_pages:
        return cache
    dst_ids = jnp.asarray(dst_pages, jnp.int32)

    def walk(node: Any, stacked: bool, path: str) -> Any:
        if _is_attn(node):
            ax = page_axis(stacked, tp)
            lead = (slice(None),) * ax
            out = dict(node)
            for k in PAGE_LEAVES:
                if k not in node:
                    continue
                rows = np.stack([tier.rows(h)[path + k] for h in host_pages])
                rows = np.moveaxis(rows, 0, ax)
                out[k] = node[k].at[lead + (dst_ids,)].set(
                    jnp.asarray(rows).astype(node[k].dtype))
            return out
        if _is_ssm(node):
            return node
        return {k: walk(node[k], stacked or k == "stack", path + k + "/")
                for k in node}

    out = walk(cache, False, "")
    tier.free(host_pages)
    return out


def swap_resume_cost(cfg: ModelConfig, tokens: int, pages: int,
                     page_size: int) -> tuple:
    """Modeled ``(transfer_s, recompute_s)`` for resuming a preempted chain.

    Transfer: PCIe setup latency plus the chain's whole-page KV bytes at
    sustained PCIe bandwidth. Recompute: re-running the prefill for the
    chain's tokens at peak FLOPs (2 * active params per token). Both sides
    are *modeled* from the roofline constants in ``repro.obs.profile`` —
    deterministic, so the swap-in-vs-re-prefill decision never depends on
    wall clock and byte-identity runs reproduce exactly. The fixed latency
    term makes short chains cheaper to recompute and long ones cheaper to
    move.
    """
    from repro.obs.profile import PCIE_BW, PCIE_LATENCY, PEAK_FLOPS
    moved = page_bytes_per_token(cfg) * pages * page_size
    transfer = PCIE_LATENCY + moved / PCIE_BW
    recompute = 2.0 * float(cfg.active_param_count()) * tokens / PEAK_FLOPS
    return transfer, recompute


def swap_crossover_tokens(cfg: ModelConfig, page_size: int,
                          max_tokens: int = 65536) -> Optional[int]:
    """Smallest chain length (tokens) where swap-in beats re-prefill, or
    None if transfer never wins below ``max_tokens`` (tiny models whose
    per-token recompute undercuts per-token PCIe traffic). The session
    bench shapes its workload around this point so both cost-model paths
    are exercised."""
    def swap_wins(T: int) -> bool:
        t, r = swap_resume_cost(cfg, T, pages_for_len(T, page_size),
                                page_size)
        return t <= r
    if not swap_wins(max_tokens):
        return None
    lo, hi = 1, max_tokens
    while lo < hi:
        mid = (lo + hi) // 2
        if swap_wins(mid):
            hi = mid
        else:
            lo = mid + 1
    return lo


def ssm_slot_view(cache: Any, state: Any) -> Any:
    """Batch-1 view of the cache for sequential suffix decode: attention
    pools shared as-is (the block-table row selects pages), SSM leaves
    replaced by ``state`` (a batch-1 snapshot). ``state=None`` (pure-attn
    or MoE archs) returns the cache unchanged."""
    if state is None:
        return cache

    def walk(node: Any, snode: Any) -> Any:
        if _is_attn(node):
            return node
        if _is_ssm(node):
            return {k: snode[k].astype(node[k].dtype) for k in node}
        return {k: walk(node[k], snode.get(k) if snode else None)
                for k in node}

    return walk(cache, state)


def ssm_leaves(cache: Any) -> Any:
    """The SSM sub-tree of the paged cache (attention pools pruned).

    The speculative verify scan emits this per step, stacking one snapshot
    per verified token along a new leading axis — the rollback ledger
    ``select_ssm_steps`` indexes into. Returns None when the arch has no
    SSM layers. Safe to call under trace (pure pytree restructuring).
    """
    def walk(node: Any) -> Any:
        if not isinstance(node, dict) or _is_attn(node):
            return None
        if _is_ssm(node):
            return dict(node)
        out = {k: walk(v) for k, v in node.items()}
        out = {k: v for k, v in out.items() if v is not None}
        return out or None
    return walk(cache)


def select_ssm_steps(cache: Any, stacked: Any, idx) -> Any:
    """Speculative rollback for hybrid archs: set each slot's SSM state to
    ``stacked[idx[slot], ..., slot, ...]``.

    ``stacked`` is the ``ssm_leaves`` tree with a leading verify-step axis
    (one snapshot per teacher-forced token, from the scan's ys); ``idx``
    (max_slots,) holds each slot's accepted draft count, so the selected
    state is the one after folding exactly the accepted tokens — the PR-6
    snapshot rule applied per step instead of per chunk. Attention pools
    pass through (rejected K/V is masked by ``seq_lens`` and overwritten
    in place later). Traceable — the verify program calls it in-dispatch.
    """
    idx = jnp.asarray(idx, jnp.int32)

    def walk(node: Any, snode: Any, stacked_ax: bool) -> Any:
        if _is_attn(node):
            return node
        if _is_ssm(node):
            out = {}
            for k in node:
                s = snode[k]              # (steps, [L,] max_slots, ...)
                slot_ax = 2 if stacked_ax else 1
                ish = [1] * s.ndim
                ish[slot_ax] = s.shape[slot_ax]
                ix = idx.reshape(ish)
                out[k] = jnp.take_along_axis(s, ix, axis=0)[0].astype(
                    node[k].dtype)
            return out
        return {k: walk(node[k], snode[k], stacked_ax or k == "stack")
                for k in node}

    return walk(cache, stacked, False)


def merge_ssm_slot(cache: Any, view: Any, slot) -> Any:
    """Fold a stepped batch-1 view back: attention pools are taken from the
    view (they were updated in place), SSM leaves written at ``slot``."""
    slot = jnp.asarray(slot, jnp.int32)

    def walk(node: Any, vnode: Any, stacked: bool) -> Any:
        if _is_attn(node):
            return vnode
        if _is_ssm(node):
            out = {}
            for k in node:
                val = vnode[k].astype(node[k].dtype)
                if stacked:
                    out[k] = jax.vmap(
                        lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                            buf, v, slot, axis=0))(node[k], val[:, 0])
                else:
                    out[k] = jax.lax.dynamic_update_index_in_dim(
                        node[k], val[0], slot, axis=0)
            return out
        return {k: walk(node[k], vnode[k], stacked or k == "stack")
                for k in node}

    return walk(cache, view, False)


# ---------------------------------------------------------------------------
# live resize (the autoscaler's actuation path)
# ---------------------------------------------------------------------------

def _resize_axis(leaf: jnp.ndarray, axis: int, new: int) -> jnp.ndarray:
    """Grow (zero-pad) or shrink (slice) one leaf along ``axis``."""
    cur = leaf.shape[axis]
    if new == cur:
        return leaf
    if new > cur:
        pad_shape = leaf.shape[:axis] + (new - cur,) + leaf.shape[axis + 1:]
        return jnp.concatenate([leaf, jnp.zeros(pad_shape, leaf.dtype)],
                               axis=axis)
    idx = [slice(None)] * leaf.ndim
    idx[axis] = slice(0, new)
    return leaf[tuple(idx)]


def resize_cache_pages(cache: Any, new_num_pages: int, tp: int = 1) -> Any:
    """Resize every page pool to ``new_num_pages``.

    Growth appends zero pages — existing page ids (and everything any block
    table references) are untouched, so decoded tokens are unaffected.
    Shrink slices the tail; the caller (scheduler) guarantees every page
    with id >= ``new_num_pages`` is free and out of every live block table
    before calling. Every shard's pool resizes in the same call (the
    logical page-id space is shared). SSM slot leaves are untouched. Runs
    eagerly (outside jit) — resizes are rare, bucketed events.
    """
    def walk(node: Any, stacked: bool) -> Any:
        if "k_pages" in node:
            axis = page_axis(stacked, tp)
            return {k: (_resize_axis(v, axis, new_num_pages)
                        if k in PAGE_LEAVES else v) for k, v in node.items()}
        if "h" in node and "conv" in node:
            return node
        return {k: walk(node[k], stacked or k == "stack") for k in node}

    return walk(cache, False)


def resize_cache_slots(cache: Any, new_slots: int) -> Any:
    """Resize the dense per-slot SSM state rows to ``new_slots``.

    New slots get zero state — identical to a fresh ``init_paged_cache``
    slot, so a request later admitted there prefills exactly as it would
    have at construction time. Shrink slices the tail; the caller drains
    those slots first. Attention page pools are untouched (they have no
    slot axis).
    """
    def walk(node: Any, stacked: bool) -> Any:
        if "k_pages" in node:
            return node
        if "h" in node and "conv" in node:
            axis = 1 if stacked else 0
            return {k: _resize_axis(v, axis, new_slots)
                    for k, v in node.items()}
        return {k: walk(node[k], stacked or k == "stack") for k in node}

    return walk(cache, False)


# ---------------------------------------------------------------------------
# sizing helpers (used by core.blueprint.serving_page_plan and the bench)
# ---------------------------------------------------------------------------

def page_bytes_per_token(cfg: ModelConfig) -> int:
    """KV bytes one token occupies across all attention layers' pools."""
    hd, KVH = cfg.resolved_head_dim, cfg.n_kv_heads
    per = 2 * KVH * hd * (1 if cfg.cache_quant else 2)
    if cfg.cache_quant:
        per += 2 * KVH * 4                       # fp32 scales
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.block_kind(i) != "ssm")
    return per * n_attn


def shard_page_bytes_per_token(cfg: ModelConfig, tp: int) -> int:
    """KV bytes one token occupies on *one shard* of a ``tp``-way group —
    the per-member slice of ``page_bytes_per_token``. Exact: every byte
    term is proportional to ``n_kv_heads``, which ``tp`` must divide."""
    total = page_bytes_per_token(cfg)
    if tp > 1 and cfg.n_kv_heads % tp:
        raise ValueError(f"tp={tp} must divide n_kv_heads {cfg.n_kv_heads}")
    return total // tp


def pool_bytes(cfg: ModelConfig, num_pages: int, page_size: int,
               tp: int = 1) -> int:
    """HBM the page pools occupy: all layers, one shard's slice when
    ``tp > 1`` (multiply by ``tp`` for the whole group)."""
    return shard_page_bytes_per_token(cfg, tp) * num_pages * page_size


def dense_cache_bytes(cfg: ModelConfig, batch: int, capacity: int) -> int:
    """Footprint of the dense engine's capacity-padded ring buffers, for the
    memory comparison in ``benchmarks/serve_bench.py``."""
    return page_bytes_per_token(cfg) * batch * capacity


def migration_bytes(cfg: ModelConfig, num_pages: int, page_size: int) -> int:
    """KV bytes a disaggregation page handoff moves for ``num_pages``
    donor pages — all layers, all shards (the whole logical page travels
    whatever the tp split is). The router's trace detail for
    ``page_migration`` events."""
    return page_bytes_per_token(cfg) * num_pages * page_size
