"""Global model-tracing flags.

``unroll_scans`` — when True, model code unrolls its internal lax.scans
(layer stack, attention kv loop, loss chunks) into python loops. Used by the
dry-run's roofline measurement: XLA's cost_analysis counts a while-loop body
once, so accurate FLOP/byte/collective accounting needs unrolled HLO. The
dry-run compiles unrolled 1-period and 2-period depth variants and
extrapolates linearly (exact for homogeneous periods).

``prefill_kernel`` — when True, the fused chunked-prefill path
(``attention._paged_prefill_write_attend``) dispatches the Pallas
write+attend kernel pair (``repro.kernels.paged_prefill``) instead of the
XLA scatter+gather. Consulted at *trace* time, so wrap the flag around the
jit'd call (the scheduler bakes it into each cached program — the flag
value is part of the program-cache key).
"""
from __future__ import annotations

import contextlib
import threading

_STATE = threading.local()


def unroll_scans() -> bool:
    return getattr(_STATE, "unroll", False)


@contextlib.contextmanager
def use_unrolled_scans(on: bool = True):
    prev = unroll_scans()
    _STATE.unroll = on
    try:
        yield
    finally:
        _STATE.unroll = prev


def prefill_kernel() -> bool:
    return getattr(_STATE, "prefill_kernel", False)


@contextlib.contextmanager
def use_prefill_kernel(on: bool = True):
    prev = prefill_kernel()
    _STATE.prefill_kernel = on
    try:
        yield
    finally:
        _STATE.prefill_kernel = prev
