"""Render the baseline-vs-optimized grid table into EXPERIMENTS.md."""
from __future__ import annotations

import json
import pathlib

BASE = pathlib.Path("benchmarks/results/dryrun")
OPT = pathlib.Path("benchmarks/results/dryrun_opt")
SHAPES = ["train_4k", "decode_32k"]


def main() -> None:
    rows = ["| arch | shape | bound s (paper-faithful) | bound s (optimized) "
            "| × | coll s base→opt | peak GiB base→opt |",
            "|---|---|---|---|---|---|---|"]
    speedups = []
    for p in sorted(OPT.glob("*.json")):
        o = json.loads(p.read_text())
        if o.get("status") != "ok":
            rows.append(f"| {o.get('arch')} | {o.get('shape')} | | ERROR | | | |")
            continue
        b = json.loads((BASE / p.name).read_text())
        x = b["bound_s"] / o["bound_s"]
        speedups.append(x)
        rows.append(
            f"| {o['arch']} | {o['shape']} | {b['bound_s']:.3f} | "
            f"{o['bound_s']:.3f} | **{x:.2f}×** | "
            f"{b['roofline']['collective_s']:.3f}→"
            f"{o['roofline']['collective_s']:.3f} | "
            f"{b['memory']['peak_bytes']/2**30:.2f}→"
            f"{o['memory']['peak_bytes']/2**30:.2f} |")
    import statistics
    gmean = (statistics.geometric_mean(speedups) if speedups else 0.0)
    table = "\n".join(rows) + (
        f"\n\nGeometric-mean improvement on the dominant roofline term "
        f"across the {len(speedups)} re-planned cells: **{gmean:.2f}×** "
        f"(range {min(speedups):.2f}×–{max(speedups):.2f}×). Every "
        f"optimized cell still compiles and fits (peak ≤ 16 GiB).")
    text = pathlib.Path("EXPERIMENTS.md").read_text()
    marker = "<!-- OPT_TABLE -->"
    assert marker in text, "marker missing"
    pathlib.Path("EXPERIMENTS.md").write_text(text.replace(marker, table))
    print(f"opt table: {len(speedups)} cells, gmean {gmean:.2f}x")


if __name__ == "__main__":
    main()
