"""Request/stream lifecycle shared by every serving engine.

A ``Request`` is one generation stream: a prompt, a token budget, and the
bookkeeping both engines fill in as the stream moves through its states::

    WAITING ──admit──► [PREFILLING] ──► ACTIVE ──budget spent──► FINISHED
      (queued; arrival   (chunked prefill  (prefilled; decoding    (finish_step
       gate not yet due,  only: prompt      greedily, one token     recorded, pages
       or no capacity)    chunks land       per scheduler tick)     freed by the
                          across ticks)                             owning engine)

PREFILLING exists only under chunked prefill (``prefill_budget`` set on
the scheduler): the request owns a slot and its prompt pages, but its
prompt is still landing chunk by chunk — ``prefill_pos`` is the chunk
cursor (prompt tokens whose K/V are already in the pages). Monolithic
admission prefills in one call and never passes through the state.

The dataclass lives here — not in ``scheduler.py`` — because three layers
share it: the continuous-batching scheduler admits/decodes/evicts single
requests, the static engine (``repro.serving.engine.serve_requests``)
serves whole groups of them, and the replicated-fabric router
(``repro.serving.router``) owns the fleet arrival queue and moves requests
*between* schedulers when a replica drains or dies. Clock fields
(``arrival_step``/``admit_step``/``finish_step``) are ticks on whichever
clock the owning engine runs; the router overwrites them with fleet-clock
values so latency is comparable across replicas added at different times.

Greedy-token bookkeeping: ``out_tokens`` accumulates the argmax token per
step, the prefill's last-position token included, so ``done`` is simply
``len(out_tokens) >= max_new_tokens``. On a re-route (replica death) the
router re-prefills ``prompt + out_tokens`` elsewhere and appends the
continuation's tokens here — token-identical for dense/SSM archs, where a
greedy continuation depends only on its prefix.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    ACTIVE = "active"
    # ACTIVE with draft tokens in flight: the scheduler verified (or is
    # about to verify) speculative drafts for this stream this tick.
    # Speculation never changes emitted tokens — greedy accept keeps the
    # byte-identity contract — so SPECULATING is observability, not a new
    # lifecycle stage: the stream still finishes through ACTIVE semantics.
    SPECULATING = "speculating"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (plen,) int32
    max_new_tokens: int
    arrival_step: int = 0                 # earliest tick it may be admitted
    # filled in by the serving engine
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    admit_step: Optional[int] = None
    finish_step: Optional[int] = None
    # prefix-cache bookkeeping: prompt tokens whose prefill was skipped
    # because a shared prefix already held their K/V (0 on a miss, and
    # always 0 on the static engine, which cannot share)
    cached_tokens: int = 0
    # chunked-prefill cursor: prompt tokens already landed in pages (None
    # outside a chunked prefill — monolithic admission never sets it)
    prefill_pos: Optional[int] = None
    # filled in by the fabric router (single-engine runs leave the defaults)
    replica: Optional[int] = None         # replica currently decoding this
    reroutes: int = 0                     # re-prefills after a replica loss
    migrations: int = 0                   # verbatim KV-page handoffs (disagg)
    # speculative-decoding bookkeeping (spec_k set on the scheduler):
    # draft tokens proposed / accepted for this stream, and whether drafts
    # were in flight on the most recent verify tick
    spec_drafted: int = 0
    spec_accepted: int = 0
    speculating: bool = False
    # SLO scheduling: admission orders due requests by priority class
    # (higher first; FCFS within a class), and under HBM pressure lower
    # classes' cold chains are preempted to the host tier first. The
    # tenant tags the stream for per-tenant page quotas.
    priority: int = 1
    tenant: str = "default"
    # host-tier bookkeeping: chains restored from host RAM / prompts
    # re-prefilled because the cost model chose recompute on preemption
    swap_ins: int = 0

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    @property
    def state(self) -> RequestState:
        if self.finish_step is not None or self.done:
            return RequestState.FINISHED
        if self.admit_step is not None:
            if self.prefill_pos is not None:
                return RequestState.PREFILLING
            if self.speculating:
                return RequestState.SPECULATING
            return RequestState.ACTIVE
        return RequestState.WAITING

    @property
    def remaining_tokens(self) -> int:
        return max(self.max_new_tokens - len(self.out_tokens), 0)


def make_request(rid: int, prompt, max_new_tokens: int,
                 arrival_step: int = 0, priority: int = 1,
                 tenant: str = "default") -> Request:
    """Validate and build a request (shared by scheduler/router submit)."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    if max_new_tokens < 1:
        raise ValueError("max_new_tokens must be >= 1 (the prefill "
                         "already produces the first token)")
    if priority < 0:
        raise ValueError("priority must be >= 0")
    return Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                   arrival_step=arrival_step, priority=int(priority),
                   tenant=str(tenant))


def worst_case_pages(req: Request, page_size: int) -> int:
    """Pages admission must reserve so the stream can never OOM mid-flight."""
    total = req.plen + req.max_new_tokens
    return -(-total // page_size)
