"""Production mesh construction (assignment-mandated shapes)."""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    # jax < 0.5 has no AxisType; every axis defaults to Auto there anyway
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh_for(n_data: int, n_model: int, n_pod: int = 1):
    """Smaller meshes for subprocess SPMD tests and elastic resize."""
    if n_pod > 1:
        return jax.make_mesh((n_pod, n_data, n_model),
                             ("pod", "data", "model"), **_axis_kwargs(3))
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **_axis_kwargs(2))
