"""HeartbeatMonitor state-transition properties (hypothesis).

The monitor classifies hosts from heartbeat silence and step-time EWMAs;
these properties pin the transition system the autoscale control plane
relies on: silence thresholds are honoured exactly, DEAD is absorbing,
a beat recovers SUSPECT/STRAGGLER, the straggler callback has hysteresis
(fires on the transition, not per check), and callbacks fire exactly once
per death.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.heartbeat import HeartbeatMonitor, HostState

# beat offsets as integer deciseconds to avoid float-equality edge cases
# exactly on a threshold boundary
beat_times = st.lists(st.integers(min_value=0, max_value=1200),
                      min_size=0, max_size=20, unique=True)


def make(interval=10.0, **kw):
    return HeartbeatMonitor(interval=interval, suspect_after=2.5,
                            dead_after=6.0, **kw)


@given(beats=beat_times, check_at=st.integers(min_value=0, max_value=1500))
@settings(max_examples=200, deadline=None)
def test_silence_classification_matches_thresholds(beats, check_at):
    """After any beat pattern, a single check classifies purely from the
    silence since the last beat (no step times involved)."""
    mon = make()
    mon.register("h", now=0.0)
    for t in sorted(beats):
        mon.beat("h", float(t))
    state = mon.check(float(check_at))["h"]
    last = max([0.0] + [float(t) for t in beats])
    silence = check_at - last
    if silence > 6.0 * 10.0:
        assert state == HostState.DEAD
    elif silence > 2.5 * 10.0:
        assert state == HostState.SUSPECT
    else:
        assert state == HostState.ALIVE


@given(beats=beat_times)
@settings(max_examples=100, deadline=None)
def test_dead_is_absorbing_and_callback_fires_once(beats):
    """Once DEAD, later beats and checks never resurrect the host, and the
    on_dead callback fired exactly once."""
    mon = make()
    deaths = []
    mon.on_dead(deaths.append)
    mon.register("h", now=0.0)
    mon.check(100.0)                        # silence 100 > 60 -> DEAD
    assert mon.hosts["h"].state == HostState.DEAD
    for t in sorted(beats):
        mon.beat("h", 100.0 + t)
        assert mon.hosts["h"].state == HostState.DEAD
    mon.check(100.0 + 1300.0)
    assert deaths == ["h"]
    assert "h" not in mon.alive()


@given(silence=st.floats(min_value=25.1, max_value=60.0,
                         exclude_max=True, allow_nan=False))
@settings(max_examples=50, deadline=None)
def test_beat_recovers_suspect(silence):
    mon = make()
    mon.register("h", now=0.0)
    state = mon.check(silence)["h"]
    assert state == HostState.SUSPECT
    mon.beat("h", silence)
    assert mon.hosts["h"].state == HostState.ALIVE
    assert mon.check(silence + 1.0)["h"] == HostState.ALIVE


@given(factor=st.floats(min_value=2.0, max_value=10.0, allow_nan=False),
       n_checks=st.integers(min_value=1, max_value=6))
@settings(max_examples=50, deadline=None)
def test_straggler_hysteresis_fires_on_transition_only(factor, n_checks):
    """A host ``factor``x slower than the median is STRAGGLER, the callback
    fires once per episode however many checks run, and a recovery beat +
    fast step re-arms it."""
    mon = make(straggler_factor=1.8)
    flagged = []
    mon.on_straggler(flagged.append)
    for i in range(4):
        mon.register(f"h{i}", now=0.0)
    for step in range(1, 4):
        t = step * 5.0
        for i in range(4):
            mon.beat(f"h{i}", t, step_time=1.0 if i < 3 else factor)
    for k in range(n_checks):
        states = mon.check(16.0 + k)
        assert states["h3"] == HostState.STRAGGLER
        assert states["h0"] == HostState.ALIVE
    assert flagged == ["h3"]                # hysteresis: one episode, one call
    # recovery: fast beats pull the EWMA back under the straggler bound
    for step in range(12):
        mon.beat("h3", 20.0 + step, step_time=1.0)
    assert mon.hosts["h3"].state == HostState.ALIVE   # beat() recovers it
    states = mon.check(21.0 + 12)
    assert states["h3"] == HostState.ALIVE
    # a fresh slow spell is a new episode: callback fires again
    for step in range(1, 10):
        t = 40.0 + step
        for i in range(4):
            mon.beat(f"h{i}", t, step_time=1.0 if i < 3 else 10.0 * factor)
    mon.check(50.0)
    assert flagged == ["h3", "h3"]


@given(beats=beat_times)
@settings(max_examples=50, deadline=None)
def test_alive_listing_consistent_with_states(beats):
    """``alive()`` is exactly the ALIVE + STRAGGLER hosts."""
    mon = make()
    mon.register("a", now=0.0)
    mon.register("b", now=0.0)
    for t in sorted(beats):
        mon.beat("a", float(t))
    states = mon.check(70.0)
    want = {h for h, s in states.items()
            if s in (HostState.ALIVE, HostState.STRAGGLER)}
    assert set(mon.alive()) == want
