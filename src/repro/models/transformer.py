"""Transformer assembly: blocks, scan-over-periods stacking, enc-dec.

Depth is organised as ``prefix`` (unrolled layers, e.g. deepseek's first
dense layer) + ``stack`` (a period of block kinds scanned ``n_periods``
times with params stacked on a leading "layers" dim). The period is
``lcm(len(layer_pattern), moe_period)`` so every scanned position has a
uniform kind across scan steps (jamba: 8, gemma2: 2, most: 1).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed, embed_schema, logits, mlp, mlp_schema,
                                 rmsnorm, rmsnorm_schema)
from repro.models.rope import rope_cos_sin
from repro.models.schema import ParamSpec, is_spec
from repro.parallel.context import constrain


def _maybe_scan(body, carry, xs, length: int):
    """lax.scan, or an unrolled python loop when the dry-run measurement flag
    is set (XLA cost_analysis counts while bodies once)."""
    from repro.models.flags import unroll_scans
    if not unroll_scans():
        return jax.lax.scan(body, carry, xs)
    ys_list = []
    for c in range(length):
        xs_c = jax.tree.map(lambda a: a[c], xs)
        carry, y = body(carry, xs_c)
        ys_list.append(y)
    if ys_list and ys_list[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------- depth plan

def depth_plan(cfg: ModelConfig) -> Tuple[int, int, int]:
    """-> (n_prefix_layers, period, n_periods)."""
    pat = len(cfg.layer_pattern)
    period = pat
    if cfg.n_routed_experts:
        period = math.lcm(pat, cfg.moe_period)
    prefix = cfg.first_k_dense
    rest = cfg.n_layers - prefix
    assert rest % period == 0, (cfg.name, rest, period)
    return prefix, period, rest // period


def stack_schema(tree: Any, n: int) -> Any:
    if is_spec(tree):
        return ParamSpec((n,) + tree.shape, ("layers",) + tree.axes,
                         init=tree.init, dtype=tree.dtype, fan_in=tree.fan_in)
    return {k: stack_schema(v, n) for k, v in tree.items()}


# ---------------------------------------------------------------- blocks

def block_schema(cfg: ModelConfig, idx: int) -> Dict[str, Any]:
    kind = cfg.block_kind(idx)
    d: Dict[str, Any] = {"ln1": rmsnorm_schema(cfg.d_model)}
    d["mixer"] = (ssm_mod.ssm_schema(cfg) if kind == "ssm"
                  else attn.attn_schema(cfg))
    if cfg.is_moe_layer(idx):
        d["ln2"] = rmsnorm_schema(cfg.d_model)
        d["ffn"] = moe_mod.moe_schema(cfg)
    elif cfg.d_ff > 0:  # mamba2: mixer-only blocks, no FFN
        d["ln2"] = rmsnorm_schema(cfg.d_model)
        d["ffn"] = mlp_schema(cfg, cfg.d_ff)
    if cfg.use_post_norm:
        d["post_ln1"] = rmsnorm_schema(cfg.d_model)
        d["post_ln2"] = rmsnorm_schema(cfg.d_model)
    return d


def block_apply(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray, idx: int,
                cos, sin, mode: str, cache: Optional[Dict] = None,
                cur_len: Optional[jnp.ndarray] = None,
                block_table: Optional[jnp.ndarray] = None,
                shard=None, chunk_len: Optional[jnp.ndarray] = None):
    """-> (x, aux, cache_update). ``shard`` (a ShardGroup) activates the
    tensor-parallel paged-decode path: head-sharded attention over per-shard
    page pools, expert-sharded MoE; SSM mixers stay replicated (their state
    is O(1) per sequence — nothing to split). ``mode == "paged_prefill"``
    lands a chunk (x: (B,S,D), live rows per ``chunk_len``) directly into
    the pages at offset ``cur_len`` — a prompt chunk, or a speculative
    verify batch of last-token+drafts rows (attention-only archs — SSM/MoE
    archs keep the exact sequential path, see scheduler)."""
    kind = cfg.block_kind(idx)
    local = kind == "attn_local"
    h = rmsnorm(x, p["ln1"], cfg.rms_eps)
    cache_update = None
    if kind == "ssm":
        if mode == "paged_prefill":
            raise NotImplementedError(
                "fused paged prefill covers attention-only archs; SSM archs "
                "use the exact sequential chunk path")
        if mode == "train":
            mix = ssm_mod.ssm_train(cfg, p["mixer"], h)
        elif mode == "prefill":
            mix, cache_update = ssm_mod.ssm_prefill(cfg, p["mixer"], h)
        else:
            mix, cache_update = ssm_mod.ssm_decode(cfg, p["mixer"], h, cache)
    else:
        if mode == "train":
            mix = attn.attn_train(cfg, p["mixer"], h, cos, sin, local=local)
        elif mode == "prefill":
            mix, cache_update = attn.attn_prefill(cfg, p["mixer"], h, cos, sin,
                                                  local=local)
        elif mode == "paged_decode":
            mix, cache_update = attn.attn_paged_decode(
                cfg, p["mixer"], h, cos, sin, cache, cur_len, block_table,
                local=local, shard=shard)
        elif mode == "paged_prefill":
            mix, cache_update = attn.attn_paged_prefill(
                cfg, p["mixer"], h, cos, sin, cache, cur_len, chunk_len,
                block_table, local=local, shard=shard)
        else:
            mix, cache_update = attn.attn_decode(cfg, p["mixer"], h, cos, sin,
                                                 cache, cur_len, local=local)
    if cfg.use_post_norm:
        mix = rmsnorm(mix, p["post_ln1"], cfg.rms_eps)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
        if cfg.is_moe_layer(idx):
            ff, aux = moe_mod.moe_apply(
                cfg, p["ffn"], h2,
                decode=(mode in ("decode", "paged_decode")),
                shard=shard if mode == "paged_decode" else None)
        else:
            ff = mlp(cfg, p["ffn"], h2)
        if cfg.use_post_norm:
            ff = rmsnorm(ff, p["post_ln2"], cfg.rms_eps)
        x = x + ff
    x = constrain(x, ("batch", None, None))
    return x, aux, cache_update


# ------------------------------------------------------------- full schema

def lm_schema(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.is_encdec:
        return _encdec_schema(cfg)
    prefix, period, n_periods = depth_plan(cfg)
    sch: Dict[str, Any] = {"embed": embed_schema(cfg),
                           "final_ln": rmsnorm_schema(cfg.d_model)}
    if prefix:
        sch["prefix"] = {str(i): block_schema(cfg, i) for i in range(prefix)}
    sch["stack"] = {str(p): stack_schema(block_schema(cfg, prefix + p), n_periods)
                    for p in range(period)}
    return sch


# -------------------------------------------------------------- positions

def default_positions(cfg: ModelConfig, batch: int, seq: int,
                      offset=0) -> jnp.ndarray:
    pos = offset + jnp.arange(seq, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_variant == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


# ---------------------------------------------------------------- forward

def lm_forward(cfg: ModelConfig, params: Dict[str, Any], tokens: jnp.ndarray,
               positions: Optional[jnp.ndarray] = None, *, mode: str = "train",
               cache: Optional[Dict] = None, cur_len=None,
               block_table: Optional[jnp.ndarray] = None,
               remat: str = "none", shard=None, chunk_len=None):
    """Decoder-only forward.

    train        -> (hidden, aux)
    prefill      -> (hidden, aux, cache)
    decode       -> (hidden, aux, cache)   tokens: (B, 1)
    paged_decode -> (hidden, aux, cache)   tokens: (B, 1); ``cache`` holds
        page pools (``repro.serving.paged_cache``), ``cur_len`` is the (B,)
        per-sequence length vector and ``block_table`` (B, n_pg) maps each
        sequence to its pages — this is what lets the continuous-batching
        scheduler decode sequences of different lengths in one step.
        ``shard`` (a ``repro.parallel.context.ShardGroup``, tp > 1) selects
        the tensor-parallel path: pool leaves carry a leading shard axis
        and attention/MoE split across the group (docs/sharding.md).
    paged_prefill -> (hidden, aux, cache)  tokens: (B, S) one prompt chunk
        per sequence; ``cur_len`` (B,) tokens already landed in the pages
        (chunk row t sits at absolute position cur_len+t), ``chunk_len``
        (B,) live rows. The chunk's K/V is written directly into the pages
        and its queries attend prefix+chunk in the same pass (fused
        chunked prefill — no dense intermediate, no ``write_prefill``).
        Speculative verify (``model.paged_verify_step``) rides the same
        mode with a last-token+drafts chunk per decoding slot, so the
        batch is the full slot table and ``chunk_len`` may be 0.
    """
    assert not cfg.is_encdec
    B, S = tokens.shape
    decoding = mode in ("decode", "paged_decode", "paged_prefill")
    prefix, period, n_periods = depth_plan(cfg)
    if positions is None:
        if mode == "paged_prefill":
            cl = jnp.asarray(cur_len, jnp.int32).reshape(-1)
            base = cl[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
            positions = jnp.broadcast_to(base, (B, S))
            if cfg.rope_variant == "mrope":
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        elif decoding:
            cl = jnp.asarray(cur_len, jnp.int32)
            base = jnp.broadcast_to(
                cl[None, None] if cl.ndim == 0 else
                cl[:, None] if cl.ndim == 1 else cl, (B, 1))
            positions = base
            if cfg.rope_variant == "mrope":
                positions = jnp.broadcast_to(base[None], (3, B, 1))
        else:
            positions = default_positions(cfg, B, S)
    cos, sin = rope_cos_sin(cfg, positions)

    x = embed(cfg, params["embed"], tokens)
    x = constrain(x, ("batch", None, None))
    aux_total = jnp.zeros((), jnp.float32)

    # ---- prefix layers (unrolled) ---------------------------------------
    prefix_cache_out = {}
    for i in range(prefix):
        c_in = cache["prefix"][str(i)] if (cache and decoding) else None
        x, aux, c_out = block_apply(cfg, params["prefix"][str(i)], x, i,
                                    cos, sin, mode, c_in, cur_len,
                                    block_table, shard, chunk_len=chunk_len)
        aux_total = aux_total + aux
        if c_out is not None:
            prefix_cache_out[str(i)] = c_out

    # ---- scanned stack ----------------------------------------------------
    stack_params = params["stack"]

    if mode == "train":
        def body(carry, xs_p):
            xx, aux_c = carry
            for p in range(period):
                xx, aux, _ = block_apply(cfg, xs_p[str(p)], xx, prefix + p,
                                         cos, sin, "train")
                aux_c = aux_c + aux
            return (xx, aux_c), None

        if remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif remat == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        (x, aux_total), _ = _maybe_scan(body, (x, aux_total), stack_params,
                                        n_periods)

    elif mode == "prefill":
        def body(carry, xs_p):
            xx, aux_c = carry
            outs = {}
            for p in range(period):
                xx, aux, c_out = block_apply(cfg, xs_p[str(p)], xx, prefix + p,
                                             cos, sin, "prefill")
                aux_c = aux_c + aux
                outs[str(p)] = c_out
            return (xx, aux_c), outs

        (x, aux_total), stack_cache = _maybe_scan(body, (x, aux_total),
                                                  stack_params, n_periods)
        cache_out = {"stack": stack_cache}
        if prefix_cache_out:
            cache_out["prefix"] = prefix_cache_out

    else:  # decode / paged_decode / paged_prefill
        def body(xx, xs_p):
            ps, cs = xs_p
            new_cs = {}
            for p in range(period):
                xx, _, c_out = block_apply(cfg, ps[str(p)], xx, prefix + p,
                                           cos, sin, mode, cs[str(p)],
                                           cur_len, block_table, shard,
                                           chunk_len=chunk_len)
                new_cs[str(p)] = c_out
            return xx, new_cs

        x, stack_cache = _maybe_scan(body, x, (stack_params, cache["stack"]),
                                     n_periods)
        cache_out = {"stack": stack_cache}
        if prefix_cache_out:
            cache_out["prefix"] = prefix_cache_out

    x = rmsnorm(x, params["final_ln"], cfg.rms_eps)
    if mode == "train":
        return x, aux_total
    return x, aux_total, cache_out


# ---------------------------------------------------------------------------
# encoder-decoder (whisper): small depth -> unrolled
# ---------------------------------------------------------------------------

def _xattn_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H = cfg.n_heads
    return {
        "wq": ParamSpec((d, H * hd), ("embed", "heads")),
        "wk": ParamSpec((d, H * hd), ("embed", "heads")),
        "wv": ParamSpec((d, H * hd), ("embed", "heads")),
        "wo": ParamSpec((H * hd, d), ("heads", "embed")),
    }


def _encdec_schema(cfg: ModelConfig) -> Dict[str, Any]:
    sch: Dict[str, Any] = {"embed": embed_schema(cfg)}
    sch["dec_pos"] = ParamSpec((36864, cfg.d_model), ("pos", None),
                               init="embed")
    sch["enc"] = {str(i): {
        "ln1": rmsnorm_schema(cfg.d_model),
        "mixer": attn.gqa_schema(cfg),
        "ln2": rmsnorm_schema(cfg.d_model),
        "ffn": mlp_schema(cfg, cfg.d_ff),
    } for i in range(cfg.n_enc_layers)}
    sch["enc_ln"] = rmsnorm_schema(cfg.d_model)
    sch["dec"] = {str(i): {
        "ln1": rmsnorm_schema(cfg.d_model),
        "mixer": attn.gqa_schema(cfg),
        "ln_x": rmsnorm_schema(cfg.d_model),
        "xattn": _xattn_schema(cfg),
        "ln2": rmsnorm_schema(cfg.d_model),
        "ffn": mlp_schema(cfg, cfg.d_ff),
    } for i in range(cfg.n_layers)}
    sch["final_ln"] = rmsnorm_schema(cfg.d_model)
    return sch


def _sinusoid(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encoder_forward(cfg: ModelConfig, params, enc_embeds: jnp.ndarray):
    """enc_embeds: (B, T, D) precomputed frame embeddings (conv stub)."""
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    for i in range(cfg.n_enc_layers):
        p = params["enc"][str(i)]
        h = rmsnorm(x, p["ln1"], cfg.rms_eps)
        x = x + attn.gqa_train(cfg, p["mixer"], h, None, None, local=False,
                               causal=False)
        h = rmsnorm(x, p["ln2"], cfg.rms_eps)
        x = x + mlp(cfg, p["ffn"], h)
    return rmsnorm(x, params["enc_ln"], cfg.rms_eps)


def _cross_attend(cfg, p, x, enc_kv):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    o = attn.attend(q, enc_kv["k"], enc_kv["v"], causal=False)
    return o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def _cross_kv(cfg, p, enc_out):
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(B, T, cfg.n_heads, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(B, T, cfg.n_heads, hd)
    return {"k": k, "v": v}


def encdec_forward(cfg: ModelConfig, params, tokens, enc_embeds=None, *,
                   mode="train", cache=None, cur_len=None, remat="none"):
    """Whisper-style enc-dec. train/prefill need enc_embeds; decode uses the
    cross-kv stored in the cache."""
    B, S = tokens.shape
    x = embed(cfg, params["embed"], tokens)
    if mode == "decode":
        pos = jnp.broadcast_to(jnp.reshape(cur_len, (1, 1)), (B, 1))
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = x + jnp.take(params["dec_pos"], pos, axis=0).astype(x.dtype)

    if mode != "decode":
        enc_out = encoder_forward(cfg, params, enc_embeds)

    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {"self": {}, "cross": {}}
    for i in range(cfg.n_layers):
        p = params["dec"][str(i)]
        h = rmsnorm(x, p["ln1"], cfg.rms_eps)
        if mode == "train":
            mix = attn.gqa_train(cfg, p["mixer"], h, None, None, local=False)
        elif mode == "prefill":
            mix, c = attn.gqa_prefill(cfg, p["mixer"], h, None, None,
                                      local=False)
            new_cache["self"][str(i)] = c
        else:
            mix, c = attn.gqa_decode(cfg, p["mixer"], h, None, None,
                                     cache["self"][str(i)], cur_len,
                                     local=False)
            new_cache["self"][str(i)] = c
        x = x + mix
        hx = rmsnorm(x, p["ln_x"], cfg.rms_eps)
        if mode == "decode":
            ekv = cache["cross"][str(i)]
        else:
            ekv = _cross_kv(cfg, p["xattn"], enc_out)
        if mode == "prefill":
            new_cache["cross"][str(i)] = ekv
        elif mode == "decode":
            new_cache["cross"][str(i)] = ekv
        x = x + _cross_attend(cfg, p["xattn"], hx, ekv)
        h2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
        x = x + mlp(cfg, p["ffn"], h2)
        x = constrain(x, ("batch", None, None))
    x = rmsnorm(x, params["final_ln"], cfg.rms_eps)
    if mode == "train":
        return x, aux
    return x, aux, new_cache
