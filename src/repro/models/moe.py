"""Mixture-of-Experts: shared + routed top-k with capacity (GShard semantics).

Dispatch uses a sort-based position-in-expert computation (stable argsort by
expert id) and scatter-add into a dense (groups, E, capacity, d) buffer — the
layout expert parallelism wants: with experts sharded on the "model" axis the
buffer reshard *is* the all-to-all. Token priority is by position (earlier
tokens win capacity), matching GShard/Switch.

Grouping: train/prefill route within each batch row (G=B, Sg=S); decode uses
a single global group so capacity padding stays ~capacity_factor even at one
token per device.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _act, mlp, mlp_schema
from repro.models.schema import ParamSpec


def moe_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    E, d, f = cfg.n_routed_experts, cfg.d_model, cfg.expert_d_ff
    p: Dict[str, Any] = {
        "router": ParamSpec((d, E), ("embed", None)),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "expert_ff")),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "expert_ff")),
        "w_down": ParamSpec((E, f, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.shared_expert_d_ff or cfg.n_shared_experts * cfg.expert_d_ff
        p["shared"] = mlp_schema(cfg, shared_ff)
        if cfg.shared_expert_gate:
            p["shared_gate"] = ParamSpec((d, 1), ("embed", None), init="zeros")
    return p


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = math.ceil(group_tokens * cfg.moe_top_k / cfg.n_routed_experts
                  * cfg.moe_capacity_factor)
    return max(c, 1)


def _positions_in_expert(flat_e: jnp.ndarray, n_expert: int) -> jnp.ndarray:
    """flat_e: (N,) expert id per slot (token-major). Returns slot rank within
    its expert, respecting token-order priority."""
    n = flat_e.shape[0]
    perm = jnp.argsort(flat_e)                       # stable in jax
    sorted_e = perm_e = flat_e[perm]
    first = jnp.searchsorted(sorted_e, jnp.arange(n_expert))
    pos_sorted = jnp.arange(n) - first[perm_e]
    return jnp.zeros((n,), jnp.int32).at[perm].set(pos_sorted.astype(jnp.int32))


def _dispatch_one(x: jnp.ndarray, idx: jnp.ndarray, cap: int,
                  n_expert: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (S, D), idx: (S, k) -> buffer (E*cap, D), dest (S*k,), keep (S*k,)."""
    S, k = idx.shape
    flat_e = idx.reshape(-1)
    pos = _positions_in_expert(flat_e, n_expert)
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, n_expert * cap)  # pad slot
    x_rep = jnp.repeat(x, k, axis=0)                            # (S*k, D)
    buf = jnp.zeros((n_expert * cap + 1, x.shape[-1]), x.dtype)
    buf = buf.at[dest].add(x_rep)
    return buf[:-1], dest, keep


def moe_apply(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray,
              *, decode: bool = False,
              shard=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss).

    ``shard`` (a ``repro.parallel.context.ShardGroup``, decode only) runs
    the expert-sharded tensor-parallel path: routing and dispatch stay
    replicated (the router weights are tiny and identical routing across
    the group is what keeps the shard pools coherent), each shard computes
    the FFN for its ``E/tp`` contiguous expert slice, and the expert-axis
    concat of slot outputs — the EP all-gather — feeds the unchanged
    combine, so the sharded result matches tp=1 token for token.
    """
    B, S, D = x.shape
    E, k = cfg.n_routed_experts, cfg.moe_top_k
    dt = x.dtype
    if decode:
        xg = x.reshape(1, B * S, D)          # one global group
    else:
        xg = x.reshape(B, S, D)
    G, Sg, _ = xg.shape
    cap = capacity(cfg, Sg)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(dt),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                       # (G,Sg,k)
    if cfg.norm_topk_prob:
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-20)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                  axis=(0, 1, 2))                               # (E,)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce) * k

    buf, dest, keep = jax.vmap(
        lambda xr, ir: _dispatch_one(xr, ir, cap, E))(xg, idx)
    buf = buf.reshape(G, E, cap, D)
    from repro.parallel.context import constrain
    # groups stay on the batch (data) axes; experts shard on "model" (EP)
    buf = constrain(buf, ("batch", "experts_act", None, None))

    # expert FFN (gated) — per contiguous expert slice so one shard of a
    # tensor-parallel group computes only the experts it owns
    def _expert_ffn(b, lo, hi):
        h = _act(jnp.einsum("gecd,edf->gecf", b,
                            p["w_gate"][lo:hi].astype(dt)), cfg.mlp_act)
        h = h * jnp.einsum("gecd,edf->gecf", b, p["w_up"][lo:hi].astype(dt))
        return jnp.einsum("gecf,efd->gecd", h, p["w_down"][lo:hi].astype(dt))

    tp = shard.tp if (shard is not None and decode) else 1
    if tp > 1:
        E_s = E // tp
        out = jnp.concatenate(
            [_expert_ffn(buf[:, s * E_s:(s + 1) * E_s], s * E_s,
                         (s + 1) * E_s) for s in range(tp)], axis=1)
    else:
        out = _expert_ffn(buf, 0, E)
    out = constrain(out, ("batch", "experts_act", None, None))

    out_flat = out.reshape(G, E * cap, D)
    w = (gates.reshape(G, Sg * k).astype(dt)
         * keep.reshape(G, Sg * k).astype(dt))
    if cfg.moe_combine == "scatter":
        # §Perf lever: scatter-add expert outputs back to token slots. With
        # experts sharded on "model" the scatter produces *partial* token
        # sums per expert shard and SPMD reduces them — O(tokens*k*D) on the
        # wire instead of all-gathering the O(E*cap*D) slot buffer.
        def make_inv(d):
            # slot -> token-slot (dropped tokens land on the sliced-off pad)
            inv_full = jnp.full((E * cap + 1,), Sg * k, jnp.int32)
            return inv_full.at[d].set(
                jnp.arange(Sg * k, dtype=jnp.int32))[:-1]
        inv = jax.vmap(make_inv)(dest)                           # (G, E*cap)
        gate_per_slot = jnp.take_along_axis(
            jnp.concatenate([w, jnp.zeros((G, 1), dt)], axis=1), inv, axis=1)
        contrib = out_flat * gate_per_slot[..., None]
        # fold the top-k sum into the scatter: slot -> token directly, so the
        # cross-expert-shard partial sum is O(Sg*D), not O(Sg*k*D)
        tok = inv // k                                           # sentinel->Sg
        y = jax.vmap(lambda c, i: jnp.zeros((Sg + 1, D), dt)
                     .at[i].add(c))(contrib, tok)[:, :-1]
    else:
        # baseline: gather each slot's output, weight by gate, sum over k
        pad = jnp.zeros((G, 1, D), dt)
        out_padded = jnp.concatenate([out_flat, pad], axis=1)
        slot_out = jnp.take_along_axis(out_padded, dest[..., None],
                                       axis=1)                   # (G,Sg*k,D)
        y = (slot_out * w[..., None]).reshape(G, Sg, k, D).sum(axis=2)

    if cfg.n_shared_experts:
        sh = mlp(cfg, p["shared"], xg)
        if cfg.shared_expert_gate:
            g = jax.nn.sigmoid(
                jnp.einsum("gsd,do->gso", xg, p["shared_gate"].astype(dt)))
            sh = sh * g
        y = y + sh
    return y.reshape(B, S, D), aux.astype(jnp.float32)
