"""§Perf hillclimb driver: the three chosen cells, candidate sets per the
hypothesis -> change -> measure -> validate loop (EXPERIMENTS.md §Perf).

Cells (selection rationale recorded in EXPERIMENTS.md):
  1. qwen1.5-110b x decode_32k  — worst roofline fraction (serving, memory-bound)
  2. deepseek-v2-236b x train_4k — most collective-bound
  3. gemma2-2b x train_4k        — most representative of the paper's
     technique: the blueprint planner's *suggested configuration* is the
     baseline; the candidates are the planner's configuration-optimization
     search (paper §2.2 advanced CPS requirement).

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [cell ...]
Writes benchmarks/results/perf/<cell>__<candidate>.json via dryrun.autotune.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys

from repro.models.schema import DEFAULT_RULES
from repro.parallel.context import ACT_RULES


def _serve_tp_both_rules():
    """decode candidate: no FSDP at serve time — params sharded over BOTH
    mesh axes (256-way TP, bf16), so no per-step param all-gather and the
    per-chip footprint stays ~1 GB. Hypothesis: the decode memory term is
    dominated by the FSDP gather's output traffic, not by the cache."""
    param_rules = {**DEFAULT_RULES,
                   "embed": (),                       # FSDP off
                   "ff": ("model", "data"),
                   "heads": ("model", "data"),
                   "kv_heads": ("model", "data"),
                   "lora": ("model", "data"),
                   "experts": ("model",),
                   "expert_ff": ("model", "data")}
    return {"param_rules": param_rules,
            "serve_param_dtype": "bfloat16"}


def _dp_heavy_rules():
    """gemma2 candidate: tensor-parallelism off everywhere except the
    (giant) embedding; model axis left to vocab sharding only."""
    param_rules = {**DEFAULT_RULES,
                   "ff": (), "heads": (), "kv_heads": (), "lora": (),
                   "ssm_inner": (), "ssm_heads": (),
                   "experts": (), "expert_ff": ()}
    # the freed "model" axis joins the batch: 256-way DP on a single pod
    act_rules = {**ACT_RULES, "batch": ("pod", "data", "model"),
                 "heads_act": (), "ff_act": (), "experts_act": ()}
    return {"param_rules": param_rules, "act_rules": act_rules}


CELLS = {
    # 1 — worst roofline fraction (large-model decode)
    "qwen1.5-110b__decode_32k": dict(
        arch="qwen1.5-110b", shape="decode_32k", multi_pod=False,
        candidates={
            "baseline": {},
            "bf16_params": {"plan": {"serve_param_dtype": "bfloat16"}},
            "int8_cache": {"cfg": {"cache_quant": True}},
            "bf16_params+int8_cache": {
                "plan": {"serve_param_dtype": "bfloat16"},
                "cfg": {"cache_quant": True}},
            "serve_tp_both": {"plan": _serve_tp_both_rules()},
            "serve_tp_both+int8_cache": {
                "plan": _serve_tp_both_rules(),
                "cfg": {"cache_quant": True}},
        }),
    # 2 — most collective-bound (MoE train)
    "deepseek-v2-236b__train_4k": dict(
        arch="deepseek-v2-236b", shape="train_4k", multi_pod=False,
        candidates={
            "baseline": {},
            "moe_scatter": {"cfg": {"moe_combine": "scatter"}},
            "moe_scatter+mask_opt": {
                "cfg": {"moe_combine": "scatter", "attn_mask_opt": True}},
            "moe_scatter+dots_remat": {
                "cfg": {"moe_combine": "scatter"},
                "plan": {"remat": "dots"}},
            "moe_scatter+dots_remat+mla_heads": {
                "cfg": {"moe_combine": "scatter", "mla_shard": "heads"},
                "plan": {"remat": "dots"}},
        }),
    # 3 — the paper's technique: blueprint suggested-config vs planner search
    "gemma2-2b__train_4k": dict(
        arch="gemma2-2b", shape="train_4k", multi_pod=False,
        candidates={
            "baseline_suggested": {},
            "mask_opt": {"cfg": {"attn_mask_opt": True}},
            "dp_heavy": {"plan": _dp_heavy_rules()},
            "dp_heavy+mask_opt": {
                "plan": _dp_heavy_rules(),
                "cfg": {"attn_mask_opt": True}},
        }),
}


def main() -> None:
    from repro.launch.dryrun import autotune
    wanted = sys.argv[1:] or list(CELLS)
    for cell in wanted:
        spec = CELLS[cell]
        autotune(spec["arch"], spec["shape"], spec["multi_pod"],
                 spec["candidates"],
                 out_path=f"benchmarks/results/perf/{cell}.json")


if __name__ == "__main__":
    main()
