"""InstaCluster façade: a Big-Data-style analytic platform in one call.

``build_cluster()`` performs the full paper pipeline — cluster provisioning
(Fig. 1), service provisioning (Ambari analogue), service interaction (Hue
analogue) — and returns a handle exposing all three plus lifecycle ops.

Paper limitation reproduced *and* lifted: InstaCluster supports one cluster
per region (paper §4). ``ClusterManager`` enforces that by default and lifts
it with ``allow_multiple_per_region=True`` (beyond-paper; the discovery
filter uses cluster-scoped tags instead of region-wide enumeration).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.core.events import EventLog
from repro.core.heartbeat import HeartbeatMonitor
from repro.core.interaction import InteractionHub
from repro.core.lifecycle import ClusterLifecycle
from repro.core.provisioner import Cluster, ClusterProvisioner
from repro.core.services import AmbariServer
from repro.core.simcloud import SimCloud

DEFAULT_SERVICES = ("hdfs", "yarn", "zookeeper", "spark", "hue")


class RegionOccupiedError(RuntimeError):
    pass


@dataclasses.dataclass
class InstaCluster:
    cluster: Cluster
    ambari: AmbariServer
    hue: InteractionHub
    lifecycle: ClusterLifecycle
    provisioner: ClusterProvisioner
    bringup_seconds: float

    @property
    def log(self) -> EventLog:
        return self.cluster.log

    def spec(self) -> Dict[str, Any]:
        s = self.cluster.spec()
        s["services"] = sorted(self.ambari.services)
        s["configs"] = {n: {k: v for k, v in svc.config.items()
                            if k != "placement"}
                        for n, svc in self.ambari.services.items()}
        return s

    def spec_json(self) -> str:
        return json.dumps(self.spec(), indent=1, sort_keys=True)


class ClusterManager:
    """Top-level entry point binding a SimCloud account."""

    def __init__(self, cloud: Optional[SimCloud] = None, *,
                 access_key_id: str = "AKIA-DEMO",
                 secret_key: str = "s3cr3t",
                 allow_multiple_per_region: bool = False):
        self.cloud = cloud or SimCloud()
        self.access_key_id = access_key_id
        self.secret_key = secret_key
        self.cloud.register_key(access_key_id, secret_key)
        self.allow_multiple = allow_multiple_per_region
        self._by_region: Dict[str, List[InstaCluster]] = {}

    def build_cluster(self, *, n_slaves: int, region: str = "us-east-1",
                      instance_type: str = "tpu-host-v5e-8",
                      services: tuple = DEFAULT_SERVICES,
                      spot: bool = False,
                      deactivate_key: bool = False,
                      config_overrides: Optional[Dict[str, Dict]] = None
                      ) -> InstaCluster:
        if self._by_region.get(region) and not self.allow_multiple:
            raise RegionOccupiedError(
                f"region {region} already hosts a cluster; the paper "
                f"supports one cluster per region (pass "
                f"allow_multiple_per_region=True to lift this)")
        t0 = self.cloud.clock
        prov = ClusterProvisioner(
            self.cloud, region=region, access_key_id=self.access_key_id,
            secret_key=self.secret_key,
            deactivate_key_after_discovery=deactivate_key)
        cluster = prov.provision(n_slaves=n_slaves,
                                 instance_type=instance_type, spot=spot)
        ambari = AmbariServer(self.cloud, cluster)
        ambari.install(list(services), config_overrides)
        for name in services:
            ambari.start(name)
        hue = InteractionHub(ambari)
        lifecycle = ClusterLifecycle(self.cloud, prov)
        handle = InstaCluster(cluster=cluster, ambari=ambari, hue=hue,
                              lifecycle=lifecycle, provisioner=prov,
                              bringup_seconds=self.cloud.clock - t0)
        self._by_region.setdefault(region, []).append(handle)
        return handle

    def build_from_spec(self, spec: Dict[str, Any], *,
                        region: Optional[str] = None) -> InstaCluster:
        """Reproducibility entry point (paper §4): rebuild a collaborator's
        experimental environment from an exported spec."""
        return self.build_cluster(
            n_slaves=spec["n_slaves"],
            region=region or spec["region"],
            instance_type=spec["instance_type"],
            services=tuple(spec.get("services", DEFAULT_SERVICES)),
            spot=spec.get("spot", False),
            config_overrides=spec.get("configs"))

    def clusters(self, region: str) -> List[InstaCluster]:
        return list(self._by_region.get(region, []))
