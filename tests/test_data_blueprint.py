"""Data-pipeline determinism/sharding + blueprint-planner tests."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, REDUCED
from repro.core.blueprint import HBM_BUDGET, suggest_plan
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh_for


def test_batches_deterministic_across_restarts():
    cfg = REDUCED["gemma2-2b"]
    a = SyntheticLM(cfg, batch=8, seq=64)
    b = SyntheticLM(cfg, batch=8, seq=64)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(a.global_batch(step)["tokens"],
                                      b.global_batch(step)["tokens"])


def test_shards_partition_the_global_batch():
    cfg = REDUCED["gemma2-2b"]
    pipe = SyntheticLM(cfg, batch=8, seq=32)
    full = pipe.global_batch(3)["tokens"]
    parts = [pipe.shard_batch(3, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_elastic_resize_preserves_global_batch():
    """Same step, different DP size -> identical global batch (the property
    that makes elastic resume exact)."""
    cfg = REDUCED["gemma2-2b"]
    pipe = SyntheticLM(cfg, batch=8, seq=32)
    full2 = np.concatenate([pipe.shard_batch(7, r, 2)["tokens"]
                            for r in range(2)], axis=0)
    full8 = np.concatenate([pipe.shard_batch(7, r, 8)["tokens"]
                            for r in range(8)], axis=0)
    np.testing.assert_array_equal(full2, full8)


def test_labels_are_shifted_tokens():
    cfg = REDUCED["gemma2-2b"]
    pipe = SyntheticLM(cfg, batch=2, seq=16)
    b = pipe.global_batch(0)
    # both cut from the same (seq+1) stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_within_true_vocab():
    cfg = REDUCED["mamba2-1.3b"]    # padded vocab > true vocab
    pipe = SyntheticLM(cfg, batch=4, seq=64)
    b = pipe.global_batch(0)
    assert b["tokens"].max() < cfg.vocab_size


def test_prefetcher_order_and_completeness():
    items = list(range(20))
    out = list(Prefetcher(iter(items), depth=3))
    assert out == items


def test_extras_per_family():
    vl = SyntheticLM(ARCHS["qwen2-vl-72b"], batch=2, seq=8)
    b = vl.extras(vl.global_batch(0))
    assert b["positions"].shape == (3, 2, 8)
    wh = SyntheticLM(ARCHS["whisper-tiny"], batch=2, seq=8)
    b = wh.extras(wh.global_batch(0))
    assert b["enc_embeds"].shape == (2, 1500, 384)


# -------------------------------------------------------------- blueprint --

MESH = {"data": 16, "model": 16}   # planner reasons over topology shape only


def test_planner_remat_scales_with_model():
    small = suggest_plan(ARCHS["gemma2-2b"], SHAPES["train_4k"], MESH)
    big = suggest_plan(ARCHS["qwen1.5-110b"], SHAPES["train_4k"], MESH)
    assert small.remat == "none"
    assert big.remat == "full"


def test_planner_memory_estimates_fit():
    for name, cfg in ARCHS.items():
        plan = suggest_plan(cfg, SHAPES["train_4k"], MESH)
        assert plan.est["opt_state_bytes"] < HBM_BUDGET, name


def test_planner_cache_placement_by_shape():
    dec = suggest_plan(ARCHS["qwen3-32b"], SHAPES["decode_32k"], MESH)
    assert dec.act_rules["cache_seq"] == ("model",)
    lng = suggest_plan(ARCHS["mamba2-1.3b"], SHAPES["long_500k"], MESH)
    assert lng.act_rules["cache_seq"][0] == "data"


def test_planner_user_overrides_win():
    """Ambari semantics: suggestions are defaults the user can override."""
    plan = suggest_plan(ARCHS["qwen1.5-110b"], SHAPES["train_4k"], MESH,
                        overrides={"remat": "dots"})
    assert plan.remat == "dots"


def test_planner_optimize_mode_encodes_hillclimb_winners():
    from repro.core.blueprint import optimized_cfg_overrides
    # small dense model training -> DP-heavy (TP off, model joins batch)
    p = suggest_plan(ARCHS["gemma2-2b"], SHAPES["train_4k"], MESH,
                     optimize=True)
    assert p.param_rules["ff"] == ()
    assert p.act_rules["batch"] == ("pod", "data", "model")
    # serving -> 2-axis TP + bf16 params, int8 cache for GQA
    p = suggest_plan(ARCHS["qwen1.5-110b"], SHAPES["decode_32k"], MESH,
                     optimize=True)
    assert p.serve_param_dtype == "bfloat16"
    assert p.param_rules["embed"] == ()
    assert optimized_cfg_overrides(ARCHS["qwen1.5-110b"],
                                   SHAPES["decode_32k"])["cache_quant"]
    # MoE/MLA train -> scatter combine + head-sharded up-projections + dots
    p = suggest_plan(ARCHS["deepseek-v2-236b"], SHAPES["train_4k"], MESH,
                     optimize=True)
    assert p.remat == "dots"
    o = optimized_cfg_overrides(ARCHS["deepseek-v2-236b"], SHAPES["train_4k"])
    assert o == {"moe_combine": "scatter", "mla_shard": "heads"}
    # ...but MLA *decode* keeps the v1 serving plan (measured regression)
    p = suggest_plan(ARCHS["deepseek-v2-236b"], SHAPES["decode_32k"], MESH,
                     optimize=True)
    assert p.serve_param_dtype == "float32"
    o = optimized_cfg_overrides(ARCHS["deepseek-v2-236b"],
                                SHAPES["decode_32k"])
    assert "mla_shard" not in o
    # big dense train keeps TP (does not fit DP-only)
    p = suggest_plan(ARCHS["qwen1.5-110b"], SHAPES["train_4k"], MESH,
                     optimize=True)
    assert p.param_rules["ff"] == ("model",)
