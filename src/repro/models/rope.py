"""Rotary position embeddings: standard, half-dim 2d (chatglm3), M-RoPE (qwen2-vl)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def rotary_dim(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    if cfg.attn_impl == "mla":
        return cfg.qk_rope_head_dim
    if cfg.rope_variant == "half2d":
        return hd // 2
    return hd


def mrope_sections(rd_half: int):
    """qwen2-vl: temporal/height/width sections over the frequency dims.

    Published split for hd=128 is (16, 24, 24) over 64 freq dims, i.e.
    (1/4, 3/8, 3/8); we keep those proportions for any head_dim.
    """
    t = rd_half // 4
    h = (rd_half - t) // 2
    w = rd_half - t - h
    return t, h, w


def rope_cos_sin(cfg: ModelConfig, positions: jnp.ndarray):
    """positions: (B, S) int32, or (3, B, S) for mrope.

    Returns cos, sin of shape (B, S, rd/2) float32.
    """
    rd = rotary_dim(cfg)
    if rd == 0 or cfg.rope_variant in ("none", "abs"):
        return None, None
    half = rd // 2
    inv_freq = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if cfg.rope_variant == "mrope":
        assert positions.ndim == 3, "mrope needs (3, B, S) position ids"
        angles3 = positions.astype(jnp.float32)[..., None] * inv_freq  # (3,B,S,half)
        t, h, w = mrope_sections(half)
        sec = jnp.concatenate([
            jnp.zeros((t,), jnp.int32),
            jnp.ones((h,), jnp.int32),
            jnp.full((w,), 2, jnp.int32),
        ])
        angles = jnp.take_along_axis(
            jnp.moveaxis(angles3, 0, -1),                      # (B,S,half,3)
            sec[None, None, :, None], axis=-1)[..., 0]         # (B,S,half)
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (B,S,half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               rd: int) -> jnp.ndarray:
    """Rotate-half RoPE on the first ``rd`` dims of the head dim.

    x: (B, S, H, hd); cos/sin: (B, S, rd/2).
    """
    if cos is None:
        return x
    dtype = x.dtype
    rot, keep = x[..., :rd], x[..., rd:]
    half = rd // 2
    x1 = rot[..., :half].astype(jnp.float32)
    x2 = rot[..., half:].astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    rotated = jnp.concatenate([r1, r2], axis=-1).astype(dtype)
    if keep.shape[-1]:
        return jnp.concatenate([rotated, keep], axis=-1)
    return rotated
