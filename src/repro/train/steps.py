"""pjit-able train / eval / serve step builders."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim.adamw import OptimConfig, opt_init, opt_update
from repro.parallel.context import use_parallel


def init_train_state(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    params = M.init(cfg, key)
    opt = opt_init(params)
    return {"params": params, "m": opt["m"], "v": opt["v"],
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, ocfg: OptimConfig, *,
                    remat: str = "none", mesh=None, act_rules=None):
    """Returns train_step(state, batch) -> (state, metrics).

    Gradient reduction across data/pod axes is induced by pjit sharding
    propagation (reduce-scatter under FSDP); no explicit psum needed.
    """

    def train_step(state, batch):
        def compute(params):
            def lf(p):
                return M.loss_fn(cfg, p, batch, remat=remat)
            return jax.value_and_grad(lf, has_aux=True)(params)

        if mesh is not None:
            with use_parallel(mesh, act_rules):
                (loss, metrics), grads = compute(state["params"])
        else:
            (loss, metrics), grads = compute(state["params"])
        new_p, new_m, new_v, gnorm = opt_update(
            ocfg, state["params"], grads, state["m"], state["v"],
            state["step"])
        new_state = {"params": new_p, "m": new_m, "v": new_v,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, mesh=None, act_rules=None):
    def eval_step(params, batch):
        if mesh is not None:
            with use_parallel(mesh, act_rules):
                loss, metrics = M.loss_fn(cfg, params, batch)
        else:
            loss, metrics = M.loss_fn(cfg, params, batch)
        return dict(metrics, loss=loss)
    return eval_step


def make_prefill_step(cfg: ModelConfig, *, mesh=None, act_rules=None):
    def prefill_step(params, batch):
        if mesh is not None:
            with use_parallel(mesh, act_rules):
                return M.prefill(cfg, params, batch)
        return M.prefill(cfg, params, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig, *, mesh=None, act_rules=None):
    def serve_step(params, cache, tokens, cur_len):
        if mesh is not None:
            with use_parallel(mesh, act_rules):
                return M.decode_step(cfg, params, cache, tokens, cur_len)
        return M.decode_step(cfg, params, cache, tokens, cur_len)
    return serve_step
