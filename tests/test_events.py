"""EventLog JSONL export/replay: type-preserving round-trip and loud,
line-numbered errors on malformed input."""
import json

import pytest

from repro.core.events import EventLog


def _sample_log() -> EventLog:
    log = EventLog()
    log.emit(0.0, "cloud", "run_instances", count=4, spot=True)
    log.emit(1.5, "master", "extend_cluster",
             added=["slave-2", "slave-3"], meta={"region": "us-east-1"})
    log.emit(2.0, "autoscale", "scale_out", resource="replicas", desired=2,
             delta=1, reason="step-scaling demand=5.000")
    log.emit(3.25, "autoscale", "drain_replica", replica=1, outstanding=0,
             hostname=None)
    return log


def test_roundtrip_preserves_timestamps_and_payload_types(tmp_path):
    log = _sample_log()
    path = tmp_path / "events.jsonl"
    n = log.write_jsonl(path)
    assert n == len(log.events) == 4

    replay = EventLog.from_jsonl(path)
    assert [e.to_dict() for e in replay.events] == \
        [e.to_dict() for e in log.events]
    # types survive, not just values
    for orig, back in zip(log.events, replay.events):
        assert type(back.t) is type(orig.t)
        for k, v in orig.detail.items():
            assert type(back.detail[k]) is type(v), (k, v)
    e = replay.events[1]
    assert isinstance(e.t, float) and e.t == 1.5
    assert e.detail["added"] == ["slave-2", "slave-3"]
    assert e.detail["meta"] == {"region": "us-east-1"}
    assert replay.events[0].detail["spot"] is True
    assert replay.events[3].detail["hostname"] is None
    # the helpers work identically on the replayed copy
    replay.assert_order("run_instances", "scale_out", "drain_replica")
    assert replay.actions("autoscale") == ["scale_out", "drain_replica"]


def test_roundtrip_skips_blank_lines(tmp_path):
    path = tmp_path / "events.jsonl"
    path.write_text('{"t": 0.0, "actor": "a", "action": "x", "detail": {}}'
                    "\n\n  \n")
    assert len(EventLog.from_jsonl(path).events) == 1


def test_malformed_json_names_line_number(tmp_path):
    log = _sample_log()
    path = tmp_path / "events.jsonl"
    log.write_jsonl(path)
    lines = path.read_text().splitlines()
    lines[2] = lines[2][:-10]              # truncate mid-object
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(ValueError, match="line 3 is not valid JSON"):
        EventLog.from_jsonl(path)


def test_missing_field_names_line_number(tmp_path):
    path = tmp_path / "events.jsonl"
    good = {"t": 0.0, "actor": "a", "action": "x", "detail": {}}
    bad = {"t": 1.0, "actor": "a", "detail": {}}          # no action
    path.write_text(json.dumps(good) + "\n" + json.dumps(bad) + "\n")
    with pytest.raises(ValueError, match=r"line 2.*\['action'\]"):
        EventLog.from_jsonl(path)


def test_non_object_line_and_detail_rejected(tmp_path):
    path = tmp_path / "events.jsonl"
    path.write_text("[1, 2, 3]\n")
    with pytest.raises(ValueError, match="line 1.*list, not an event"):
        EventLog.from_jsonl(path)
    path.write_text('{"t": 0.0, "actor": "a", "action": "x", '
                    '"detail": "oops"}\n')
    with pytest.raises(ValueError, match="line 1.*non-object 'detail'"):
        EventLog.from_jsonl(path)
