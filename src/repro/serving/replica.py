"""A serving replica: one scheduler + one page pool, placed on a *shard
group* of cluster nodes (one node at tp=1).

The fabric router (``repro.serving.router``) spreads requests over a fleet
of these. The wrapper is deliberately thin — all decode/admission logic
stays in ``ContinuousBatchingScheduler`` — and adds only what the fleet
needs to reason about a member:

* **placement** — the cluster hostnames this replica's "serve" service
  spans: ``tp`` shard-group members placed on contiguous nodes by
  ``AmbariServer.provision_serving`` + ``NodeDirectory`` (one hostname at
  tp=1; ``None``/empty for an unplaced, in-process fabric). ``fail()``
  purges the hostnames so a dead member can never read as still occupying
  a node in any hostname-derived stats or routing signal;
* **load** — ``outstanding_pages`` is the routing signal: worst-case pages
  reserved by admitted streams plus the worst-case pages of everything in
  the replica's own queue, so routing sees committed-but-not-yet-admitted
  work too (pages are logical, so the signal is tp-invariant);
* **lifecycle** — ``draining`` stops new routing while admitted/queued
  streams finish (graceful scale-in); ``failed`` marks a dead replica
  (heartbeat DEAD / spot preemption) whose unfinished streams the router
  re-prefills elsewhere. A single preempted *member* of a tp>1 group is
  survivable when a warm spare exists — ``repro.autoscale.fleet`` swaps
  the node without failing the group;
* **role** — under disaggregation a replica is a ``prefill`` or
  ``decode`` specialist (default ``mixed`` does both): prefill replicas
  take every routed prompt, park completed prompts (``handoff_ready``)
  and donate their KV pages verbatim to a decode replica (the router's
  migration pass); ``fits`` on a prefill replica therefore checks prompt
  pages only, while decode replicas answer for the worst case.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.serving.request import Request, worst_case_pages
from repro.serving.scheduler import ContinuousBatchingScheduler


class ServingReplica:
    def __init__(self, replica_id: int,
                 sched: ContinuousBatchingScheduler, *,
                 hostname: Optional[str] = None,
                 hostnames: Optional[Sequence[str]] = None):
        if hostname is not None and hostnames is not None:
            raise ValueError("pass hostname or hostnames, not both")
        self.replica_id = replica_id
        self.sched = sched
        # the observability plane's identity for this member: lifecycle
        # spans carry the replica id, and the registry's exposition labels
        # every sample so a fleet's concatenated /metrics stays unambiguous
        sched.replica_id = replica_id
        sched.registry.labels.update({"replica": str(replica_id),
                                      "role": sched.role})
        self.hostnames: List[str] = (list(hostnames) if hostnames
                                     else [hostname] if hostname else [])
        if sched.tp > 1 and self.hostnames \
                and len(self.hostnames) != sched.tp:
            raise ValueError(
                f"shard group of tp={sched.tp} needs {sched.tp} hostnames, "
                f"got {self.hostnames}")
        self.draining = False
        self.failed = False

    @property
    def role(self) -> str:
        """Disaggregation role ("mixed" | "prefill" | "decode")."""
        return self.sched.role

    @property
    def hostname(self) -> Optional[str]:
        """Primary (rank-0) member hostname — the fleet's stable key for
        single-node replicas; None once failed (hostnames are purged)."""
        return self.hostnames[0] if self.hostnames else None

    @property
    def tp(self) -> int:
        return self.sched.tp

    @classmethod
    def build(cls, cfg, params, replica_id: int, *, max_slots: int = 4,
              page_size: int = 16, num_pages: Optional[int] = None,
              max_seq_len: int = 512, prefix_cache: Optional[bool] = None,
              tp: int = 1, hostname: Optional[str] = None,
              hostnames: Optional[Sequence[str]] = None,
              prefill_budget: Optional[int] = None,
              role: str = "mixed", spec_k: Optional[int] = None,
              spec_draft=None, host_pages: Optional[int] = None,
              tenant_quotas=None,
              swap_crossover: Optional[int] = None) -> "ServingReplica":
        sched = ContinuousBatchingScheduler(
            cfg, params, max_slots=max_slots, page_size=page_size,
            num_pages=num_pages, max_seq_len=max_seq_len,
            prefix_cache=prefix_cache, tp=tp, prefill_budget=prefill_budget,
            role=role, spec_k=spec_k, spec_draft=spec_draft,
            host_pages=host_pages, tenant_quotas=tenant_quotas,
            swap_crossover=swap_crossover)
        return cls(replica_id, sched, hostname=hostname, hostnames=hostnames)

    # -------------------------------------------------------------- state --
    @property
    def live(self) -> bool:
        """Accepting new routed requests."""
        return not (self.draining or self.failed)

    @property
    def num_unfinished(self) -> int:
        return self.sched.num_active + len(self.sched.waiting)

    @property
    def idle(self) -> bool:
        return self.num_unfinished == 0

    @property
    def reserved_pages(self) -> int:
        return self.sched.reserved_pages

    @property
    def outstanding_pages(self) -> int:
        """Routing load signal: reservations held by admitted streams plus
        the worst-case reservations of this replica's queued streams.

        Tier-aware by construction: retained (cold) chains and host-
        resident pages carry no reservation — they are reclaimable under
        pressure — so a replica dense with idle sessions still reads as
        lightly loaded, while its prefix index keeps advertising those
        sessions through ``prefix_match_len`` (affinity routing sees
        host-resident chains too)."""
        ps = self.sched.page_size
        queued = sum(worst_case_pages(r, ps) for r in self.sched.waiting)
        return self.sched.reserved_pages + queued

    @property
    def hot_pages(self) -> int:
        """Pages backing live streams (the autoscaler's working set)."""
        return self.sched.hot_pages

    def prefix_match_len(self, prompt) -> int:
        """Tokens of ``prompt`` already cached in this replica's page pool —
        the router's prefix-affinity routing signal."""
        return self.sched.prefix_match_len(prompt)

    def fits(self, req: Request) -> bool:
        """Could this replica *ever* admit the request (spill-over check)?
        A prefill-role replica answers for the prompt's pages only — the
        generation worst case is the adopting decode replica's burden."""
        if req.plen + req.max_new_tokens > self.sched.max_seq_len:
            return False
        cap = self.sched.alloc.capacity
        if self.sched.capacity_hint is not None:
            cap = max(cap, self.sched.capacity_hint - 1)
        if self.role == "prefill":
            from repro.serving.paged_cache import pages_for_len
            return pages_for_len(req.plen + 1, self.sched.page_size) <= cap
        return worst_case_pages(req, self.sched.page_size) <= cap

    # ------------------------------------------------------------- handoff --
    def handoff_ready(self) -> List[int]:
        """Slots parked after prefill, awaiting KV-page migration."""
        return self.sched.handoff_ready()

    def can_adopt(self, req: Request) -> bool:
        return self.sched.can_adopt(req)

    def adopt(self, req: Request, donor: "ServingReplica",
              donor_slot: int) -> int:
        """Verbatim page handoff: copy the donor slot's KV pages into this
        replica's pool, then release them on the donor."""
        slot = self.sched.adopt(req, donor.sched, donor_slot)
        # the donor may have died (and freed its copy of the pages) between
        # the copy and this release — surrender only a slot the donor still
        # holds for THIS stream, or a failed donor's already-cleared slot
        # would double-free
        if donor.sched.slot_req[donor_slot] is req:
            donor.sched.surrender_slot(donor_slot)
        req.replica = self.replica_id
        return slot

    # ---------------------------------------------------------- lifecycle --
    def accept(self, req: Request) -> None:
        req.replica = self.replica_id
        # routed requests are already due on the fleet clock; gate them on
        # the replica's own clock so admission may happen this very tick
        req.arrival_step = min(req.arrival_step, self.sched.step_idx)
        self.sched.submit_request(req)

    def step(self, max_fuse: int = 16) -> List[Request]:
        return self.sched.step(max_fuse=max_fuse)

    def drain(self) -> None:
        """Stop routing to this replica; admitted/queued streams finish."""
        self.draining = True

    def undrain(self) -> None:
        if not self.failed:
            self.draining = False

    def fail(self) -> List[Request]:
        """Mark dead and surrender every unfinished stream for re-routing.

        The device state is considered lost: queued streams come back
        untouched, admitted streams come back with the tokens they already
        emitted (the router re-prefills ``prompt + out_tokens`` elsewhere).
        The hostnames are purged too: every hostname-derived signal —
        node-occupancy checks before a release, prefix-affinity stats, a
        later ``fail_host`` sweep — must stop seeing this replica on its
        nodes the moment it dies, or a replacement booting on the same
        hostname races a ghost (the regression in tests/test_fabric.py).
        """
        self.failed = True
        self.draining = True
        self.hostnames = []           # purge placement: the nodes are free
        lost: List[Request] = list(self.sched.waiting)
        self.sched.waiting.clear()
        # host-side bookkeeping is still ours to zero out (the simulated
        # node is gone; the scheduler object just stops being stepped)
        for slot, req in enumerate(self.sched.slot_req):
            if req is not None:
                if req.replica is not None \
                        and req.replica != self.replica_id:
                    # adopted away mid-handoff (scheduler.adopt transfers
                    # ownership at the copy point): the decode side owns the
                    # only live copy of the stream — free our now-orphaned
                    # source pages, but do NOT requeue it or touch its
                    # cursor, or the stream would decode twice
                    self.sched.stats["migrations_out"] += 1
                else:
                    lost.append(req)
                    req.prefill_pos = None  # a mid-prefill stream restarts
                self.sched.alloc.free(self.sched.slot_pages[slot])
                self.sched.slot_pages[slot] = []
                self.sched.slot_req[slot] = None
                self.sched.slot_reserve[slot] = 0
                self.sched.slot_shared[slot] = 0
                self.sched.slot_parked[slot] = False
                self.sched.slot_resume_state[slot] = None
        self.sched._prefill_fifo.clear()
        self.sched.reserved_pages = 0
        # both memory tiers died with the node: retained chains release
        # their refs, host-RAM rows are dropped, tenant ledgers reset
        self.sched.drop_tier_state()
        self.sched.index.clear()      # the device's cached prefixes died too
        return lost

    def stats(self) -> dict:
        return dict(self.sched.stats)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        host = self.hostname or "unplaced"
        return (f"ServingReplica({self.replica_id}@{host}, "
                f"active={self.sched.num_active}, "
                f"queued={len(self.sched.waiting)}, "
                f"reserved={self.sched.reserved_pages})")
