"""Roofline-driven block-size sweep for the chunked flash-prefill kernel.

For each (arch, chunk, page_size) point this sweeps the attend kernel's
``block_q`` grid parameter. ``block_q`` sets the KV re-read factor — every
query block streams the whole live context out of the pool pages, so a
chunk split into ``ceil(chunk / block_q)`` query blocks moves that many
times the context bytes. The sweep therefore:

1. models each candidate on the roofline (bytes moved at ``HBM_BW`` vs
   attention FLOPs at ``PEAK_FLOPS``, whichever bounds) and drops
   candidates whose modeled time is > ``--prune`` x the best model — on
   hardware the model alone nearly always picks the winner;
2. times the surviving candidates (best-of-``--repeats`` on a warm
   program) and keeps the fastest measured one.

Best configs land in ``BENCH_prefill_tune.json``; ``repro.kernels.ops``
loads that file lazily (or via ``$REPRO_PREFILL_TUNE`` /
``register_prefill_tuning``) and every ``ops.paged_prefill`` call with a
matching shape signature picks up the tuned ``block_q``. On CPU the
kernels run interpret-mode, so measured walls are dispatch-dominated
proxies; the modeled ranking is the portable signal and both numbers are
recorded per candidate.

Usage:
    PYTHONPATH=src python benchmarks/prefill_autotune.py          # full sweep
    PYTHONPATH=src python benchmarks/prefill_autotune.py --smoke  # CI
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REDUCED
from repro.kernels import ops
from repro.kernels import paged_prefill as pp
from repro.obs.profile import HBM_BW, PEAK_FLOPS

PREFIX_PAGES = 3          # synthetic live context = chunk + this many pages


def model_candidate(chunk, ctx, n_pg, ps, H, KVH, d, block_q, itemsize=4):
    """Roofline time for one attend candidate: KV streams once per query
    block, q/out move once, FLOPs are the two attention matmuls."""
    n_qb = math.ceil(chunk / block_q)
    kv_bytes = n_pg * ps * KVH * d * itemsize * 2
    qo_bytes = chunk * H * d * itemsize * 2
    bytes_moved = kv_bytes * n_qb + qo_bytes
    flops = 4 * chunk * ctx * H * d
    return {
        "bytes_moved": int(bytes_moved),
        "flops": int(flops),
        "modeled_ms": round(max(bytes_moved / HBM_BW,
                                flops / PEAK_FLOPS) * 1e3, 6),
    }


def time_candidate(q, pool, bt, start, lens, block_q, repeats):
    fn = jax.jit(lambda q_: pp.paged_prefill_attend(
        q_, pool["k_pages"], pool["v_pages"], bt, start, lens,
        block_q=block_q, interpret=True))
    fn(q).block_until_ready()                                     # warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(q).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def sweep_point(cfg, chunk, ps, candidates, repeats, prune):
    """One (chunk, page_size) point: model, prune, measure, pick."""
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model // cfg.n_heads
    start = PREFIX_PAGES * ps                  # chunk lands mid-sequence
    ctx = start + chunk
    n_pg = -(-ctx // ps)
    key = jax.random.PRNGKey(chunk * 1000 + ps)
    ks = jax.random.split(key, 3)
    pool = {
        "k_pages": jax.random.normal(ks[0], (n_pg + 1, ps, KVH, d),
                                     jnp.float32),
        "v_pages": jax.random.normal(ks[1], (n_pg + 1, ps, KVH, d),
                                     jnp.float32),
    }
    bt = jnp.arange(1, n_pg + 1, dtype=jnp.int32)[None]
    q = jax.random.normal(ks[2], (1, chunk, H, d), jnp.float32)
    lens = jnp.asarray([chunk], jnp.int32)
    starts = jnp.asarray([start], jnp.int32)

    cands = {}
    for bq in sorted({min(b, chunk) for b in candidates}):
        cands[bq] = model_candidate(chunk, ctx, n_pg, ps, H, KVH, d, bq)
    floor = min(c["modeled_ms"] for c in cands.values())
    survivors = [bq for bq, c in cands.items()
                 if c["modeled_ms"] <= prune * floor]
    for bq in survivors:
        cands[bq]["measured_ms"] = round(
            time_candidate(q, pool, bt, starts, lens, bq, repeats), 3)
    best = min(survivors, key=lambda bq: (cands[bq]["measured_ms"],
                                          cands[bq]["modeled_ms"]))
    return {
        "block_q": int(best),
        "modeled_ms": cands[best]["modeled_ms"],
        "measured_ms": cands[best]["measured_ms"],
        "candidates": {str(bq): c for bq, c in cands.items()},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen3-32b", choices=sorted(REDUCED))
    ap.add_argument("--wide", type=int, default=4,
                    help="width multiple matching serve_bench's bench_cfg")
    ap.add_argument("--deep", type=int, default=2)
    ap.add_argument("--chunks", type=int, nargs="+",
                    default=[16, 32, 64, 128],
                    help="chunk buckets to tune (scheduler dispatch sizes)")
    ap.add_argument("--page-sizes", type=int, nargs="+", default=[4, 8])
    ap.add_argument("--candidates", type=int, nargs="+",
                    default=[8, 16, 32, 64, 128])
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--prune", type=float, default=4.0,
                    help="drop candidates modeled worse than this x best")
    ap.add_argument("--out", default="BENCH_prefill_tune.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + registry round-trip check (CI)")
    args = ap.parse_args()
    if args.smoke:
        args.chunks, args.page_sizes = [8], [4]
        args.candidates, args.repeats = [4, 8], 1

    import serve_bench
    cfg = serve_bench.bench_cfg(args.arch, args.wide, args.deep)
    H, KVH = cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model // cfg.n_heads

    entries = {}
    for ps in args.page_sizes:
        for chunk in args.chunks:
            key = ops.prefill_tuning_key(H, d, KVH, chunk, ps)
            entries[key] = sweep_point(cfg, chunk, ps, args.candidates,
                                       args.repeats, args.prune)
            print(f"{key}: block_q={entries[key]['block_q']} "
                  f"modeled={entries[key]['modeled_ms']}ms "
                  f"measured={entries[key]['measured_ms']}ms")

    out = {"version": 1, "arch": cfg.name,
           "dims": {"n_heads": H, "n_kv_heads": KVH, "head_dim": d},
           "entries": entries}
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
        fh.write("\n")
    print(f"wrote {args.out} ({len(entries)} entries)")

    # round-trip: the table must steer ops.paged_prefill's block_q lookup
    prev = ops.register_prefill_tuning(entries)
    try:
        for ps in args.page_sizes:
            for chunk in args.chunks:
                want = entries[ops.prefill_tuning_key(H, d, KVH, chunk,
                                                      ps)]["block_q"]
                got = ops._prefill_tuned_block_q(H, d, KVH, chunk, ps)
                if got != want:
                    raise SystemExit(f"tuning round-trip failed: chunk "
                                     f"{chunk} ps {ps}: {got} != {want}")
    finally:
        ops.register_prefill_tuning(prev)
    print("tuning round-trip ok")


if __name__ == "__main__":
    main()
