"""Chunked flash-prefill kernel suite (direct-to-page KV writes).

Covers the PR's byte-identity contracts end to end:

* write kernel vs the ``ref.py`` scatter oracle — bit-identical payloads
  *and* scale planes for fp32/int8/fp8 pools, over ragged chunk lengths
  and page-boundary-crossing starts;
* attend kernel vs the gather+softmax oracle under causal, sliding-window
  and softcap masks, quantised and not;
* the fused ``ops.paged_prefill`` entry (write then attend) and its tp=2
  shard-group variant vs tp=1 — outputs and reassembled pools byte-equal;
* the model-level fused path vs the legacy dense-prefill +
  ``write_prefill`` copy route (layer-0 pool bytes identical, next token
  identical);
* scheduler-level identity gates: fused on/off, Pallas kernel on/off,
  fp8 pools kernel on/off, tp=1 vs tp=2 — all at fp32 activations, the
  same contract the serving gates in benchmarks/serve_bench.py enforce;
* the cross-instance compiled-program cache (a second scheduler compiles
  nothing) and the dispatch counters behind BENCH_prefill.json.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.kernels import ops, ref
from repro.kernels import paged_prefill as pp
from repro.models import model as M
from repro.serving import paged_cache as PC
from repro.serving import scheduler as SCH
from repro.serving.scheduler import ContinuousBatchingScheduler

KEY = jax.random.PRNGKey(0)


def rand(shape, i, scale=1.0):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape,
                             jnp.float32) * scale


def make_pool(P, ps, KVH, d, quant, seed=100):
    """A pool with non-zero prior contents (the prefix the attend kernel
    must stream alongside the chunk)."""
    if quant:
        kq = jax.random.randint(jax.random.fold_in(KEY, seed),
                                (P, ps, KVH, d), -127, 128, jnp.int32)
        vq = jax.random.randint(jax.random.fold_in(KEY, seed + 1),
                                (P, ps, KVH, d), -127, 128, jnp.int32)
        dt = jnp.int8 if quant == "int8" else jnp.float8_e4m3fn
        return {
            "k_pages": kq.astype(dt), "v_pages": vq.astype(dt),
            "k_scale_pages": jnp.abs(rand((P, ps, KVH), seed + 2)) + 1e-3,
            "v_scale_pages": jnp.abs(rand((P, ps, KVH), seed + 3)) + 1e-3,
        }
    return {"k_pages": rand((P, ps, KVH, d), seed),
            "v_pages": rand((P, ps, KVH, d), seed + 1)}


def pool_equal(a, b):
    return all(bool(jnp.array_equal(a[k], b[k])) for k in a)


# ---------------------------------------------------------- write kernel --

@pytest.mark.parametrize("quant", [None, "int8", "fp8"])
def test_write_kernel_bit_identical_to_oracle(quant):
    """Ragged starts/lengths crossing page boundaries: the Pallas scatter
    lands byte-for-byte what the XLA oracle lands — including the fp32
    scale planes (reciprocal-multiply quantisation on both sides)."""
    B, S, KVH, d, ps, P, n_pg = 3, 7, 2, 16, 4, 20, 5
    k_new, v_new = rand((B, S, KVH, d), 1), rand((B, S, KVH, d), 2)
    # start mid-page (5), page-aligned (0, 8); lens ragged incl. 0-padding
    start = jnp.asarray([5, 0, 8], jnp.int32)
    lens = jnp.asarray([7, 5, 3], jnp.int32)
    bt = jnp.asarray(np.random.RandomState(0).choice(
        np.arange(1, P), (B, n_pg), replace=False), jnp.int32)
    pool = make_pool(P, ps, KVH, d, quant)

    got = pp.paged_prefill_write(
        k_new, v_new, pool["k_pages"], pool["v_pages"], bt, start, lens,
        k_scale_pages=pool.get("k_scale_pages"),
        v_scale_pages=pool.get("v_scale_pages"), quant=quant,
        interpret=True)
    want = ref.paged_prefill_write_ref(k_new, v_new, pool, bt, start, lens,
                                       quant=quant)
    assert pool_equal(got, want)


def test_write_kernel_preserves_untouched_rows():
    """Rows outside [start, start+len) — the already-prefilled prefix and
    the pages beyond the chunk — keep their previous bytes."""
    B, S, KVH, d, ps, P, n_pg = 1, 4, 2, 16, 4, 8, 4
    pool = make_pool(P, ps, KVH, d, None)
    before = jax.tree_util.tree_map(jnp.copy, pool)
    bt = jnp.asarray([[2, 3, 4, 5]], jnp.int32)
    got = pp.paged_prefill_write(
        rand((B, S, KVH, d), 1), rand((B, S, KVH, d), 2),
        pool["k_pages"], pool["v_pages"], bt,
        jnp.asarray([6], jnp.int32), jnp.asarray([4], jnp.int32),
        interpret=True)
    # positions 6..9 span pages bt[1] rows 2..3 and bt[2] rows 0..1;
    # pages 0,1 (sink + unowned), bt[0], bt[3] and the prefix rows of
    # bt[1] must be untouched
    for key in ("k_pages", "v_pages"):
        assert bool(jnp.array_equal(got[key][0:2], before[key][0:2]))
        assert bool(jnp.array_equal(got[key][2], before[key][2]))
        assert bool(jnp.array_equal(got[key][5], before[key][5]))
        assert bool(jnp.array_equal(got[key][3, :2], before[key][3, :2]))
        assert not bool(jnp.array_equal(got[key][3, 2:], before[key][3, 2:]))


# --------------------------------------------------------- attend kernel --

@pytest.mark.parametrize("quant", [None, "int8", "fp8"])
@pytest.mark.parametrize("softcap,window", [(None, None), (None, 3),
                                            (30.0, None), (30.0, 3)])
def test_attend_kernel_matches_oracle(quant, softcap, window):
    """Post-write attention over prefix+chunk pages: causal, windowed and
    softcapped variants vs the gather oracle, quantised and not."""
    B, S, H, KVH, d, ps, P, n_pg = 2, 6, 4, 2, 16, 4, 10, 4
    start = jnp.asarray([5, 0], jnp.int32)
    lens = jnp.asarray([6, 4], jnp.int32)
    bt = jnp.asarray(np.random.RandomState(1).choice(
        np.arange(1, P), (B, n_pg), replace=False), jnp.int32)
    pool = make_pool(P, ps, KVH, d, quant)
    # land a chunk first so its K/V stream back from the pages
    pool = ref.paged_prefill_write_ref(
        rand((B, S, KVH, d), 20), rand((B, S, KVH, d), 21), pool, bt,
        start, lens, quant=quant)
    q = rand((B, S, H, d), 22)

    got = pp.paged_prefill_attend(
        q, pool["k_pages"], pool["v_pages"], bt, start, lens,
        k_scale_pages=pool.get("k_scale_pages"),
        v_scale_pages=pool.get("v_scale_pages"), softcap=softcap,
        window=window, block_q=4, interpret=True)
    want = ref.paged_prefill_attention_ref(
        q, pool["k_pages"], pool["v_pages"], bt, start, lens,
        k_scale_pages=pool.get("k_scale_pages"),
        v_scale_pages=pool.get("v_scale_pages"), softcap=softcap,
        window=window)
    # compare live rows only (padding rows are unspecified)
    for b in range(B):
        n = int(lens[b])
        np.testing.assert_allclose(np.asarray(got[b, :n]),
                                   np.asarray(want[b, :n]),
                                   rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("block_q", [2, 4, 8])
def test_attend_block_q_invariant(block_q):
    """The autotuned block size changes the grid, never the math."""
    B, S, H, KVH, d, ps, P, n_pg = 1, 6, 4, 2, 16, 4, 8, 3
    start, lens = jnp.asarray([3], jnp.int32), jnp.asarray([6], jnp.int32)
    bt = jnp.asarray([[1, 2, 3]], jnp.int32)
    pool = make_pool(P, ps, KVH, d, None)
    pool = ref.paged_prefill_write_ref(
        rand((B, S, KVH, d), 30), rand((B, S, KVH, d), 31), pool, bt,
        start, lens)
    q = rand((B, S, H, d), 32)
    want = ref.paged_prefill_attention_ref(q, pool["k_pages"],
                                           pool["v_pages"], bt, start, lens)
    got = pp.paged_prefill_attend(q, pool["k_pages"], pool["v_pages"], bt,
                                  start, lens, block_q=block_q,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-4)


# --------------------------------------------- fused op + shard identity --

@pytest.mark.parametrize("quant", [None, "fp8"])
def test_ops_paged_prefill_fused(quant):
    """The registered ``ops.paged_prefill`` entry = write-ref then
    attend-ref, pools bit-identical, outputs allclose."""
    B, S, H, KVH, d, ps, P, n_pg = 2, 5, 4, 2, 16, 4, 9, 4
    start = jnp.asarray([2, 0], jnp.int32)
    lens = jnp.asarray([5, 3], jnp.int32)
    bt = jnp.asarray(np.random.RandomState(2).choice(
        np.arange(1, P), (B, n_pg), replace=False), jnp.int32)
    pool = make_pool(P, ps, KVH, d, quant)
    q = rand((B, S, H, d), 40)
    k_new, v_new = rand((B, S, KVH, d), 41), rand((B, S, KVH, d), 42)

    o, new_pool = ops.paged_prefill(q, k_new, v_new, pool, bt, start, lens,
                                    quant=quant, interpret=True)
    want_pool = ref.paged_prefill_write_ref(k_new, v_new, pool, bt, start,
                                            lens, quant=quant)
    assert pool_equal(new_pool, want_pool)
    want_o = ref.paged_prefill_attention_ref(
        q, want_pool["k_pages"], want_pool["v_pages"], bt, start, lens,
        k_scale_pages=want_pool.get("k_scale_pages"),
        v_scale_pages=want_pool.get("v_scale_pages"))
    for b in range(B):
        n = int(lens[b])
        np.testing.assert_allclose(np.asarray(o[b, :n]),
                                   np.asarray(want_o[b, :n]),
                                   rtol=2e-5, atol=1e-4)


def test_ops_paged_prefill_sharded_byte_identical():
    """tp=2 shard-group fused prefill == tp=1: concatenated head outputs
    and per-shard pools reassemble bit-identically."""
    B, S, H, KVH, d, ps, P, n_pg, tp = 2, 5, 4, 2, 16, 4, 9, 4, 2
    start = jnp.asarray([3, 0], jnp.int32)
    lens = jnp.asarray([5, 2], jnp.int32)
    bt = jnp.asarray(np.random.RandomState(3).choice(
        np.arange(1, P), (B, n_pg), replace=False), jnp.int32)
    pool1 = make_pool(P, ps, KVH, d, None)
    q = rand((B, S, H, d), 50)
    k_new, v_new = rand((B, S, KVH, d), 51), rand((B, S, KVH, d), 52)

    o1, new1 = ops.paged_prefill(q, k_new, v_new, pool1, bt, start, lens,
                                 interpret=True)
    KVHs = KVH // tp
    pool2 = {k: jnp.stack([v[:, :, s * KVHs:(s + 1) * KVHs]
                           for s in range(tp)])
             for k, v in pool1.items()}
    o2, new2 = ops.paged_prefill_sharded(q, k_new, v_new, pool2, bt, start,
                                         lens, interpret=True)
    assert bool(jnp.array_equal(o1, o2))
    for k in new1:
        merged = jnp.concatenate([new2[k][s] for s in range(tp)], axis=2)
        assert bool(jnp.array_equal(new1[k], merged))


def test_prefill_autotune_registry():
    """Registered tuning entries steer block_q; unknown keys fall back."""
    key = ops.prefill_tuning_key(4, 16, 2, 8, 4)
    prev = ops.register_prefill_tuning({key: {"block_q": 2}})
    try:
        assert ops._prefill_tuned_block_q(4, 16, 2, 8, 4) == 2
        assert ops._prefill_tuned_block_q(4, 16, 2, 64, 4) == 64
    finally:
        ops.register_prefill_tuning(prev)


# --------------------------------------- model path vs write_prefill copy --

@pytest.mark.parametrize("quant", [False, "int8", "fp8"])
def test_direct_write_matches_write_prefill_route(quant):
    """Fused paged prefill == the legacy dense-prefill + ``write_prefill``
    copy route: identical next token, and the first layer's landed pool
    bytes identical (deeper layers' K/V inherit attention's float error,
    which fp32 keeps far from any argmax tie)."""
    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32",
                              cache_quant=quant)
    params = M.init(cfg, jax.random.PRNGKey(0))
    plen, ps, num_pages = 11, 4, 16
    toks = jax.random.randint(jax.random.fold_in(KEY, 60), (1, plen), 0,
                              cfg.vocab_size)
    n_pg = PC.pages_for_len(plen + 1, ps)
    row = jnp.asarray([list(range(1, n_pg + 1))
                       + [0] * (6 - n_pg)][:1], jnp.int32) \
        if n_pg < 6 else jnp.asarray([list(range(1, n_pg + 1))], jnp.int32)

    # legacy: dense prefill -> page-copy insert
    lg_l, pre = M.prefill(cfg, params, {"tokens": toks})
    cache_l = PC.init_paged_cache(cfg, num_pages, ps, 1)
    cache_l = PC.write_prefill(cfg, cache_l, pre, row[0], 0, plen, plen, ps)
    tok_l = int(jnp.argmax(lg_l[0, -1, :cfg.vocab_size]))

    # fused: direct page writes, one call
    cache_f = PC.init_paged_cache(cfg, num_pages, ps, 1)
    hidden, cache_f = M.paged_prefill_step(
        cfg, params, cache_f, toks, jnp.asarray([0], jnp.int32),
        jnp.asarray([plen], jnp.int32), row)
    lg_f = M.final_logits(cfg, params, hidden[:, plen - 1:plen])
    tok_f = int(jnp.argmax(lg_f[0, -1, :cfg.vocab_size]))

    assert tok_f == tok_l
    # layer 0: same K/V inputs, same quantisation -> byte-identical pages
    for leaf in ("k_pages", "v_pages"):
        a = cache_f["stack"]["0"][leaf][0]
        b = cache_l["stack"]["0"][leaf][0]
        assert bool(jnp.array_equal(a, b)), f"layer-0 {leaf} differ"


# ------------------------------------------------------- scheduler gates --

def _mk_sched(cfg, params, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq_len", 64)
    return ContinuousBatchingScheduler(cfg, params, **kw)


def _serve(cfg, params, prompts, gens, **kw):
    s = _mk_sched(cfg, params, **kw)
    reqs = [s.submit(p, g) for p, g in zip(prompts, gens)]
    s.run()
    return [list(r.out_tokens) for r in reqs], s


@pytest.fixture(scope="module")
def dense_setup():
    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 13, 21)]
    gens = [5, 4, 6]
    return cfg, params, prompts, gens


def test_scheduler_fused_matches_legacy(dense_setup):
    cfg, params, prompts, gens = dense_setup
    legacy, _ = _serve(cfg, params, prompts, gens, prefill_fused=False)
    fused, _ = _serve(cfg, params, prompts, gens, prefill_fused=True)
    chunked, _ = _serve(cfg, params, prompts, gens, prefill_fused=True,
                        prefill_budget=6)
    assert fused == legacy
    assert chunked == legacy


def test_scheduler_kernel_matches_xla(dense_setup):
    cfg, params, prompts, gens = dense_setup
    xla, _ = _serve(cfg, params, prompts, gens, prefill_budget=8)
    kern, _ = _serve(cfg, params, prompts, gens, prefill_budget=8,
                     prefill_kernel=True)
    assert kern == xla


@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_scheduler_quant_kernel_matches_xla(quant, dense_setup):
    """Quantised pools: kernel on/off byte-identical at matching pool
    dtype (the in-kernel quantisation is bit-equal to quantize_kv)."""
    cfg, params, prompts, gens = dense_setup
    qcfg = dataclasses.replace(cfg, cache_quant=quant)
    xla, _ = _serve(qcfg, params, prompts[:2], gens[:2], prefill_budget=8)
    kern, _ = _serve(qcfg, params, prompts[:2], gens[:2], prefill_budget=8,
                     prefill_kernel=True)
    assert kern == xla


def test_scheduler_fused_tp2_matches_tp1(dense_setup):
    cfg, params, prompts, gens = dense_setup
    t1, _ = _serve(cfg, params, prompts, gens, prefill_budget=8)
    t2, _ = _serve(cfg, params, prompts, gens, prefill_budget=8, tp=2)
    assert t2 == t1


def test_exact_prefill_archs_keep_sequential_path():
    cfg = REDUCED["jamba-v0.1-52b"]
    params = M.init(cfg, jax.random.PRNGKey(0))
    s = _mk_sched(cfg, params, prefill_fused=True)   # silently disabled
    assert not s.prefill_fused


# ------------------------------------------------- program cache + stats --

def test_program_cache_shared_across_instances(dense_setup):
    cfg, params, prompts, gens = dense_setup
    SCH.clear_program_cache()
    try:
        _, s1 = _serve(cfg, params, prompts, gens, prefill_budget=6)
        assert s1.stats["prefill_compiles"] > 0
        assert s1.stats["prefill_dispatches"] > 0
        size = SCH.program_cache_size()
        _, s2 = _serve(cfg, params, prompts, gens, prefill_budget=6)
        assert s2.stats["prefill_compiles"] == 0      # everything reused
        assert s2.stats["prefill_dispatches"] == s1.stats[
            "prefill_dispatches"]
        assert SCH.program_cache_size() == size
    finally:
        SCH.clear_program_cache()


def test_program_cache_keys_isolate_kernel_flag(dense_setup):
    cfg, params, prompts, gens = dense_setup
    SCH.clear_program_cache()
    try:
        _, s1 = _serve(cfg, params, prompts[:1], gens[:1])
        _, s2 = _serve(cfg, params, prompts[:1], gens[:1],
                       prefill_kernel=True)
        assert s2.stats["prefill_compiles"] > 0       # distinct programs
    finally:
        SCH.clear_program_cache()


def test_fused_halves_first_chunk_dispatches(dense_setup):
    """The perf story behind BENCH_prefill.json: legacy monolithic
    admission costs 2 dispatches (prefill + page-copy insert); fused
    costs 1."""
    cfg, params, prompts, gens = dense_setup
    _, legacy = _serve(cfg, params, prompts[:1], gens[:1],
                       prefill_fused=False)
    _, fused = _serve(cfg, params, prompts[:1], gens[:1])
    assert legacy.stats["prefill_dispatches"] == 2
    assert fused.stats["prefill_dispatches"] == 1
