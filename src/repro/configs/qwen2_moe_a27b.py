"""qwen2-moe-a2.7b [moe] — 4 shared (fused 5632) + 60 routed top-4.

24L d_model=2048 16H (kv=16) expert_ff=1408 vocab=151936
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    n_routed_experts=60,
    n_shared_experts=4,
    moe_top_k=4,
    expert_d_ff=1408,
    shared_expert_d_ff=5632,
    shared_expert_gate=True,
    norm_topk_prob=True,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="qwen2-moe-a2.7b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    n_routed_experts=6,
    n_shared_experts=2,
    moe_top_k=2,
    expert_d_ff=32,
    shared_expert_d_ff=64,
    shared_expert_gate=True,
    norm_topk_prob=True,
    tie_embeddings=False,
)
