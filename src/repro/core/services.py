"""Service provisioning — the Ambari-analogue server, agents and catalog.

The paper delegates service provisioning to Ambari: a server on the master
installs/configures/starts services on agent nodes and watches heartbeats.
Here the "services" are the framework's subsystems (data pipeline, trainer,
serving engine, checkpoint store, monitor, interaction hub) plus the paper's
Table-1 Big-Data catalog mapped onto them, and Table-2's ports preserved.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional

from repro.core.events import EventLog
from repro.core.heartbeat import HeartbeatMonitor
from repro.core.provisioner import Cluster
from repro.core.simcloud import LATENCY, SimCloud

# ---------------------------------------------------------------------------
# Table 2 (paper) + Ambari port, extended with framework endpoints
# ---------------------------------------------------------------------------
PORTS = {
    "ambari": 8080,
    "spark-driver": 7077,
    "spark-webui": 8888,
    "spark-jobserver": 8090,
    "hue": 8808,
    # framework endpoints
    "train": 7077,          # the Spark-analogue compute service
    "serve": 8090,
    "datastore": 9000,      # HDFS namenode-analogue
    "monitor": 8661,
}

# ---------------------------------------------------------------------------
# Table 1 (paper): service -> (provisioning support, interaction support)
# Mapped onto framework analogues; n/s entries reproduced faithfully.
# ---------------------------------------------------------------------------
SERVICE_MATRIX = {
    #  name              provisioned_by   interaction    framework analogue
    "hdfs":            ("ambari",        "hue",          "datastore"),
    "yarn":            ("ambari",        "hue",          "scheduler"),
    "tez":             ("ambari",        None,           "compiler-cache"),
    "hive":            ("ambari",        "hue",          "metrics-sql"),
    "hbase":           ("ambari",        "hue",          "kvstore"),
    "pig":             ("ambari",        "hue",          "batch-script"),
    "sqoop":           ("ambari",        "hue",          "data-import"),
    "oozie":           ("ambari",        "hue",          "workflow"),
    "zookeeper":       ("ambari",        "hue",          "coordination"),
    "falcon":          ("ambari",        None,           "lineage"),
    "storm":           ("ambari",        "native",       "stream"),
    "flume":           ("ambari",        None,           "log-ingest"),
    "slider":          ("ambari",        None,           "long-running"),
    "knox":            ("ambari",        None,           "gateway"),
    "kafka":           ("ambari",        None,           "queue"),
    "spark":           ("ambari",        "hue",          "train"),        # *
    "impala":          (None,            "hue",          "serve"),
    "hue":             ("ambari*",       "native",       "interaction"),  # * = this paper's contribution
    "nagios":          ("ambari",        "ambari",       "monitor"),
    "ganglia":         ("ambari",        "ambari",       "monitor"),
}


class ServiceState(enum.Enum):
    INSTALLED = "installed"
    STARTED = "started"
    STOPPED = "stopped"
    FAILED = "failed"


@dataclasses.dataclass
class ServiceInstance:
    name: str
    port: Optional[int]
    placement: List[str]                 # hostnames
    state: ServiceState
    config: Dict[str, Any]


class AmbariServer:
    """Service-provisioning server running on the cluster master."""

    def __init__(self, cloud: SimCloud, cluster: Cluster,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.cloud = cloud
        self.cluster = cluster
        self.monitor = monitor or HeartbeatMonitor()
        self.services: Dict[str, ServiceInstance] = {}
        self.port = PORTS["ambari"]
        for node in cluster.directory.slaves():
            self.monitor.register(node.hostname, now=cloud.clock)

    # ------------------------------------------------------------ catalog --
    @staticmethod
    def available_services() -> List[str]:
        return sorted(SERVICE_MATRIX)

    def suggest_config(self, name: str) -> Dict[str, Any]:
        """Ambari-style suggested configuration; user may override."""
        slaves = self.cluster.directory.slaves()
        return {
            "placement": [n.hostname for n in slaves],
            "port": PORTS.get(SERVICE_MATRIX.get(name, (0, 0, name))[2],
                              PORTS.get(name)),
            "replicas": max(1, len(slaves) // 2) if name == "hdfs"
            else len(slaves),
        }

    # ------------------------------------------------------------- install --
    def install(self, names: List[str],
                config_overrides: Optional[Dict[str, Dict[str, Any]]] = None
                ) -> List[ServiceInstance]:
        """Install a service selection (one install latency per wave — the
        agents work in parallel, which is where the paper's speedup lives)."""
        out = []
        self.cloud._advance(LATENCY["service_install"])
        for name in names:
            if name not in SERVICE_MATRIX:
                raise KeyError(f"unknown service {name!r} (Table 1)")
            prov = SERVICE_MATRIX[name][0]
            if prov is None:
                raise ValueError(
                    f"service {name!r} has no provisioning support (n/s in "
                    f"Table 1); install its backing analogue instead")
            cfg = self.suggest_config(name)
            cfg.update((config_overrides or {}).get(name, {}))
            svc = ServiceInstance(name=name, port=cfg.get("port"),
                                  placement=cfg["placement"],
                                  state=ServiceState.INSTALLED, config=cfg)
            self.services[name] = svc
            self.cluster.log.emit(self.cloud.clock, "ambari",
                                  "install_service", service=name,
                                  placement=len(cfg["placement"]))
            out.append(svc)
        return out

    # ---------------------------------------------------------- serving --
    def provision_serving(self, model_cfg, shape, mesh=None,
                          config_overrides: Optional[Dict[str, Any]] = None,
                          replicas: int = 1, tp: int = 1) -> ServiceInstance:
        """Install the continuous-batching serving engine as a service.

        The framework analogue of installing Impala's backing service: the
        page-pool sizing comes from the blueprint planner
        (``repro.core.blueprint.serving_page_plan``) the same way Ambari
        suggests a service configuration from cluster facts, and the user
        may override any knob before start. ``model_cfg``/``shape`` are the
        arch + input-shape cell being served.

        ``replicas=k`` provisions the replicated fabric
        (``repro.serving.router``): the plan carries the per-replica
        slot/page split and ``replica_placement`` pins each replica to a
        cluster node (round-robin over the directory's slaves — the fabric
        router and fleet autoscaler key drain/re-route on these hostnames).

        ``tp=k`` makes every replica a *shard group*: ``replica_placement``
        entries become contiguous k-node hostname lists (group i spans
        slaves ``[i*k, (i+1)*k)`` — contiguity keeps a group's members on
        adjacent ranks, the layout the group's all-gather wants), and the
        cluster must hold ``replicas * k`` slaves so no two shards of one
        group share a node.
        """
        from repro.core.blueprint import serving_page_plan
        pool = serving_page_plan(model_cfg, shape, mesh, replicas=replicas,
                                 tp=tp)
        if pool is None:
            raise ValueError(
                f"{model_cfg.name} is not paged-servable (MLA/enc-dec/"
                "pure-SSM); provision the dense engine instead")
        if pool["num_pages"] < 1:
            raise ValueError(
                f"{model_cfg.name} on {shape.name}: bf16 params leave no "
                f"HBM for KV pages on this mesh — provision more chips "
                f"(plan: {pool})")
        self.cloud._advance(LATENCY["service_install"])
        cfg = self.suggest_config("impala")      # serve endpoint placement
        cfg.update(pool)
        cfg["arch"] = model_cfg.name
        cfg["shape"] = shape.name
        slaves = self.cluster.directory.slaves()
        if tp > 1:
            if len(slaves) < replicas * tp:
                raise ValueError(
                    f"{replicas} shard groups of tp={tp} need "
                    f"{replicas * tp} slaves; cluster has {len(slaves)} — "
                    "a group must span distinct nodes")
            cfg["replica_placement"] = [
                [slaves[i * tp + j].hostname for j in range(tp)]
                for i in range(replicas)]
        else:
            cfg["replica_placement"] = [
                slaves[i % len(slaves)].hostname if slaves else None
                for i in range(replicas)]
        cfg.update(config_overrides or {})
        svc = ServiceInstance(name="serve", port=cfg.get("port"),
                              placement=cfg["placement"],
                              state=ServiceState.INSTALLED, config=cfg)
        self.services["serve"] = svc
        self.cluster.log.emit(self.cloud.clock, "ambari", "install_service",
                              service="serve", placement=len(cfg["placement"]),
                              num_pages=pool["num_pages"],
                              page_size=pool["page_size"],
                              replicas=replicas, tp=tp)
        return svc

    def start(self, name: str) -> ServiceInstance:
        svc = self.services[name]
        self.cloud._advance(LATENCY["service_start"])
        svc.state = ServiceState.STARTED
        self.cluster.log.emit(self.cloud.clock, "ambari", "start_service",
                              service=name, port=svc.port)
        return svc

    def stop(self, name: str) -> None:
        svc = self.services[name]
        svc.state = ServiceState.STOPPED
        self.cluster.log.emit(self.cloud.clock, "ambari", "stop_service",
                              service=name)

    def status(self) -> Dict[str, str]:
        return {n: s.state.value for n, s in self.services.items()}

    # ---------------------------------------------------------- heartbeats --
    def agent_heartbeat(self, hostname: str,
                        step_time: Optional[float] = None) -> None:
        self.monitor.beat(hostname, self.cloud.clock, step_time=step_time)

    def check_agents(self) -> Dict[str, str]:
        return {h: s.value
                for h, s in self.monitor.check(self.cloud.clock).items()}
