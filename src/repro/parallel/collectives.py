"""Distributed-optimization collectives: int8-compressed gradient sync.

Cross-pod gradient reduction rides the slow DCN links; compressing that hop
is the classic distributed-optimization trick. ``compressed_psum`` performs
an all-reduce over a mesh axis where the wire format is per-chunk-scaled
int8 (error-feedback optional at the call site): each shard all-gathers the
quantized operand (1 byte/elem + scales) and dequant-sums locally — 4x
fewer bytes on the wire than an fp32 ring all-reduce's 2x traversal.

Used inside ``jax.shard_map`` with ``axis_names={axis}`` (all other mesh
axes stay automatic), so XLA keeps handling data/model sharding while the
pod-axis collective is explicit.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map without replication checking, across jax versions: newest
    jax spells it ``jax.shard_map(..., check_vma=)``, the 0.5-0.6 band has
    ``jax.shard_map(..., check_rep=)``, and 0.4.x keeps it under
    ``jax.experimental.shard_map`` with ``check_rep=``."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:   # top-level shard_map that still takes check_rep
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def quantize_int8(x: jnp.ndarray, block: int = 256
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantisation. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape: Tuple[int, ...],
                    dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    block: int = 256) -> jnp.ndarray:
    """int8-on-the-wire all-reduce over ``axis_name`` (inside shard_map)."""
    q, scale = quantize_int8(x, block)
    qg = jax.lax.all_gather(q, axis_name)          # int8 bytes on the wire
    sg = jax.lax.all_gather(scale, axis_name)
    total = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    flat = total.reshape(-1)
    n = 1
    for d in x.shape:
        n *= d
    return flat[:n].reshape(x.shape).astype(x.dtype)


def compression_error_bound(x: jnp.ndarray, block: int = 256) -> float:
    """Worst-case per-element quantisation error: scale/2 per block."""
    q, scale = quantize_int8(x, block)
    return float(jnp.max(scale)) / 2.0


def make_compressed_grad_sync(mesh, axis: str = "pod", block: int = 256,
                              leaf_spec: P = None):
    """Returns grads -> grads *averaged* over ``axis`` with int8 wire format.

    ``leaf_spec`` describes the physical layout of each gradient leaf
    (default: sharded over ``axis`` on dim 0, replicated elsewhere); the
    compressed all-reduce runs over ``axis`` only.
    """
    spec = leaf_spec if leaf_spec is not None else P(axis)

    def sync_leaf(g):
        fn = shard_map_compat(
            lambda t: compressed_psum(t, axis, block) / mesh.shape[axis],
            mesh=mesh, in_specs=spec, out_specs=spec)
        return fn(g)

    def sync(grads):
        return jax.tree.map(sync_leaf, grads)

    return sync
