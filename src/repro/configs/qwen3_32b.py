"""qwen3-32b [dense] — per-head q/k RMS norm, GQA kv=8.

64L d_model=5120 64H (GQA kv=8, head_dim 128) d_ff=25600 vocab=151936
[hf:Qwen/Qwen3-8B scaled per assignment; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    rms_eps=1e-6,
)

REDUCED = ModelConfig(
    name="qwen3-32b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=192,
    vocab_size=512,
    qk_norm=True,
    tie_embeddings=False,
)
