"""Paged-KV serving: allocator invariants, kernel-vs-oracle equivalence
(interpret mode), paged-vs-dense decode equivalence, and the continuous
batching scheduler's late-join determinism property."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.kernels import ops, ref
from repro.models import model as M
from repro.serving import engine as E
from repro.serving import paged_cache as PC
from repro.serving.scheduler import ContinuousBatchingScheduler, supports_paged

KEY = jax.random.PRNGKey(7)


def rand(shape, i, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


# ------------------------------------------------------------- allocator --

def test_allocator_invariants():
    a = PC.PageAllocator(10)                 # 9 allocatable + sink
    assert a.num_free == 9
    p1 = a.alloc(4, owner="r1")
    p2 = a.alloc(5, owner="r2")
    assert a.num_free == 0 and a.num_allocated == 9
    assert PC.SINK_PAGE not in p1 + p2       # sink never handed out
    assert len(set(p1) | set(p2)) == 9       # no double allocation
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.free(p1)
    assert a.num_free == 4
    with pytest.raises(ValueError):
        a.free(p1)                           # double free
    with pytest.raises(ValueError):
        a.free([PC.SINK_PAGE])
    a.free(p2)
    assert a.num_free == 9 and a.num_allocated == 0


def test_pages_for_len():
    assert PC.pages_for_len(1, 8) == 1
    assert PC.pages_for_len(8, 8) == 1
    assert PC.pages_for_len(9, 8) == 2


# ------------------------------------------------- kernel vs ref oracle --

@pytest.mark.parametrize("window,softcap", [(None, None), (None, 30.0),
                                            (10, None), (12, 50.0)])
def test_paged_decode_kernel_matches_ref(window, softcap):
    B, H, KVH, d, ps, P, n_pg = 3, 8, 2, 32, 8, 17, 4
    q = rand((B, H, d), 1)
    kp = rand((P, ps, KVH, d), 2)
    vp = rand((P, ps, KVH, d), 3)
    bt = jnp.asarray(np.random.RandomState(0).choice(
        np.arange(1, P), (B, n_pg)), jnp.int32)
    lens = jnp.asarray([5, 32, 17], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lens, softcap=softcap,
                                     window=window, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lens,
                                          softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_kernel_matches_dense_ref():
    """Identity block table + full pages == contiguous dense decode."""
    B, H, KVH, d, ps, n_pg = 2, 4, 2, 32, 8, 3
    S = ps * n_pg
    q = rand((B, H, d), 4)
    k = rand((B, S, KVH, d), 5)
    v = rand((B, S, KVH, d), 6)
    # pages 1.. hold the contiguous cache rows; page 0 is the sink
    kp = jnp.concatenate([jnp.zeros((1, ps, KVH, d))] + [
        k[b].reshape(n_pg, ps, KVH, d) for b in range(B)])
    vp = jnp.concatenate([jnp.zeros((1, ps, KVH, d))] + [
        v[b].reshape(n_pg, ps, KVH, d) for b in range(B)])
    bt = jnp.asarray(1 + np.arange(B * n_pg).reshape(B, n_pg), jnp.int32)
    lens = jnp.asarray([S, S - 3], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_kernel_quantised():
    B, H, KVH, d, ps, P, n_pg = 2, 4, 2, 32, 8, 9, 3
    from repro.models.attention import quantize_kv
    kp = rand((P, ps, KVH, d), 7)
    vp = rand((P, ps, KVH, d), 8)
    k8, ks = quantize_kv(kp)
    v8, vs = quantize_kv(vp)
    q = rand((B, H, d), 9)
    bt = jnp.asarray(np.random.RandomState(1).choice(
        np.arange(1, P), (B, n_pg)), jnp.int32)
    lens = jnp.asarray([20, 11], jnp.int32)
    out = ops.paged_decode_attention(q, k8, v8, bt, lens, k_scale_pages=ks,
                                     v_scale_pages=vs, interpret=True)
    want = ref.paged_decode_attention_ref(
        q, k8.astype(jnp.float32) * ks[..., None],
        v8.astype(jnp.float32) * vs[..., None], bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_decode_zero_length_finite():
    B, H, KVH, d, ps, P, n_pg = 2, 4, 2, 32, 8, 5, 2
    out = ops.paged_decode_attention(
        rand((B, H, d), 10), rand((P, ps, KVH, d), 11),
        rand((P, ps, KVH, d), 12),
        jnp.zeros((B, n_pg), jnp.int32), jnp.zeros((B,), jnp.int32),
        interpret=True)
    assert np.isfinite(np.asarray(out)).all()


# --------------------------------------- paged model path vs dense engine --

@pytest.mark.parametrize("arch,quant", [
    ("qwen3-32b", False),            # plain GQA
    ("gemma2-2b", False),            # sliding window + softcaps
    ("jamba-v0.1-52b", False),       # hybrid attn+SSM (dense state slots)
    ("qwen2-moe-a2.7b", False),      # MoE decode routing path
    ("qwen3-32b", True),             # int8-quantised pools
])
def test_paged_decode_matches_dense_engine(arch, quant):
    """Prefill -> page insert -> paged decode reproduces the dense engine's
    greedy tokens exactly.

    fp32 activations: the dense and paged paths are different XLA programs,
    and in bf16/int8 their reassociated reductions can drift ~1e-3 — enough
    to flip a greedy argmax on near-ties. fp32 shrinks the drift ~2^13 so
    exact token equality is a stable assertion of the *logic*, not of
    bitwise numerics XLA never promises.
    """
    cfg = dataclasses.replace(REDUCED[arch], cache_quant=quant,
                              dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    plen, gen, ps = 13, 8, 8
    toks = jax.random.randint(KEY, (1, plen), 0, cfg.vocab_size)

    lg, cache, cur = E.prefill(cfg, params, {"tokens": toks},
                               capacity=plen + gen + 2)
    first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
        jnp.int32)[:, None]
    dtoks, _, _ = E.greedy_decode(cfg, params, cache, first, cur, gen - 1)
    dense_out = [int(first[0, 0])] + [int(t) for t in dtoks[0]]

    sched = ContinuousBatchingScheduler(cfg, params, max_slots=1,
                                        page_size=ps, max_seq_len=64)
    req = sched.submit(np.asarray(toks[0]), gen)
    sched.run()
    assert req.out_tokens == dense_out


def test_scheduler_late_join_determinism():
    """Requests joining a running batch decode the same tokens as solo.

    fp32 for argmax stability across the two differently-shaped compiled
    programs (1-slot vs 2-slot) — see the note on the equivalence test.
    """
    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 12, 20, 9)]
    gens = [6, 3, 8, 5]

    solo = []
    for p, g in zip(prompts, gens):
        s = ContinuousBatchingScheduler(cfg, params, max_slots=1,
                                        page_size=8, max_seq_len=64)
        s.submit(p, g)
        solo.append(s.run()[0].out_tokens)

    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64)
    reqs = [s.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(zip(prompts, gens))]
    s.run()
    for r, want in zip(reqs, solo):
        assert r.out_tokens == want
    # evict-on-finish returned every page; reservations drained
    assert s.alloc.num_allocated == 0
    assert s.reserved_pages == 0
    assert all(r.finish_step is not None for r in reqs)


def test_scheduler_rejects_unsupported():
    cfg = REDUCED["deepseek-v2-236b"]          # MLA
    assert not supports_paged(cfg)
    with pytest.raises(NotImplementedError):
        ContinuousBatchingScheduler(cfg, params=None)


def test_scheduler_admission_respects_pool():
    """With a pool too small for two worst-case requests, the second waits
    and still completes after the first frees its pages."""
    cfg = REDUCED["qwen3-32b"]
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    # each request reserves ceil((8+8)/8)=2 pages; pool holds 3 (+sink)
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    num_pages=4, max_seq_len=16)
    r1 = s.submit(rng.randint(0, cfg.vocab_size, size=8), 8)
    r2 = s.submit(rng.randint(0, cfg.vocab_size, size=8), 8)
    s.step()
    assert r1.admit_step is not None and r2.admit_step is None
    s.run()
    assert r2.finish_step is not None and len(r2.out_tokens) == 8
    assert s.alloc.num_allocated == 0


def test_scheduler_rejects_unservable_request():
    """A reservation that could never fit the pool fails at submit, not by
    spinning the run loop forever."""
    cfg = REDUCED["qwen3-32b"]
    params = M.init(cfg, jax.random.PRNGKey(0))
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    num_pages=4, max_seq_len=64)
    with pytest.raises(ValueError, match="never be admitted"):
        s.submit(np.zeros(40, np.int32), 20)   # needs 8 pages, pool holds 3
    with pytest.raises(ValueError, match="max_new_tokens"):
        s.submit(np.zeros(4, np.int32), 0)


def test_scheduler_single_token_request_finishes_via_step():
    """max_new_tokens == 1 completes at prefill; step() must still report it
    and hand its slot to a same-tick waiting request."""
    cfg = REDUCED["qwen3-32b"]
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(5)
    s = ContinuousBatchingScheduler(cfg, params, max_slots=1, page_size=8,
                                    max_seq_len=32)
    r1 = s.submit(rng.randint(0, cfg.vocab_size, size=6), 1)
    r2 = s.submit(rng.randint(0, cfg.vocab_size, size=6), 2)
    done = s.step()
    assert r1 in done and r1.finish_step is not None
    assert r2.admit_step is not None           # took r1's slot the same tick
    s.run()
    assert len(r2.out_tokens) == 2


def test_scheduler_finish_step_fuse_invariant():
    """Fusion is a dispatch optimisation: recorded finish ticks must not
    depend on max_fuse."""
    cfg = REDUCED["qwen3-32b"]
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9)]
    gens = [7, 4]
    records = []
    for fuse in (1, 32):
        s = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                        page_size=8, max_seq_len=32)
        reqs = [s.submit(p, g) for p, g in zip(prompts, gens)]
        s.run(max_fuse=fuse)
        records.append([(r.admit_step, r.finish_step) for r in reqs])
    assert records[0] == records[1]


# ------------------------------------------------ blueprint + provisioning --

def test_serving_page_plan_sizing():
    from repro.configs.base import SHAPES
    from repro.core.blueprint import serving_page_plan
    from repro.configs.registry import ARCHS
    plan = serving_page_plan(ARCHS["qwen3-32b"], SHAPES["decode_32k"],
                             {"model": 8, "data": 4})
    assert plan["num_pages"] > 0
    assert plan["pages_per_seq"] == -(-32768 // plan["page_size"])
    assert plan["pool_bytes"] <= 32 * 16 * 1024 ** 3
    # MLA archs keep the dense engine
    assert serving_page_plan(ARCHS["deepseek-v2-236b"],
                             SHAPES["decode_32k"]) is None


def test_provision_serving_service():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.core.provisioner import ClusterProvisioner
    from repro.core.services import AmbariServer, PORTS
    from repro.core.simcloud import SimCloud
    cloud = SimCloud(seed=7)
    cloud.register_key("AK", "SK")
    prov = ClusterProvisioner(cloud, region="us-east-1", access_key_id="AK",
                              secret_key="SK")
    cluster = prov.provision(n_slaves=2)
    server = AmbariServer(cloud, cluster)
    svc = server.provision_serving(ARCHS["qwen3-32b"], SHAPES["decode_32k"],
                                   {"model": 8, "data": 4})
    assert svc.port == PORTS["serve"]
    assert svc.config["num_pages"] > 0
    assert server.status()["serve"] == "installed"
    server.start("serve")
    assert server.status()["serve"] == "started"
