"""Elastic autoscaling control plane: live resize token-identity, allocator
grow/shrink invariants, policy hysteresis, cluster wiring (extend/shrink,
spot preemption -> warm-spare replacement), event-log replay, the
cost-vs-latency acceptance criterion on the bursty trace, and the fleet
controller's replica axis (grow/drain/shrink over the serving fabric)."""
import dataclasses
import pathlib
import sys

import jax
import numpy as np
import pytest

from repro.autoscale import (AutoscaleController, CapacityBands,
                             FleetController, StepScalingPolicy,
                             TargetTrackingPolicy)
from repro.autoscale.controller import pow2_bucket
from repro.configs.registry import REDUCED
from repro.core.cluster import ClusterManager
from repro.core.events import EventLog
from repro.core.heartbeat import HeartbeatMonitor
from repro.models import model as M
from repro.serving import paged_cache as PC
from repro.serving.scheduler import ContinuousBatchingScheduler

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "benchmarks"))
import autoscale_bench as AB                                    # noqa: E402

CFG = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


# ------------------------------------------------------------- allocator --

def test_allocator_grow_shrink():
    a = PC.PageAllocator(6)                  # pages 1..5
    low = a.alloc(5, owner="r")
    a.grow(10)                               # adds 6..9
    assert a.num_free == 4 and a.num_pages == 10
    high = a.alloc(4, owner="s")
    assert set(low + high) == set(range(1, 10))
    a.free(low)
    a.request_shrink(6)                      # s still owns pages 6..9
    assert not a.shrink_ready()              # drain-before-shrink
    assert a.capacity == 5 and a.num_free == 5
    a.free(high)                             # freed high pages are retired,
    assert a.num_free == 5                   # not returned to the free list
    assert a.shrink_ready()
    assert a.complete_shrink() == 6
    assert a.num_free == 5 and a.num_pages == 6


def test_allocator_shrink_relax_and_cancel():
    a = PC.PageAllocator(10)
    a.request_shrink(4)
    assert a.capacity == 3
    a.request_shrink(8)                      # relax: 4..7 un-retired
    assert a.capacity == 7 and a.num_free == 7
    a.grow(12)                               # cancel: everything back + new
    assert a.num_free == 11 and not a.shrink_pending


def test_allocator_relax_to_full_then_grow_no_phantom_shrink():
    """Regression: relaxing a shrink back to the exact pool size must clear
    the target; a stale target used to turn the next grow into a phantom
    pending shrink whose completion sliced the grown pool out from under
    the free list (double allocation of the same page id)."""
    a = PC.PageAllocator(21)
    a.request_shrink(11)
    a.request_shrink(21)                     # full relax == cancellation
    assert not a.shrink_pending
    p = a.alloc(1, owner="x")[0]
    a.free([p])                              # no limbo drop with no shrink
    assert a.num_free == 20
    a.grow(41)
    assert not a.shrink_ready()              # no phantom shrink to complete
    got = a.alloc(40, owner="y")
    assert len(set(got)) == 40               # every page id handed out once


def test_scheduler_page_shrink_is_reservation_aware(params):
    """Shrinking below outstanding reservations clamps instead of letting a
    mid-flight _grow_pages OOM."""
    rng = np.random.RandomState(0)
    s = ContinuousBatchingScheduler(CFG, params, max_slots=2, page_size=8,
                                    num_pages=9, max_seq_len=32)
    r1 = s.submit(rng.randint(0, CFG.vocab_size, size=8), 16)   # 3 pages
    r2 = s.submit(rng.randint(0, CFG.vocab_size, size=8), 16)   # 3 pages
    s.step()
    assert s.reserved_pages == 6
    s.resize(num_pages=2)                    # floor: reserved + sink = 7
    assert s.alloc.capacity >= s.reserved_pages
    s.run()                                  # must complete without OOM
    assert r1.done and r2.done
    s._settle_resize()
    assert s.alloc.num_pages == 7


# ---------------------------------------------------------------- policy --

def test_target_tracking_deadband_and_cooldown():
    p = TargetTrackingPolicy(metric="m", target=0.8, tolerance=0.1,
                             min_cap=1, max_cap=16, cooldown_in=30.0)
    assert p.evaluate(0.0, 0.8, 4) is None            # on target
    assert p.evaluate(0.0, 0.85, 4) is None           # inside deadband
    d = p.evaluate(1.0, 1.6, 4)                       # 2x over target
    assert d.desired == 8 and d.delta == 4 and d.direction == "out"
    d = p.evaluate(2.0, 0.1, 8)
    assert d.desired == 1 and d.direction == "in"
    assert p.evaluate(10.0, 0.1, 8) is None           # scale-in cooldown
    assert p.evaluate(33.0, 0.1, 8) is not None       # cooldown expired
    d = p.evaluate(40.0, 100.0, 8)
    assert d.desired == 16                            # clamped to max_cap


def test_step_scaling_ladder():
    p = StepScalingPolicy(metric="queue", steps_out=[(1, 1), (4, 2), (16, 8)],
                          scale_in_below=0.0, min_cap=1, max_cap=12)
    assert p.evaluate(0.0, 0.0, 4).desired == 3       # scale-in step
    assert p.evaluate(1.0, 5.0, 4).desired == 6       # middle rung
    assert p.evaluate(2.0, 20.0, 4).desired == 12     # top rung, clamped
    assert p.evaluate(3.0, 0.5, 4) is None            # between rungs


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 2, 3, 4, 5, 9)] == [1, 2, 4, 4, 8, 16]


# ------------------------------------------- live resize: token identity --

def test_live_resize_token_identity(params):
    """Acceptance: a slot + page-pool resize mid-run produces token-identical
    fp32 output vs a fixed-capacity run of the same request trace."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7, 11)]
    gens = [6, 8, 5, 7]

    fixed = ContinuousBatchingScheduler(CFG, params, max_slots=2,
                                        page_size=8, max_seq_len=64)
    ref = [fixed.submit(p, g, arrival_step=i)
           for i, (p, g) in enumerate(zip(prompts, gens))]
    fixed.run()

    s = ContinuousBatchingScheduler(CFG, params, max_slots=1, page_size=8,
                                    num_pages=9, max_seq_len=64)
    s.capacity_hint = 20
    reqs = [s.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(zip(prompts, gens))]
    s.step(); s.step()
    s.resize(max_slots=2, num_pages=17)      # grow mid-flight
    for _ in range(6):
        s.step()
    s.resize(max_slots=1, num_pages=9)       # drain-shrink mid-flight
    s.run()
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]
    assert s.alloc.num_allocated == 0 and s.reserved_pages == 0
    s._settle_resize()
    assert s.max_slots == 1 and s.alloc.num_pages == 9
    assert s.stats["resizes"] == 2


def test_live_resize_token_identity_hybrid():
    """Same property through the SSM dense-slot resize path (jamba)."""
    cfg = dataclasses.replace(REDUCED["jamba-v0.1-52b"], dtype="float32")
    p = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 6, 5)]
    gens = [5, 4, 6]
    fixed = ContinuousBatchingScheduler(cfg, p, max_slots=2, page_size=8,
                                        max_seq_len=32)
    ref = [fixed.submit(pr, g) for pr, g in zip(prompts, gens)]
    fixed.run()
    s = ContinuousBatchingScheduler(cfg, p, max_slots=1, page_size=8,
                                    max_seq_len=32)
    reqs = [s.submit(pr, g) for pr, g in zip(prompts, gens)]
    s.step()
    s.resize(max_slots=2)
    s.run()
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]


# ------------------------------------------------------------ controller --

def test_controller_scales_out_and_in(params):
    sched = ContinuousBatchingScheduler(CFG, params, max_slots=1,
                                        page_size=8, max_seq_len=48)
    bands = CapacityBands(min_slots=1, max_slots=8, min_pages=7,
                          max_pages=49)
    ctl = AutoscaleController(sched, bands, eval_interval=4)
    rng = np.random.RandomState(2)
    for _ in range(12):                      # burst at t=0
        sched.submit(rng.randint(0, CFG.vocab_size, size=6), 8,
                     arrival_step=0)
    for i in range(3):                       # trickle after a valley
        sched.submit(rng.randint(0, CFG.vocab_size, size=6), 6,
                     arrival_step=120 + 10 * i)
    done = ctl.run()
    assert len(done) == 15
    slots = [s for _, _, s, _ in ctl.capacity_log]
    assert max(slots) == 8                   # burst drove it to the band max
    assert sched.target_slots <= 2           # valley + tail scaled back in
    assert ctl.summary()["scale_in"] >= 1
    # pages followed slots and every decision landed in the event log
    assert any(p > 7 for _, _, _, p in ctl.capacity_log)
    assert len(ctl.log.actions("autoscale")) >= ctl.summary()["decisions"]


def test_controller_cluster_wiring_and_preemption(params):
    """Node scale-out goes through ClusterLifecycle.extend, drained nodes are
    shrunk away, and a spot preemption is replaced from the warm-spare pool
    without losing the serving run."""
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=1, spot=True)
    ic.lifecycle.provision_spares(ic.cluster, 1)
    monitor = HeartbeatMonitor()
    for node in ic.cluster.directory.slaves():
        monitor.register(node.hostname, now=mgr.cloud.clock)

    sched = ContinuousBatchingScheduler(CFG, params, max_slots=2,
                                        page_size=8, max_seq_len=48)
    bands = CapacityBands(min_slots=2, max_slots=8, min_pages=13,
                          max_pages=49)
    ctl = AutoscaleController(sched, bands, eval_interval=2,
                              slots_per_node=2, lifecycle=ic.lifecycle,
                              cluster=ic.cluster, monitor=monitor)
    rng = np.random.RandomState(3)
    for _ in range(10):
        sched.submit(rng.randint(0, CFG.vocab_size, size=6), 10,
                     arrival_step=0)
    # drive manually so we can preempt mid-run, after scale-out
    preempted = False
    for _ in range(200):
        if not (sched.waiting or sched.num_active):
            break
        ctl.tick()
        sched.step(max_fuse=2)
        if not preempted and len(ic.cluster.slaves) > 1:
            victim = ic.cluster.slaves[-1].instance_id
            mgr.cloud.preempt_spot(victim)
            preempted = True
    ctl.tick()
    assert preempted, "controller never extended the cluster"
    assert not sched.waiting and sched.num_active == 0
    ic.log.assert_order("extend_cluster", "preempt_replaced")
    # the preempted host was replaced, keeping its logical hostname
    hostnames = [n.hostname for n in ic.cluster.directory.slaves()]
    assert len(hostnames) == len(set(hostnames))
    # scale-in after the run released the extra nodes
    assert ctl.nodes_ready <= 2


def test_event_log_roundtrip_with_scale_events(tmp_path, params):
    sched = ContinuousBatchingScheduler(CFG, params, max_slots=1,
                                        page_size=8, max_seq_len=32)
    bands = CapacityBands(min_slots=1, max_slots=4, min_pages=5,
                          max_pages=17)
    ctl = AutoscaleController(sched, bands, eval_interval=2)
    rng = np.random.RandomState(4)
    for _ in range(6):
        sched.submit(rng.randint(0, CFG.vocab_size, size=5), 6,
                     arrival_step=0)
    ctl.run()
    path = tmp_path / "events.jsonl"
    n = ctl.log.write_jsonl(path)
    assert n == len(ctl.log.events) > 0
    replay = EventLog.from_jsonl(path)
    assert [e.to_dict() for e in replay.events] == \
        [e.to_dict() for e in ctl.log.events]
    replay.assert_order("scale_out")


# ------------------------------------------------- blueprint + benchmark --

def test_serving_page_plan_capacity_bands():
    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS
    from repro.core.blueprint import serving_page_plan
    plan = serving_page_plan(ARCHS["qwen3-32b"], SHAPES["decode_32k"],
                             {"model": 8, "data": 4})
    assert plan["min_slots"] >= 1
    assert plan["max_slots"] == plan["max_concurrent_seqs"]
    assert plan["min_pages"] <= plan["max_pages"] == plan["num_pages"]
    bands = CapacityBands.from_plan(plan)
    assert bands.max_slots >= bands.min_slots


def test_fleet_policy_grows_and_drains_on_bursty_trace(params):
    """Acceptance: on the bursty trace the fleet policy grows the fabric
    from 1 to >= 2 replicas and shrinks back by *draining* (not killing)
    busy replicas — no request is lost, no stream is re-prefilled."""
    from repro.serving.router import ServingRouter
    rng = np.random.RandomState(0)
    trace = AB.bursty_trace(rng, CFG.vocab_size, requests=24, horizon=60,
                            n_bursts=1, burst_frac=0.6, p_lo=4, p_hi=10,
                            g_lo=6, g_hi=14)
    router = ServingRouter(CFG, params, replicas=1, max_slots=2,
                           page_size=8, max_seq_len=32)
    ctl = FleetController(router, min_replicas=1, max_replicas=3,
                          eval_interval=2)
    for arrival, prompt, gen in trace:
        router.submit(prompt, gen, arrival_step=arrival)
    for i in range(3):                      # quiet tail: trickle arrivals so
        router.submit(rng.randint(0, CFG.vocab_size, size=6), 6,  # scale-in
                      arrival_step=120 + 30 * i)    # cooldowns can elapse
    done = ctl.run()
    # no request lost, every token budget honoured
    assert len(done) == len(trace) + 3
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)
    s = ctl.summary()
    assert s["peak_replicas"] >= 2, s           # burst grew the fleet
    assert s["scale_in"] >= 1 and s["final_replicas"] == 1, s
    assert s["reroutes"] == 0                   # drained, never killed
    # the scale-in path is drain-then-remove, in that order
    ctl.log.assert_order("scale_out", "add_replica", "scale_in",
                         "drain_replica", "remove_replica")
    # at least one drain hit a replica that still had streams in flight
    drains = [e for e in ctl.log.events if e.action == "drain_replica"]
    assert any(e.detail["outstanding"] > 0 for e in drains), drains


def test_fleet_cluster_wiring_node_per_replica_and_preemption(params):
    """Fleet scale-out acquires a node per replica via ClusterLifecycle;
    a spot preemption fails the replica, re-routes its streams onto
    survivors (token budgets intact), and replaces the node from the
    warm-spare pool under its stable hostname."""
    from repro.serving.router import ServingRouter
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=1, spot=True)
    ic.lifecycle.provision_spares(ic.cluster, 1)
    monitor = HeartbeatMonitor()
    for node in ic.cluster.directory.slaves():
        monitor.register(node.hostname, now=mgr.cloud.clock)

    router = ServingRouter(CFG, params, replicas=1, max_slots=2,
                           page_size=8, max_seq_len=48,
                           placement=["slave-0"])
    ctl = FleetController(router, min_replicas=1, max_replicas=3,
                          eval_interval=2, lifecycle=ic.lifecycle,
                          cluster=ic.cluster, monitor=monitor)
    rng = np.random.RandomState(3)
    reqs = [router.submit(rng.randint(0, CFG.vocab_size, size=6), 10,
                          arrival_step=0) for _ in range(10)]
    preempted = False
    for _ in range(300):
        if not router.num_unfinished:
            break
        ctl.tick()
        router.step(max_fuse=2)
        if not preempted and len(ic.cluster.slaves) > 1:
            new_host = ic.cluster.directory.slaves()[-1].hostname
            busy = any(r.hostname == new_host and r.num_unfinished > 0
                       for r in router.replicas.values())
            if busy:
                mgr.cloud.preempt_spot(ic.cluster.slaves[-1].instance_id)
                preempted = True
    ctl.tick()
    assert preempted, "fleet controller never extended the cluster"
    assert not router.num_unfinished
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    assert router.stats["reroutes"] >= 1        # preemption re-routed work
    ic.log.assert_order("extend_cluster", "replica_failed",
                        "preempt_replaced")
    # the replacement kept the logical hostname unique in the directory
    hostnames = [n.hostname for n in ic.cluster.directory.slaves()]
    assert len(hostnames) == len(set(hostnames))


def test_autoscale_bench_cost_criterion(params):
    """Acceptance: on the bursty trace, autoscaling is >= 1.3x cheaper in
    instance-seconds than static peak provisioning at equal-or-better p99
    latency. Deterministic: everything runs on the simulated tick clock."""
    rng = np.random.RandomState(0)
    trace = AB.bursty_trace(rng, CFG.vocab_size, requests=32, horizon=160,
                            n_bursts=2, burst_frac=0.5, p_lo=4, p_hi=12,
                            g_lo=4, g_hi=12)
    out = AB.compare(CFG, params, trace, page_size=8, max_seq=32,
                     slots_per_node=2, boot_ticks=0, eval_interval=1)
    assert out["cost_ratio"] >= 1.3, out
    assert out["p99_ratio"] <= 1.0, out
    assert out["autoscale"]["peak_slots"] <= out["peak_slots"]
