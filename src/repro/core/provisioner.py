"""Cluster provisioning — the paper's Fig. 1 sequence, step for step.

Slave boot:  create temp user (password = AWS Access Key ID) -> install agent.
Master boot: query EC2 for slaves -> assign hostnames + hosts file ->
generate cluster key-pair -> distribute key-pair + hosts over the temp user
-> delete temp user everywhere -> tag instances -> (optional) deactivate the
AWS key -> install + start the Ambari-analogue server.

Every step lands in the EventLog; tests assert the exact Fig. 1 order and
the security invariants (temp user gone once keys are in place, key-pair
regenerated on every full restart).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Dict, List, Optional

from repro.core.discovery import NodeDirectory
from repro.core.events import EventLog
from repro.core.simcloud import LATENCY, Instance, InstanceState, SimCloud

IMAGE_ID = "ami-instacluster-tpu-001"   # the paper ships an AMI; ours is sim


@dataclasses.dataclass
class SecurityState:
    temp_user_active: Dict[str, bool]
    cluster_keypair: Optional[str]
    keypair_generation: int = 0


@dataclasses.dataclass
class Cluster:
    region: str
    master: Instance
    slaves: List[Instance]
    directory: NodeDirectory
    security: SecurityState
    access_key_id: str
    secret_key: str
    log: EventLog
    spot: bool = False

    @property
    def instance_ids(self) -> List[str]:
        return [self.master.instance_id] + [s.instance_id for s in self.slaves]

    def spec(self) -> Dict[str, Any]:
        """Reproducibility export (paper §4: share type+count+config)."""
        return {
            "image_id": IMAGE_ID,
            "region": self.region,
            "instance_type": self.slaves[0].instance_type if self.slaves
            else self.master.instance_type,
            "n_slaves": len(self.slaves),
            "spot": self.spot,
            "chips_per_host": self.slaves[0].chips if self.slaves else 0,
        }


class ClusterProvisioner:
    _kp_counter = itertools.count(1)

    def __init__(self, cloud: SimCloud, *, region: str, access_key_id: str,
                 secret_key: str, deactivate_key_after_discovery: bool = False):
        self.cloud = cloud
        self.region = region
        self.access_key_id = access_key_id
        self.secret_key = secret_key
        self.deactivate = deactivate_key_after_discovery

    # ------------------------------------------------------------ helpers --
    def _gen_keypair(self, sec: SecurityState) -> None:
        sec.keypair_generation += 1
        seed = f"{self.region}:{sec.keypair_generation}:{next(self._kp_counter)}"
        sec.cluster_keypair = hashlib.sha256(seed.encode()).hexdigest()[:32]

    def _boot_slaves(self, n: int, instance_type: str, spot: bool,
                     log: EventLog) -> List[Instance]:
        slaves = self.cloud.run_instances(
            count=n, instance_type=instance_type, region=self.region,
            image_id=IMAGE_ID, access_key_id=self.access_key_id,
            user_data={"role": "slave", "access_key_id": self.access_key_id},
            spot=spot)
        for i, inst in enumerate(slaves):
            log.emit(self.cloud.clock, f"slave-boot-{i}", "spawn_slave",
                     instance_id=inst.instance_id)
            log.emit(self.cloud.clock, f"slave-boot-{i}", "create_temp_user",
                     password="<AWS_ACCESS_KEY_ID>")
        self.cloud._advance(LATENCY["pkg_install_agent"])
        for i, inst in enumerate(slaves):
            log.emit(self.cloud.clock, f"slave-boot-{i}", "install_agent",
                     instance_id=inst.instance_id)
        return slaves

    # ---------------------------------------------------------- provision --
    def provision(self, *, n_slaves: int, instance_type: str = "tpu-host-v5e-8",
                  spot: bool = False, log: Optional[EventLog] = None) -> Cluster:
        log = log or EventLog()
        c = self.cloud

        slaves = self._boot_slaves(n_slaves, instance_type, spot, log)

        master = c.run_instances(
            count=1, instance_type=instance_type, region=self.region,
            image_id=IMAGE_ID, access_key_id=self.access_key_id,
            user_data={"role": "master", "access_key_id": self.access_key_id,
                       "secret_key": self.secret_key, "region": self.region,
                       "deactivate_key": self.deactivate})[0]
        log.emit(c.clock, "master", "spawn_master",
                 instance_id=master.instance_id)

        # 1. master queries EC2 for slaves in the region
        found = [i for i in c.describe_instances(region=self.region,
                                                 access_key_id=self.access_key_id)
                 if i.user_data.get("role") == "slave"
                 and i.state == InstanceState.RUNNING]
        log.emit(c.clock, "master", "query_ec2_slaves", found=len(found))

        # 2. hostname assignment + hosts file
        directory = NodeDirectory()
        directory.enumerate(master, found)
        log.emit(c.clock, "master", "assign_hostnames",
                 hostnames=[n.hostname for n in directory.slaves()])
        log.emit(c.clock, "master", "update_hosts_file",
                 sha=hashlib.sha256(directory.hosts_file().encode())
                 .hexdigest()[:8])

        # 3. cluster key-pair generation + distribution over temp user
        sec = SecurityState(temp_user_active={s.instance_id: True
                                              for s in found},
                            cluster_keypair=None)
        self._gen_keypair(sec)
        log.emit(c.clock, "master", "generate_keypair",
                 generation=sec.keypair_generation)
        c._advance(LATENCY["ssh_roundtrip"])  # parallel fan-out
        for n in directory.slaves():
            log.emit(c.clock, "master", "distribute_keypair_hosts",
                     to=n.hostname)

        # 4. temp user deletion (password auth window closes)
        for s in found:
            sec.temp_user_active[s.instance_id] = False
        log.emit(c.clock, "master", "delete_temp_user", count=len(found))

        # 5. tag instances with their roles
        c.create_tags([master.instance_id], {"instacluster:role": "master"},
                      self.access_key_id)
        for n in directory.slaves():
            c.create_tags([n.instance_id],
                          {"instacluster:role": n.hostname},
                          self.access_key_id)
        log.emit(c.clock, "master", "tag_instances",
                 count=1 + len(found))

        # 6. optional AWS key deactivation (paper: advisable unless spot)
        if self.deactivate:
            if spot:
                log.emit(c.clock, "master", "skip_key_deactivation",
                         reason="spot instances need live keys for restarts")
            else:
                c.deactivate_key(self.access_key_id)
                log.emit(c.clock, "master", "deactivate_aws_key")

        # 7. service-provisioning server (Ambari analogue)
        c._advance(LATENCY["pkg_install_server"])
        log.emit(c.clock, "master", "install_ambari_server", port=8080)
        log.emit(c.clock, "master", "start_ambari_server")

        return Cluster(region=self.region, master=master, slaves=found,
                       directory=directory, security=sec,
                       access_key_id=self.access_key_id,
                       secret_key=self.secret_key, log=log, spot=spot)

    # --------------------------------------------------------- rediscovery --
    def rediscover(self, cluster: Cluster) -> List[str]:
        """After restart: re-query EC2, remap hostname->IP, redistribute the
        hosts file, regenerate + redistribute the cluster key-pair (paper:
        key-pair is revoked and regenerated after each full restart)."""
        c = self.cloud
        log = cluster.log
        insts = c.describe_instances(region=self.region,
                                     access_key_id=self.access_key_id)
        log.emit(c.clock, "master", "requery_ec2", found=len(insts))
        changed = cluster.directory.remap_ips(insts)
        log.emit(c.clock, "master", "remap_private_ips", changed=changed)
        self._gen_keypair(cluster.security)
        log.emit(c.clock, "master", "regenerate_keypair",
                 generation=cluster.security.keypair_generation)
        c._advance(LATENCY["ssh_roundtrip"])
        log.emit(c.clock, "master", "redistribute_hosts_file",
                 to=[n.hostname for n in cluster.directory.slaves()])
        return changed
