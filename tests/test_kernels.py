"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def rand(shape, dtype, i):
    x = jax.random.normal(jax.random.fold_in(KEY, i), shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- flash attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KVH,d", [
    (1, 128, 1, 1, 32),
    (2, 256, 4, 2, 64),
    (1, 384, 8, 8, 64),      # MHA, non-multiple of 256
    (2, 512, 8, 2, 128),     # GQA 4:1, MXU-width head
    (1, 250, 4, 1, 64),      # ragged seq (padding path)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, H, KVH, d, dtype, causal):
    q = rand((B, S, H, d), dtype, 1)
    k = rand((B, S, KVH, d), dtype, 2)
    v = rand((B, S, KVH, d), dtype, 3)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 50.0),
                                            (128, 30.0)])
def test_flash_attention_window_softcap(window, softcap):
    B, S, H, KVH, d = 2, 320, 4, 2, 64
    q, k, v = (rand((B, S, H, d), jnp.float32, 1),
               rand((B, S, KVH, d), jnp.float32, 2),
               rand((B, S, KVH, d), jnp.float32, 3))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128, 192, 320]),
       st.sampled_from([(1, 1), (2, 2), (4, 2), (4, 1)]),
       st.sampled_from([32, 64]), st.booleans())
def test_flash_attention_property(B, S, heads, d, causal):
    H, KVH = heads
    q, k, v = (rand((B, S, H, d), jnp.float32, 11),
               rand((B, S, KVH, d), jnp.float32, 12),
               rand((B, S, KVH, d), jnp.float32, 13))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    assert np.isfinite(np.asarray(out)).all()


def test_flash_attention_block_size_invariance():
    """Property: output independent of BlockSpec tiling."""
    B, S, H, KVH, d = 1, 384, 4, 2, 64
    q, k, v = (rand((B, S, H, d), jnp.float32, 21),
               rand((B, S, KVH, d), jnp.float32, 22),
               rand((B, S, KVH, d), jnp.float32, 23))
    outs = [np.asarray(ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                           block_k=bk, interpret=True))
            for bq, bk in [(64, 64), (128, 128), (128, 64), (64, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


# -------------------------------------------------------- decode attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KVH,d,bk", [
    (1, 512, 4, 2, 64, 128),
    (2, 1024, 8, 1, 64, 512),
    (2, 700, 4, 4, 128, 256),    # ragged
])
def test_decode_attention_matches_ref(B, S, H, KVH, d, bk, dtype):
    q = rand((B, H, d), dtype, 31)
    k = rand((B, S, KVH, d), dtype, 32)
    v = rand((B, S, KVH, d), dtype, 33)
    out = ops.decode_attention(q, k, v, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_decode_attention_split_invariance():
    B, S, H, KVH, d = 2, 1024, 4, 2, 64
    q, k, v = (rand((B, H, d), jnp.float32, 41),
               rand((B, S, KVH, d), jnp.float32, 42),
               rand((B, S, KVH, d), jnp.float32, 43))
    outs = [np.asarray(ops.decode_attention(q, k, v, block_k=bk,
                                            interpret=True))
            for bk in (128, 256, 1024)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


# ----------------------------------------------------- paged decode (COW) --

def test_paged_decode_aliased_block_tables_matches_ref():
    """Shared-prefix serving: two sequences whose block tables alias the
    same physical pages (a shared prefix chain) plus private tails. The
    kernel must gather the aliased pages independently per sequence —
    against the oracle, and against the same K/V laid out contiguously."""
    H, KVH, d, ps = 4, 2, 32, 8
    n_shared, n_pg = 2, 4                    # 2 aliased pages + 2 private
    P = 1 + n_shared + 2 * (n_pg - n_shared)  # sink + shared + both tails
    q = rand((2, H, d), jnp.float32, 91)
    kp = rand((P, ps, KVH, d), jnp.float32, 92)
    vp = rand((P, ps, KVH, d), jnp.float32, 93)
    shared = [1, 2]
    tail_a, tail_b = [3, 4], [5, 6]
    bt = jnp.asarray([shared + tail_a, shared + tail_b], jnp.int32)
    lens = jnp.asarray([ps * n_pg, ps * n_pg - 5], jnp.int32)  # ragged b

    out = ops.paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # cross-check vs a dense layout: each sequence's pages gathered into a
    # contiguous cache must attend identically — aliasing is invisible
    k_dense = jnp.stack([kp[np.asarray(bt[i])].reshape(-1, KVH, d)
                         for i in range(2)])
    v_dense = jnp.stack([vp[np.asarray(bt[i])].reshape(-1, KVH, d)
                         for i in range(2)])
    want_dense = ref.decode_attention_ref(q, k_dense, v_dense, valid_len=lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_dense),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_aliased_pages_quantised():
    """Aliased block tables through the int8 + fp32-scale pool path."""
    from repro.models.attention import quantize_kv
    H, KVH, d, ps = 4, 2, 32, 8
    P = 6
    kp = rand((P, ps, KVH, d), jnp.float32, 94)
    vp = rand((P, ps, KVH, d), jnp.float32, 95)
    k8, ks = quantize_kv(kp)
    v8, vs = quantize_kv(vp)
    q = rand((2, H, d), jnp.float32, 96)
    bt = jnp.asarray([[1, 2, 3], [1, 2, 4]], jnp.int32)   # pages 1-2 shared
    lens = jnp.asarray([22, 19], jnp.int32)
    out = ops.paged_decode_attention(q, k8, v8, bt, lens, k_scale_pages=ks,
                                     v_scale_pages=vs, interpret=True)
    want = ref.paged_decode_attention_ref(
        q, k8.astype(jnp.float32) * ks[..., None],
        v8.astype(jnp.float32) * vs[..., None], bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ SSD --

@pytest.mark.parametrize("B,S,H,G,N,P,chunk", [
    (1, 128, 2, 1, 16, 32, 32),
    (2, 256, 4, 1, 16, 64, 64),
    (1, 256, 4, 2, 32, 32, 128),     # multi-group
])
def test_ssd_matches_sequential_ref(B, S, H, G, N, P, chunk):
    x = rand((B, S, H, P), jnp.float32, 51) * 0.5
    dt = jax.nn.softplus(rand((B, S, H), jnp.float32, 52))
    A = -jnp.exp(rand((H,), jnp.float32, 53) * 0.3)
    Bm = rand((B, S, G, N), jnp.float32, 54) * 0.5
    Cm = rand((B, S, G, N), jnp.float32, 55) * 0.5
    y, h = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    B, S, H, G, N, P = 1, 256, 2, 1, 16, 32
    x = rand((B, S, H, P), jnp.float32, 61) * 0.5
    dt = jax.nn.softplus(rand((B, S, H), jnp.float32, 62))
    A = -jnp.exp(rand((H,), jnp.float32, 63) * 0.3)
    Bm = rand((B, S, G, N), jnp.float32, 64) * 0.5
    Cm = rand((B, S, G, N), jnp.float32, 65) * 0.5
    outs = [np.asarray(ops.ssd(x, dt, A, Bm, Cm, chunk=c, interpret=True)[0])
            for c in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_model_path():
    """Kernel wrapper == the model's pure-jnp chunked path (dry-run path)."""
    from repro.models.ssm import ssd_chunked
    B, S, H, G, N, P = 2, 256, 4, 1, 16, 32
    x = rand((B, S, H, P), jnp.float32, 71) * 0.5
    dt = jax.nn.softplus(rand((B, S, H), jnp.float32, 72))
    A = -jnp.exp(rand((H,), jnp.float32, 73) * 0.3)
    Bm = rand((B, S, G, N), jnp.float32, 74) * 0.5
    Cm = rand((B, S, G, N), jnp.float32, 75) * 0.5
    y_k, h_k = ops.ssd(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    y_m, h_m = ssd_chunked(x, dt, A, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=1e-4, atol=1e-4)


def test_model_attention_matches_kernel():
    """The model's chunked-XLA attention == the Pallas kernel == the oracle."""
    from repro.models.attention import attend
    B, S, H, KVH, d = 1, 8192, 4, 2, 64   # force the chunked path
    q, k, v = (rand((B, S, H, d), jnp.bfloat16, 81),
               rand((B, S, KVH, d), jnp.bfloat16, 82),
               rand((B, S, KVH, d), jnp.bfloat16, 83))
    o_model = attend(q, k, v, causal=True)
    o_kernel = ops.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(o_model, np.float32),
                               np.asarray(o_kernel, np.float32),
                               rtol=3e-2, atol=3e-2)
