"""The paper's eight demonstration use cases (Appendix A), end to end.

Run:  PYTHONPATH=src python examples/use_cases.py
"""
from repro.core.cluster import ClusterManager

TEXT = b"""instacluster builds a big data cluster in minutes
the cluster runs spark and hdfs and hue
the cluster is reproducible
"""


def main() -> None:
    mgr = ClusterManager()

    print("== use case 1: provision 6-node cluster + install services ==")
    ic = mgr.build_cluster(n_slaves=6)
    print(f"  up in {ic.bringup_seconds/60:.1f} simulated minutes; "
          f"services: {ic.ambari.status()}")

    print("== use case 2: stop the cluster (billing halt) ==")
    ic.lifecycle.stop(ic.cluster)
    print(f"  hourly cost now ${mgr.cloud.hourly_cost(ic.cluster.instance_ids):.2f}")

    print("== use case 3: start the cluster (slaves first) ==")
    changed = ic.lifecycle.start(ic.cluster)
    print(f"  private IPs remapped for: {changed}")

    print("== use case 4: extend by three machines ==")
    nodes = ic.lifecycle.extend(ic.cluster, 3)
    print(f"  new hosts: {[n.hostname for n in nodes]}")

    print("== use case 7: upload a file to storage ==")
    info = ic.hue.upload_file("/data/corpus.txt", TEXT)
    print(f"  {info}")

    print("== use case 5: browse storage ==")
    print(f"  {ic.hue.browse_storage('/data')}")

    print("== use case 6: submit a compute job ==")
    job = ic.hue.submit_job("spark", lambda: sum(range(1000)))
    print(f"  job {job.job_id}: {job.status} result={job.result}")

    print("== use case 8: MapReduce WordCount over the uploaded file ==")
    counts = ic.hue.run_wordcount("/data/corpus.txt")
    top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
    print(f"  top words: {top}")

    print("== event log (Fig. 1 + lifecycle) ==")
    for e in ic.log.events[:14]:
        print(f"  t={e.t:7.1f}s {e.actor:14s} {e.action}")


if __name__ == "__main__":
    main()
