"""jit'd wrappers over the Pallas kernels (+ pure-jnp combines).

``interpret=True`` runs kernel bodies on CPU (how this container validates
them); on real TPU deployments pass ``interpret=False``.

**Profiling hook** (``set_profile_hook``): an opt-in callback wrapped
around every public entry point, fed the kind, the post-
``block_until_ready`` wall seconds, and the call's array arguments (for
byte accounting) — ``repro.obs.profile.KernelProfiler.hook()`` is the
intended consumer. The hook only fires for calls with concrete operands:
a call made *inside* an outer jit trace sees abstract tracers, where wall
time is meaningless (and a host callback would break tracing), so those
pass straight through. Hooked or not, results are identical — timing
reads the clock around the call and touches nothing else.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _fd
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_decode as _pd
from repro.kernels import paged_prefill as _pp
from repro.kernels import ssd as _ssd

_PROFILE_HOOK = None


def set_profile_hook(hook) -> Optional[object]:
    """Install ``hook(kind, wall_seconds, args)`` around every public op
    (None uninstalls); returns the previous hook so callers can restore
    it (``prev = set_profile_hook(p.hook()) ... set_profile_hook(prev)``)."""
    global _PROFILE_HOOK
    prev = _PROFILE_HOOK
    _PROFILE_HOOK = hook
    return prev


def _traced(tree) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(tree))


def _profiled(kind, fn, *args, **kw):
    hook = _PROFILE_HOOK
    if hook is None or _traced((args, kw)):
        return fn(*args, **kw)
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kw))
    hook(kind, time.perf_counter() - t0, args)
    return out


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "block_q", "block_k",
                                             "interpret"))
def _flash_attention_jit(q, k, v, *, causal=True, window=None, softcap=None,
                         scale=None, block_q=128, block_k=128,
                         interpret=False):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=False):
    return _profiled("flash_attention", _flash_attention_jit, q, k, v,
                     causal=causal, window=window, softcap=softcap,
                     scale=scale, block_q=block_q, block_k=block_k,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "block_k",
                                             "interpret"))
def _decode_attention_jit(q, k_cache, v_cache, *, softcap=None, scale=None,
                          block_k=512, interpret=False):
    """Flash-decode: partials from the kernel, LSE combine in jnp.

    q: (B,H,d); caches (B,S,KVH,d) -> (B,H,d).
    """
    B, H, d = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    m, lse, o = _fd.decode_attention_partials(
        q, k_cache, v_cache, softcap=softcap, scale=scale, block_k=block_k,
        interpret=interpret)
    m_glob = m.max(axis=1, keepdims=True)                   # (BK,1,G)
    w = jnp.exp(m - m_glob)
    l_glob = (lse * w).sum(axis=1)                          # (BK,G)
    o_glob = (o * w[..., None]).sum(axis=1)                 # (BK,G,d)
    out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
    return out.reshape(B, KVH, G, d).reshape(B, H, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, softcap=None, scale=None,
                     block_k=512, interpret=False):
    """Flash-decode: partials from the kernel, LSE combine in jnp.

    q: (B,H,d); caches (B,S,KVH,d) -> (B,H,d).
    """
    return _profiled("decode_attention", _decode_attention_jit, q, k_cache,
                     v_cache, softcap=softcap, scale=scale, block_k=block_k,
                     interpret=interpret)


def _paged_decode_one(q, k_pages, v_pages, block_table, seq_lens, *,
                      k_scale_pages, v_scale_pages, softcap, window, scale,
                      interpret):
    """One pool's paged flash-decode: kernel partials + jnp LSE combine."""
    B, H, d = q.shape
    m, lse, o = _pd.paged_decode_partials(
        q, k_pages, v_pages, block_table, seq_lens,
        k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
        softcap=softcap, window=window, scale=scale, interpret=interpret)
    m_glob = m.max(axis=2, keepdims=True)                   # (B,KVH,1,G)
    w = jnp.exp(m - m_glob)
    l_glob = (lse * w).sum(axis=2)                          # (B,KVH,G)
    o_glob = (o * w[..., None]).sum(axis=2)                 # (B,KVH,G,d)
    out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
    return out.reshape(B, H, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "scale",
                                             "interpret"))
def _paged_decode_attention_jit(q, k_pages, v_pages, block_table, seq_lens, *,
                                k_scale_pages=None, v_scale_pages=None,
                                softcap=None, window=None, scale=None,
                                interpret=False):
    return _paged_decode_one(q, k_pages, v_pages, block_table, seq_lens,
                             k_scale_pages=k_scale_pages,
                             v_scale_pages=v_scale_pages, softcap=softcap,
                             window=window, scale=scale, interpret=interpret)


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           k_scale_pages=None, v_scale_pages=None,
                           softcap=None, window=None, scale=None,
                           interpret=False):
    """Paged flash-decode: per-page partials from the kernel, LSE combine
    in jnp (same structure as ``decode_attention``).

    q: (B,H,d); pools (P,ps,KVH,d); block_table (B,n_pg); seq_lens (B,)
    -> (B,H,d). See ``repro.kernels.paged_decode`` for the page gather.
    """
    return _profiled("paged_decode_attention", _paged_decode_attention_jit,
                     q, k_pages, v_pages, block_table, seq_lens,
                     k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
                     softcap=softcap, window=window, scale=scale,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("softcap", "window", "scale",
                                             "interpret"))
def _paged_decode_attention_sharded_jit(q, k_pages, v_pages, block_table,
                                        seq_lens, *, k_scale_pages=None,
                                        v_scale_pages=None, softcap=None,
                                        window=None, scale=None,
                                        interpret=False):
    """Shard-group paged flash-decode: pools carry a leading shard axis
    (tp, P, ps, KVH/tp, d) and the kernel is invoked once per shard on
    that shard's query-head slice of ``q`` (B, H, d); the head-axis concat
    of the per-shard results is the group's all_gather. The block table and
    sequence lengths are the shared control plane — identical operands on
    every shard.
    """
    tp = k_pages.shape[0]
    B, H, d = q.shape
    Hs = H // tp
    outs = []
    for s in range(tp):
        outs.append(_paged_decode_one(
            q[:, s * Hs:(s + 1) * Hs], k_pages[s], v_pages[s], block_table,
            seq_lens,
            k_scale_pages=None if k_scale_pages is None else k_scale_pages[s],
            v_scale_pages=None if v_scale_pages is None else v_scale_pages[s],
            softcap=softcap, window=window, scale=scale, interpret=interpret))
    return jnp.concatenate(outs, axis=1)


def paged_decode_attention_sharded(q, k_pages, v_pages, block_table,
                                   seq_lens, *, k_scale_pages=None,
                                   v_scale_pages=None, softcap=None,
                                   window=None, scale=None,
                                   interpret=False):
    """Shard-group paged flash-decode (see ``_paged_decode_attention_sharded_jit``
    for the shard/head-slice structure)."""
    return _profiled("paged_decode_attention_sharded",
                     _paged_decode_attention_sharded_jit,
                     q, k_pages, v_pages, block_table, seq_lens,
                     k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages,
                     softcap=softcap, window=window, scale=scale,
                     interpret=interpret)


# --- paged prefill (chunked flash-prefill with direct-to-page KV writes) ---

# Autotuned block sizes, keyed by shape signature (see prefill_tuning_key).
# benchmarks/prefill_autotune.py sweeps candidates and writes the cache
# JSON; it is consumed here either via register_prefill_tuning() or lazily
# from $REPRO_PREFILL_TUNE / ./BENCH_prefill_tune.json on first lookup.
_PREFILL_TUNE: Dict[str, Dict] = {}
_PREFILL_TUNE_LOADED = False
_PREFILL_TUNE_DEFAULT_PATH = "BENCH_prefill_tune.json"


def prefill_tuning_key(H: int, d: int, KVH: int, chunk: int,
                       page_size: int) -> str:
    return f"h{H}xd{d}xkv{KVH}|chunk{chunk}|ps{page_size}"


def register_prefill_tuning(table: Dict[str, Dict]) -> Dict[str, Dict]:
    """Install autotuned prefill configs ({key: {"block_q": int, ...}});
    returns the previous table. Entries merge over defaults — an unknown
    key falls back to block_q=min(chunk, 128)."""
    global _PREFILL_TUNE, _PREFILL_TUNE_LOADED
    prev = _PREFILL_TUNE
    _PREFILL_TUNE = dict(table)
    _PREFILL_TUNE_LOADED = True
    return prev


def _prefill_tuned_block_q(H, d, KVH, chunk, page_size) -> int:
    global _PREFILL_TUNE_LOADED
    if not _PREFILL_TUNE_LOADED:
        _PREFILL_TUNE_LOADED = True
        path = os.environ.get("REPRO_PREFILL_TUNE", _PREFILL_TUNE_DEFAULT_PATH)
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    _PREFILL_TUNE.update(json.load(f).get("entries", {}))
            except (OSError, ValueError):
                pass
    entry = _PREFILL_TUNE.get(prefill_tuning_key(H, d, KVH, chunk, page_size))
    if entry and "block_q" in entry:
        # clamp to the chunk width: speculative verify reuses this path at
        # chunk = spec_k + 1 (a handful of rows), and a stale or hand-edited
        # tune entry must never produce a query tile wider than the array
        return min(int(entry["block_q"]), chunk)
    return min(chunk, 128)


def _paged_prefill_one(q, k_new, v_new, pool, block_table, start, chunk_lens,
                       *, quant, softcap, window, scale, block_q, interpret):
    """One pool's fused chunk prefill: write kernel then attend kernel.

    The write must land first — the attend kernel streams the chunk's own
    K/V back out of the pages (which is also what gives quantised pools
    the same quantise->dequantise roundtrip as the XLA scatter+gather
    path)."""
    new_pool = _pp.paged_prefill_write(
        k_new, v_new, pool["k_pages"], pool["v_pages"], block_table, start,
        chunk_lens, k_scale_pages=pool.get("k_scale_pages"),
        v_scale_pages=pool.get("v_scale_pages"), quant=quant,
        interpret=interpret)
    o = _pp.paged_prefill_attend(
        q, new_pool["k_pages"], new_pool["v_pages"], block_table, start,
        chunk_lens, k_scale_pages=new_pool.get("k_scale_pages"),
        v_scale_pages=new_pool.get("v_scale_pages"), softcap=softcap,
        window=window, scale=scale, block_q=block_q, interpret=interpret)
    return o, new_pool


@functools.partial(jax.jit, static_argnames=("quant", "softcap", "window",
                                             "scale", "block_q", "interpret"))
def _paged_prefill_jit(q, k_new, v_new, pool, block_table, start, chunk_lens,
                       *, quant=None, softcap=None, window=None, scale=None,
                       block_q=None, interpret=False):
    return _paged_prefill_one(q, k_new, v_new, pool, block_table, start,
                              chunk_lens, quant=quant, softcap=softcap,
                              window=window, scale=scale, block_q=block_q,
                              interpret=interpret)


def paged_prefill(q, k_new, v_new, pool, block_table, start, chunk_lens, *,
                  quant=None, softcap=None, window=None, scale=None,
                  block_q=None, interpret=False):
    """Fused chunked prefill: scatter the chunk's K/V directly into the
    pool pages (no contiguous intermediate, no post-hoc ``write_prefill``
    copy), then flash-attend prefix+chunk from the pages.

    q: (B,S,H,d); k_new/v_new: (B,S,KVH,d); ``pool`` dict holds one
    layer's pages (k_pages/v_pages (P,ps,KVH,d) + scale planes when
    ``quant``); start/chunk_lens: (B,) int32. Returns (o (B,S,H,d),
    new_pool). ``block_q`` defaults to the autotuned value for the shape
    (benchmarks/prefill_autotune.py).
    """
    if block_q is None:
        B, S, H, d = q.shape
        block_q = _prefill_tuned_block_q(H, d, k_new.shape[2], S,
                                         pool["k_pages"].shape[1])
    return _profiled("paged_prefill", _paged_prefill_jit, q, k_new, v_new,
                     pool, block_table, start, chunk_lens, quant=quant,
                     softcap=softcap, window=window, scale=scale,
                     block_q=block_q, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("quant", "softcap", "window",
                                             "scale", "block_q", "interpret"))
def _paged_prefill_sharded_jit(q, k_new, v_new, pool, block_table, start,
                               chunk_lens, *, quant=None, softcap=None,
                               window=None, scale=None, block_q=None,
                               interpret=False):
    """Shard-group fused prefill: pool leaves carry a leading shard axis
    (tp, P, ps, KVH/tp, d); shard ``s`` runs the write+attend pair on its
    query/kv head slices and the head-axis concat is the group's
    all_gather (same structure as ``_paged_decode_attention_sharded_jit``)."""
    tp = pool["k_pages"].shape[0]
    B, S, H, d = q.shape
    KVH = k_new.shape[2]
    Hs, KVHs = H // tp, KVH // tp
    outs, pools = [], []
    for s in range(tp):
        o_s, pool_s = _paged_prefill_one(
            q[:, :, s * Hs:(s + 1) * Hs],
            k_new[:, :, s * KVHs:(s + 1) * KVHs],
            v_new[:, :, s * KVHs:(s + 1) * KVHs],
            {k: v[s] for k, v in pool.items()}, block_table, start,
            chunk_lens, quant=quant, softcap=softcap, window=window,
            scale=scale, block_q=block_q, interpret=interpret)
        outs.append(o_s)
        pools.append(pool_s)
    new_pool = {k: jnp.stack([pools[s][k] for s in range(tp)])
                for k in pool}
    return jnp.concatenate(outs, axis=2), new_pool


def paged_prefill_sharded(q, k_new, v_new, pool, block_table, start,
                          chunk_lens, *, quant=None, softcap=None,
                          window=None, scale=None, block_q=None,
                          interpret=False):
    """Shard-group fused chunked prefill (see ``_paged_prefill_sharded_jit``
    for the shard/head-slice structure)."""
    if block_q is None:
        B, S, H, d = q.shape
        block_q = _prefill_tuned_block_q(H, d, k_new.shape[2], S,
                                         pool["k_pages"].shape[2])
    return _profiled("paged_prefill_sharded", _paged_prefill_sharded_jit,
                     q, k_new, v_new, pool, block_table, start, chunk_lens,
                     quant=quant, softcap=softcap, window=window, scale=scale,
                     block_q=block_q, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, dt, A, Bm, Cm, *, chunk=128, h0=None, interpret=False):
    """Full SSD forward via the intra-chunk kernel + jnp inter-chunk scan.

    Same contract as ``repro.models.ssm.ssd_chunked``:
    x: (B,S,H,P), dt: (B,S,H) fp32, A: (H,), Bm/Cm: (B,S,G,N).
    Returns (y, h_final).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    bc = jnp.repeat(Bm.reshape(B, nc, chunk, G, N), rep, axis=3)
    cc = jnp.repeat(Cm.reshape(B, nc, chunk, G, N), rep, axis=3)
    a = dtc * A.astype(jnp.float32)
    cum = jnp.cumsum(a, axis=2)                             # (B,nc,Q,H)
    total = cum[:, :, -1]                                   # (B,nc,H)

    y_diag, states = _ssd.ssd_intra_chunk(xc, bc, cc, cum, dtc,
                                          interpret=interpret)

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def body(h_prev, xs):
        s_c, tot_c = xs
        return h_prev * jnp.exp(tot_c)[..., None, None] + s_c, h_prev

    h_final, h_prevs = jax.lax.scan(
        body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,nc,H,N,P)

    y_off = jnp.einsum("bcihn,bchnp->bcihp",
                       cc.astype(jnp.float32) * jnp.exp(cum)[..., None],
                       h_prevs, preferred_element_type=jnp.float32)
    y = (y_diag.astype(jnp.float32) + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), h_final


def ssd(x, dt, A, Bm, Cm, *, chunk=128, h0=None, interpret=False):
    """Full SSD forward via the intra-chunk kernel + jnp inter-chunk scan.

    Same contract as ``repro.models.ssm.ssd_chunked``:
    x: (B,S,H,P), dt: (B,S,H) fp32, A: (H,), Bm/Cm: (B,S,G,N).
    Returns (y, h_final).
    """
    return _profiled("ssd", _ssd_jit, x, dt, A, Bm, Cm, chunk=chunk, h0=h0,
                     interpret=interpret)
