"""SLO burn-rate monitors: objective validation, the multi-window
fire/clear hysteresis, the (bad, total) source adapters over the metrics
registry, the TelemetryBus.rate() startup guard (S3), and the autoscale
controller merging ``slo_*`` signals into its telemetry bus."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.autoscale import AutoscaleController, CapacityBands
from repro.autoscale.metrics import TelemetryBus
from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.slo import (SLObjective, SLOMonitor, counter_ratio_source,
                           histogram_threshold_source)
from repro.serving.scheduler import ContinuousBatchingScheduler

CFG = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


# ------------------------------------------------------------- objective --

def test_objective_validates_target_and_exposes_budget():
    slo = SLObjective("ttft", 0.99)
    assert slo.error_budget == pytest.approx(0.01)
    for bad in (0.0, 1.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            SLObjective("x", bad)


def test_monitor_rejects_bad_windows_and_inverted_hysteresis():
    slo = SLObjective("x", 0.9)
    src = lambda: (0.0, 0.0)                               # noqa: E731
    with pytest.raises(ValueError):
        SLOMonitor(slo, src, short_window=0, long_window=10)
    with pytest.raises(ValueError):
        SLOMonitor(slo, src, short_window=10, long_window=5)
    with pytest.raises(ValueError):
        SLOMonitor(slo, src, fire_burn=1.0, clear_burn=2.0)


# ---------------------------------------------------------- burn + alert --

class _Feed:
    """A scripted cumulative (bad, total) source."""

    def __init__(self):
        self.bad = 0.0
        self.total = 0.0

    def tick(self, bad_frac, n=10):
        self.bad += bad_frac * n
        self.total += n

    def __call__(self):
        return self.bad, self.total


def test_monitor_fire_requires_both_windows():
    """A single-tick blip saturates the short window but not the long one
    — the multi-window pattern's whole point is not alerting on it."""
    feed = _Feed()
    mon = SLOMonitor(SLObjective("lat", 0.9), feed,
                     short_window=2, long_window=40)
    for t in range(1, 30):
        feed.tick(0.0)
        mon.sample(t)
    feed.tick(1.0)                             # one terrible tick
    sig = mon.sample(30)
    assert sig["slo_lat_burn_short"] > 2.0     # short window saturated
    assert sig["slo_lat_burn_long"] < 2.0      # diluted over the long one
    assert sig["slo_lat_firing"] == 0.0 and not mon.firing


def test_monitor_fire_and_clear_hysteresis():
    feed = _Feed()
    mon = SLOMonitor(SLObjective("lat", 0.9), feed,
                     short_window=5, long_window=20,
                     fire_burn=2.0, clear_burn=1.0)
    t = 0
    for _ in range(10):                        # healthy warmup
        t += 1
        feed.tick(0.0)
        mon.sample(t)
    assert not mon.firing
    for _ in range(25):                        # sustained 5x burn
        t += 1
        feed.tick(0.5)
        mon.sample(t)
    assert mon.firing
    assert [tr["to"] for tr in mon.transitions] == ["firing"]
    for _ in range(25):                        # hover between clear and fire
        t += 1
        feed.tick(0.15)                        # burn 1.5: in the gap
        sig = mon.sample(t)
    assert mon.firing                          # hysteresis holds the alert
    assert 1.0 < sig["slo_lat_burn_short"] < 2.0
    for _ in range(30):                        # genuinely healthy again
        t += 1
        feed.tick(0.0)
        mon.sample(t)
    assert not mon.firing
    assert [tr["to"] for tr in mon.transitions] == ["firing", "clear"]


def test_burn_is_zero_without_traffic():
    feed = _Feed()
    mon = SLOMonitor(SLObjective("lat", 0.9), feed)
    assert mon.sample(1)["slo_lat_burn_short"] == 0.0
    mon2 = SLOMonitor(SLObjective("lat", 0.9), lambda: (0.0, 5.0))
    mon2.sample(1)
    assert mon2.sample(2)["slo_lat_burn_long"] == 0.0   # no new total


# ----------------------------------------------------------------- sources --

def test_histogram_threshold_source_is_conservative_under():
    h = Histogram("lat", (1.0, 10.0, 100.0))
    src = histogram_threshold_source(h, 10.0)
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    bad, total = src()
    # 5.0 lands in (1, 10] whose lower bound 1 < threshold: counted good
    # even though the threshold cuts through its bucket; 50 and 500 are in
    # buckets whose lower bounds (10, 100) guarantee exceedance
    assert (bad, total) == (2.0, 4.0)


def test_counter_ratio_source_reads_live_counters():
    reg = MetricsRegistry()
    bad, total = reg.counter("blocked"), reg.counter("attempts")
    src = counter_ratio_source(bad, total)
    assert src() == (0.0, 0.0)
    total.inc(8)
    bad.inc(2)
    assert src() == (2.0, 8.0)


# ----------------------------------------------------- rate startup guard --

def test_bus_rate_guards_short_spans():
    """Regression (S3): two samples one tick apart used to read a burst as
    a sustained rate over any horizon; now the window must span at least
    ``min_span_frac`` of the horizon before a rate is reported."""
    bus = TelemetryBus()
    bus.record(0, {"tokens_out": 0})
    assert bus.rate("tokens_out", 20) == 0.0          # single sample
    bus.record(1, {"tokens_out": 100})
    # a 1-tick span is noise against a 20-tick horizon
    assert bus.rate("tokens_out", 20) == 0.0
    assert bus.rate("tokens_out", 20, default=-1.0) == -1.0
    # an explicit whole-series read (horizon=None) still works at 2 samples
    assert bus.rate("tokens_out", None) == pytest.approx(100.0)
    for t in range(2, 11):
        bus.record(t, {"tokens_out": 100 * t})
    assert bus.rate("tokens_out", 20) == pytest.approx(100.0)
    # degenerate clock (no forward motion) stays on the default
    bus2 = TelemetryBus()
    bus2.record(5, {"x": 1})
    bus2.record(5, {"x": 9})
    assert bus2.rate("x", None) == 0.0


# ------------------------------------------------------------ integration --

def test_controller_merges_slo_signals_into_bus(params):
    sched = ContinuousBatchingScheduler(CFG, params, max_slots=2,
                                        page_size=8, max_seq_len=48)
    slo = SLObjective("ttft", 0.5, "half of requests admit within 2 ticks")
    mon = SLOMonitor(slo, histogram_threshold_source(sched.h_ttft, 2.0),
                     short_window=4, long_window=8)
    bands = CapacityBands(min_slots=1, max_slots=2, min_pages=7,
                          max_pages=15)
    ctl = AutoscaleController(sched, bands, eval_interval=2,
                              slo_monitors=[mon])
    rng = np.random.RandomState(0)
    for i in range(8):
        sched.submit(rng.randint(0, CFG.vocab_size, size=6), 5,
                     arrival_step=i // 2)
    done = ctl.run()
    assert len(done) == 8
    for sig in ("slo_ttft_burn_short", "slo_ttft_burn_long",
                "slo_ttft_firing"):
        assert sig in ctl.bus.series, sorted(ctl.bus.series)
        assert len(ctl.bus.series[sig]) > 0
    # the firing signal is a clean 0/1 the policies can threshold on
    assert set(v for _, v in ctl.bus.series["slo_ttft_firing"]) <= {0.0, 1.0}
