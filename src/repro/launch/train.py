"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the arch's reduced config end-to-end (the
full configs are exercised by the dry-run); on a real fleet the same driver
runs the full config with the blueprint-planned mesh.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ARCHS, REDUCED, get_arch, get_reduced
from repro.core.blueprint import suggest_plan
from repro.launch.mesh import make_mesh_for
from repro.optim.adamw import OptimConfig
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full published config (needs a real fleet)")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_arch(args.arch) if args.full_config else get_reduced(args.arch)
    n_dev = args.data_par * args.model_par
    mesh = make_mesh_for(args.data_par, args.model_par) if n_dev > 1 else None
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = suggest_plan(cfg, shape,
                        mesh if mesh is not None
                        else {"data": 1, "model": 1})
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"remat={plan.remat} notes={list(plan.notes)}")

    ocfg = OptimConfig(peak_lr=args.lr,
                       warmup_steps=max(1, args.steps // 10),
                       total_steps=args.steps)
    trainer = Trainer(cfg, ocfg, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      mesh=mesh, act_rules=plan.act_rules, remat=plan.remat)
    report = trainer.run(args.steps)
    print(json.dumps({"final_step": report.final_step,
                      "loss_first": round(report.losses[0], 4),
                      "loss_last": round(report.losses[-1], 4),
                      "restores": report.restores,
                      "wall_s": round(report.wall_seconds, 1)}))


if __name__ == "__main__":
    main()
