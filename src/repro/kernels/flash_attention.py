"""Pallas TPU flash-attention forward kernel.

Blocked online-softmax with explicit VMEM tiling: grid is
(batch*q_heads, q_blocks, kv_blocks) with the kv dimension innermost and
sequential; running max / sum / accumulator live in VMEM scratch that
persists across kv iterations. GQA is handled in the BlockSpec index maps
(each q head reads its kv group's block — kv is never duplicated in HBM).

Supports causal masking, sliding windows (gemma2 local layers) and logit
soft-capping. Block sizes default to 128x128 — MXU-aligned on v5e.

Target is TPU; correctness on this CPU-only container is established in
interpret mode against ``repro.kernels.ref`` (see tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], block_q: int, block_k: int,
               kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    # causal: skip compute for blocks fully above the diagonal
    needed = jnp.logical_or(
        jnp.logical_not(causal),
        ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * alpha + p.sum(axis=-1)
        m_scr[...] = m_new
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, d)  k/v: (B, S, KVH, d)  ->  (B, S, H, d)."""
    B, Sq, H, d = q.shape
    _, Skv, KVH, _ = k.shape
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)

    # pad sequence dims to block multiples (masked out via kv_len)
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v

    qf = jnp.moveaxis(qp, 2, 1).reshape(B * H, Sq + pq, d)
    kf = jnp.moveaxis(kp, 2, 1).reshape(B * KVH, Skv + pk, d)
    vf = jnp.moveaxis(vp, 2, 1).reshape(B * KVH, Skv + pk, d)

    n_q = (Sq + pq) // block_q
    n_kv = (Skv + pk) // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, H=H, KVH=KVH, G=G:
                         ((b // H) * KVH + (b % H) // G, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, H=H, KVH=KVH, G=G:
                         ((b // H) * KVH + (b % H) // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(B, H, Sq + pq, d)[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)
