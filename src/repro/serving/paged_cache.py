"""Paged KV cache: shared page pools + block tables for the serving engine.

The dense engine (``repro.serving.engine``) gives every sequence a
capacity-padded ring buffer — memory scales with ``batch * capacity`` even
when most sequences are short. Here K/V live in per-layer *page pools* of
shape ``(num_pages, page_size, KVH, head_dim)``; a sequence owns just the
pages its tokens fill, recorded in a block table row. Allocation and
freeing are O(pages) host-side list operations, so the continuous-batching
scheduler (``repro.serving.scheduler``) can admit and evict sequences
mid-flight without reshaping any device buffer.

Layout invariants
-----------------
* Page 0 is the **sink page**: never allocated, and every unused block-table
  entry points at it. Idle decode slots write their garbage token there and
  the attention mask (``seq_lens``) keeps it out of every real sequence's
  softmax.
* Token ``t`` of a sequence lives at ``(block_table[t // page_size],
  t % page_size)`` — pages are filled densely in order, so a sequence of
  length ``n`` owns exactly ``ceil(n / page_size)`` pages.
* With ``cfg.cache_quant`` the pools hold int8 K/V plus fp32
  per-(position, kv-head) scale pages — the same quantisation contract as
  the dense engine's ring buffers (``repro.models.attention.quantize_kv``).

SSM layers need no paging (their state is O(1) per sequence); they keep a
dense ``(max_slots, ...)`` state row per scheduler slot in the same cache
pytree, so hybrid archs (jamba, mamba2) flow through the same decode step.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import quantize_kv
from repro.models.transformer import depth_plan

SINK_PAGE = 0

# leaves whose first axis is the page-pool axis
PAGE_LEAVES = ("k_pages", "v_pages", "k_scale_pages", "v_scale_pages")


def pages_for_len(n_tokens: int, page_size: int) -> int:
    """Pages a sequence of ``n_tokens`` occupies (dense fill from page 0)."""
    return -(-n_tokens // page_size)


class PageAllocator:
    """Host-side free-list allocator over the shared page-id space.

    One allocator serves every layer: layer pools are shaped identically, so
    page id ``p`` addresses the same slot in each. Page 0 (the sink) is
    never handed out.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least one allocatable page + sink"
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, SINK_PAGE, -1))
        self._owner: Dict[int, Any] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._owner)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, owner: Any = None) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, free {len(self._free)} "
                f"of {self.num_pages - 1}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._owner[p] = owner
        return out

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == SINK_PAGE:
                raise ValueError("sink page cannot be freed")
            if p not in self._owner:
                raise ValueError(f"double free of page {p}")
            del self._owner[p]
            if p < self._shrink_target:
                self._free.append(p)
            # else: the page is being retired by a pending shrink

    # --------------------------------------------------------- live resize --
    # _shrink_target defaults past any page id, i.e. "no shrink pending";
    # set as a class attribute so allocators pickled/built before this field
    # existed keep working.
    _shrink_target: int = 1 << 62

    @property
    def shrink_pending(self) -> bool:
        return self._shrink_target < self.num_pages

    def grow(self, new_num_pages: int) -> None:
        """Add pages ``[num_pages, new_num_pages)`` to the free list; cancels
        any pending shrink (its retired pages return to the pool). The
        shrink target is cleared unconditionally — a stale target below the
        new size would read as a phantom pending shrink and let a later
        ``complete_shrink`` slice the grown pool out from under the free
        list."""
        assert new_num_pages >= self.num_pages
        old_target = min(self._shrink_target, self.num_pages)
        in_free = set(self._free)
        self._free.extend(p for p in range(old_target, self.num_pages)
                          if p not in self._owner and p not in in_free)
        self._shrink_target = 1 << 62
        self._free.extend(range(self.num_pages, new_num_pages))
        self.num_pages = new_num_pages

    def request_shrink(self, new_num_pages: int) -> None:
        """Retire free pages with id >= ``new_num_pages`` immediately; pages
        still owned keep their owner and block ``complete_shrink`` until
        freed (drain-before-shrink). Raising a pending target un-retires the
        pages between the two targets."""
        assert 2 <= new_num_pages <= self.num_pages
        old = min(self._shrink_target, self.num_pages)
        if new_num_pages > old:
            in_free = set(self._free)
            self._free.extend(p for p in range(old, new_num_pages)
                              if p not in self._owner
                              and p not in in_free)
        # relaxing all the way back to the pool size is a cancellation, not
        # a pending shrink — leave no stale target behind
        self._shrink_target = (new_num_pages if new_num_pages < self.num_pages
                               else 1 << 62)
        self._free = [p for p in self._free if p < new_num_pages]

    def shrink_ready(self) -> bool:
        return self.shrink_pending and all(p < self._shrink_target
                                           for p in self._owner)

    def complete_shrink(self) -> int:
        """Finish a drained shrink; returns the new pool size."""
        assert self.shrink_ready()
        self.num_pages = self._shrink_target
        self._shrink_target = 1 << 62
        return self.num_pages

    @property
    def effective_pages(self) -> int:
        """Pool size after any pending shrink lands (including sink)."""
        return min(self.num_pages, self._shrink_target)

    @property
    def capacity(self) -> int:
        """Allocatable pages after any pending shrink lands (minus sink)."""
        return self.effective_pages - 1


# ---------------------------------------------------------------------------
# cache pytree construction
# ---------------------------------------------------------------------------

def _attn_pool_leaves(cfg: ModelConfig, num_pages: int,
                      page_size: int) -> Dict[str, jnp.ndarray]:
    if cfg.attn_impl == "mla":
        raise NotImplementedError(
            "paged serving covers GQA archs; MLA decode keeps the dense "
            "compressed-cache path (see docs/serving.md)")
    hd = cfg.resolved_head_dim
    KVH = cfg.n_kv_heads
    kv_dt = jnp.int8 if cfg.cache_quant else jnp.dtype(cfg.dtype)
    out = {
        "k_pages": jnp.zeros((num_pages, page_size, KVH, hd), kv_dt),
        "v_pages": jnp.zeros((num_pages, page_size, KVH, hd), kv_dt),
    }
    if cfg.cache_quant:
        out["k_scale_pages"] = jnp.zeros((num_pages, page_size, KVH),
                                         jnp.float32)
        out["v_scale_pages"] = jnp.zeros((num_pages, page_size, KVH),
                                         jnp.float32)
    return out


def _ssm_slot_leaves(cfg: ModelConfig, max_slots: int) -> Dict[str, jnp.ndarray]:
    raw = ssm_mod.ssm_cache_spec(cfg, max_slots)
    return {k: jnp.zeros(shape, jnp.dtype(str(dt)))
            for k, (shape, _axes, dt) in raw.items()}


def _layer_leaves(cfg: ModelConfig, idx: int, num_pages: int, page_size: int,
                  max_slots: int) -> Dict[str, jnp.ndarray]:
    if cfg.block_kind(idx) == "ssm":
        return _ssm_slot_leaves(cfg, max_slots)
    return _attn_pool_leaves(cfg, num_pages, page_size)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     max_slots: int) -> Any:
    """Zero page pools in the same prefix/stack pytree shape the dense cache
    uses (``repro.models.model.cache_schema``), so the transformer's scanned
    stack threads them identically."""
    if cfg.is_encdec:
        raise NotImplementedError("paged serving targets decoder-only archs")
    prefix, period, n_periods = depth_plan(cfg)
    out: Dict[str, Any] = {}
    if prefix:
        out["prefix"] = {str(i): _layer_leaves(cfg, i, num_pages, page_size,
                                               max_slots)
                         for i in range(prefix)}
    out["stack"] = {
        str(p): jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape).copy(),
            _layer_leaves(cfg, prefix + p, num_pages, page_size, max_slots))
        for p in range(period)}
    return out


# ---------------------------------------------------------------------------
# prefill insertion
# ---------------------------------------------------------------------------

def _write_attn_prefill(cfg: ModelConfig, node: Dict[str, jnp.ndarray],
                        pre: Dict[str, jnp.ndarray], page_ids: jnp.ndarray,
                        page_slots: jnp.ndarray,
                        stacked: bool) -> Dict[str, jnp.ndarray]:
    """Scatter one sequence's prefill K/V (B=1) into its pages.

    ``page_ids``/``page_slots``: (n_write,) int32 — padding positions past
    the live length are routed to the sink page by the caller."""
    out = dict(node)
    n_write = page_ids.shape[0]
    for name in ("k", "v"):
        kv = pre[name][..., 0, :n_write, :, :] if stacked \
            else pre[name][0, :n_write]                   # ([L,]n,KVH,hd)
        if cfg.cache_quant:
            q8, sc = quantize_kv(kv)
            if stacked:
                out[f"{name}_pages"] = node[f"{name}_pages"].at[
                    :, page_ids, page_slots].set(q8)
                out[f"{name}_scale_pages"] = node[f"{name}_scale_pages"].at[
                    :, page_ids, page_slots].set(sc)
            else:
                out[f"{name}_pages"] = node[f"{name}_pages"].at[
                    page_ids, page_slots].set(q8)
                out[f"{name}_scale_pages"] = node[f"{name}_scale_pages"].at[
                    page_ids, page_slots].set(sc)
        else:
            dt = node[f"{name}_pages"].dtype
            if stacked:
                out[f"{name}_pages"] = node[f"{name}_pages"].at[
                    :, page_ids, page_slots].set(kv.astype(dt))
            else:
                out[f"{name}_pages"] = node[f"{name}_pages"].at[
                    page_ids, page_slots].set(kv.astype(dt))
    return out


def _write_ssm_prefill(node: Dict[str, jnp.ndarray],
                       pre: Dict[str, jnp.ndarray], slot,
                       stacked: bool) -> Dict[str, jnp.ndarray]:
    out = dict(node)
    for name in node:
        val = pre[name]
        if stacked:
            out[name] = node[name].at[:, slot].set(
                val[:, 0].astype(node[name].dtype))
        else:
            out[name] = node[name].at[slot].set(
                val[0].astype(node[name].dtype))
    return out


def write_prefill(cfg: ModelConfig, paged: Any, pre: Any, block_row,
                  slot, plen, n_write: int, page_size: int) -> Any:
    """Insert a freshly prefilled sequence (batch 1) into the paged cache.

    ``pre`` is the cache returned by a batch-1 prefill on an ``n_write``-long
    (possibly right-padded) prompt; ``plen`` (dynamic) is the live length —
    padding positions are scattered to the sink page, so one compilation per
    prefill *bucket* serves every prompt length in it. ``block_row``:
    (n_pg,) int32 page ids for this sequence (unused tail = sink).
    Returns the updated cache pytree; jit with ``n_write``/``page_size``
    static. For archs with SSM layers the caller must use ``n_write ==
    plen`` — an SSM final state folds padding tokens in.
    """
    t = jnp.arange(n_write)
    live = t < jnp.asarray(plen)
    page_ids = jnp.where(live, jnp.asarray(block_row)[t // page_size],
                         SINK_PAGE).astype(jnp.int32)
    page_slots = (t % page_size).astype(jnp.int32)

    def walk(node: Any, pnode: Any, stacked: bool) -> Any:
        if "k_pages" in node:
            return _write_attn_prefill(cfg, node, pnode, page_ids,
                                       page_slots, stacked)
        if "h" in node and "conv" in node:
            return _write_ssm_prefill(node, pnode, slot, stacked)
        return {k: walk(node[k], pnode[k], stacked or k == "stack")
                for k in node}

    return walk(paged, pre, False)


# ---------------------------------------------------------------------------
# live resize (the autoscaler's actuation path)
# ---------------------------------------------------------------------------

def _resize_axis(leaf: jnp.ndarray, axis: int, new: int) -> jnp.ndarray:
    """Grow (zero-pad) or shrink (slice) one leaf along ``axis``."""
    cur = leaf.shape[axis]
    if new == cur:
        return leaf
    if new > cur:
        pad_shape = leaf.shape[:axis] + (new - cur,) + leaf.shape[axis + 1:]
        return jnp.concatenate([leaf, jnp.zeros(pad_shape, leaf.dtype)],
                               axis=axis)
    idx = [slice(None)] * leaf.ndim
    idx[axis] = slice(0, new)
    return leaf[tuple(idx)]


def resize_cache_pages(cache: Any, new_num_pages: int) -> Any:
    """Resize every page pool to ``new_num_pages``.

    Growth appends zero pages — existing page ids (and everything any block
    table references) are untouched, so decoded tokens are unaffected.
    Shrink slices the tail; the caller (scheduler) guarantees every page
    with id >= ``new_num_pages`` is free and out of every live block table
    before calling. SSM slot leaves are untouched. Runs eagerly (outside
    jit) — resizes are rare, bucketed events.
    """
    def walk(node: Any, stacked: bool) -> Any:
        if "k_pages" in node:
            axis = 1 if stacked else 0
            return {k: (_resize_axis(v, axis, new_num_pages)
                        if k in PAGE_LEAVES else v) for k, v in node.items()}
        if "h" in node and "conv" in node:
            return node
        return {k: walk(node[k], stacked or k == "stack") for k in node}

    return walk(cache, False)


def resize_cache_slots(cache: Any, new_slots: int) -> Any:
    """Resize the dense per-slot SSM state rows to ``new_slots``.

    New slots get zero state — identical to a fresh ``init_paged_cache``
    slot, so a request later admitted there prefills exactly as it would
    have at construction time. Shrink slices the tail; the caller drains
    those slots first. Attention page pools are untouched (they have no
    slot axis).
    """
    def walk(node: Any, stacked: bool) -> Any:
        if "k_pages" in node:
            return node
        if "h" in node and "conv" in node:
            axis = 1 if stacked else 0
            return {k: _resize_axis(v, axis, new_slots)
                    for k, v in node.items()}
        return {k: walk(node[k], stacked or k == "stack") for k in node}

    return walk(cache, False)


# ---------------------------------------------------------------------------
# sizing helpers (used by core.blueprint.serving_page_plan and the bench)
# ---------------------------------------------------------------------------

def page_bytes_per_token(cfg: ModelConfig) -> int:
    """KV bytes one token occupies across all attention layers' pools."""
    hd, KVH = cfg.resolved_head_dim, cfg.n_kv_heads
    per = 2 * KVH * hd * (1 if cfg.cache_quant else 2)
    if cfg.cache_quant:
        per += 2 * KVH * 4                       # fp32 scales
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.block_kind(i) != "ssm")
    return per * n_attn


def pool_bytes(cfg: ModelConfig, num_pages: int, page_size: int) -> int:
    """Total HBM the page pools occupy (all layers)."""
    return page_bytes_per_token(cfg) * num_pages * page_size


def dense_cache_bytes(cfg: ModelConfig, batch: int, capacity: int) -> int:
    """Footprint of the dense engine's capacity-padded ring buffers, for the
    memory comparison in ``benchmarks/serve_bench.py``."""
    return page_bytes_per_token(cfg) * batch * capacity
