"""Static-batch serving engine: KV/state cache management, prefill + decode.

Cache layout mirrors the model's scan structure (see
``repro.models.model.cache_schema``). Sliding-window layers get
window-capacity ring buffers; SSM layers carry (state, conv-tail). The
decode step is a single jit-able function suitable for pjit lowering in the
dry-run (``decode_32k`` / ``long_500k`` cells).

This engine decodes one fixed batch at a time — every stream pays
``capacity`` cache memory and the batch runs until its longest member
finishes. For mixed-length request traffic use the continuous-batching
scheduler (``repro.serving.scheduler``) over the paged variant of this
cache (``repro.serving.paged_cache``): same quantisation contract
(``quantize_kv``), but K/V live in a shared page pool so sequences join
and leave mid-flight. MLA and enc-dec archs stay on this engine (see
docs/serving.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.schema import init_params

_SEQ_LEAVES = {"k", "v", "c_kv", "k_pe", "k_scale", "v_scale"}
_SEQ_AXIS_FROM_END = {"k": 3, "v": 3, "c_kv": 2, "k_pe": 2,
                      "k_scale": 2, "v_scale": 2}


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Any:
    """Zero-initialised cache pytree with ring-buffer capacities."""
    sch = M.cache_schema(cfg, batch, capacity)
    return init_params(sch, jax.random.PRNGKey(0))


def _place_seq(buf: jnp.ndarray, kv: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Place prefill kv (length S) into a capacity-``cap`` ring buffer."""
    cap, S = buf.shape[axis], kv.shape[axis]
    if S >= cap:
        tail = jax.lax.slice_in_dim(kv, S - cap, S, axis=axis)
        pos = (S - cap + np.arange(cap)) % cap
        inv = np.argsort(pos)               # slot j <- tail[inv[j]]
        return jnp.take(tail, inv, axis=axis)
    return jax.lax.dynamic_update_slice_in_dim(buf, kv, 0, axis=axis)


def load_prefill_cache(zeros: Any, pre: Any, path=()) -> Any:
    """Merge prefill-produced cache into the capacity-sized zero cache.

    When the target cache is int8-quantised (``cfg.cache_quant``) the
    prefill's bf16 kv is quantised here and scale leaves are synthesised.
    """
    if isinstance(zeros, dict):
        out = {}
        for k in zeros:
            if k in ("k_scale", "v_scale") and k not in pre:
                from repro.models.attention import quantize_kv
                _, scale = quantize_kv(pre[k[0]])
                out[k] = load_prefill_cache(zeros[k], scale, path + (k,))
            elif k in ("k", "v") and zeros[k].dtype == jnp.int8 \
                    and pre[k].dtype != jnp.int8:
                from repro.models.attention import quantize_kv
                q8, _ = quantize_kv(pre[k])
                out[k] = load_prefill_cache(zeros[k], q8, path + (k,))
            else:
                out[k] = load_prefill_cache(zeros[k], pre[k], path + (k,))
        return out
    key = path[-1]
    if key in _SEQ_LEAVES:
        axis = zeros.ndim - _SEQ_AXIS_FROM_END[key]
        return _place_seq(zeros, pre.astype(zeros.dtype), axis)
    return pre.astype(zeros.dtype)          # ssm h / conv states


def prefill(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            capacity: int):
    """-> (last-token logits, capacity cache, cur_len)."""
    B, S = batch["tokens"].shape
    lg, pre_cache = M.prefill(cfg, params, batch)
    zeros = init_cache(cfg, B, capacity)
    cache = load_prefill_cache(zeros, pre_cache)
    return lg, cache, jnp.asarray(S, jnp.int32)


def decode_step(cfg: ModelConfig, params, cache, tokens: jnp.ndarray,
                cur_len: jnp.ndarray):
    """One serving step: tokens (B,1) at position cur_len."""
    return M.decode_step(cfg, params, cache, tokens, cur_len)


def greedy_decode(cfg: ModelConfig, params, cache, first_token: jnp.ndarray,
                  cur_len: jnp.ndarray, n_steps: int):
    """Greedy generation loop (lax.scan over steps). -> (tokens, cache)."""

    def body(carry, _):
        tok, cl, cc = carry
        lg, cc = M.decode_step(cfg, params, cc, tok, cl)
        nxt = jnp.argmax(lg[:, -1, :cfg.vocab_size], axis=-1
                         ).astype(jnp.int32)[:, None]
        return (nxt, cl + 1, cc), nxt

    (_, cur_len, cache), toks = jax.lax.scan(
        body, (first_token, cur_len, cache), None, length=n_steps)
    return jnp.moveaxis(toks[..., 0], 0, 1), cache, cur_len
