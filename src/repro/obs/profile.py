"""Opt-in kernel profiling: wall time + modeled bytes/FLOPs per dispatch.

``KernelProfiler`` records every paged-decode / prefill dispatch the
serving stack makes: per-call wall time (after ``jax.block_until_ready``
so async dispatch doesn't under-report), plus *modeled* work — FLOPs
from the active-parameter count and bytes-moved from the weight +
paged-KV traffic the call implies. Dividing modeled work by the machine
peaks gives a roofline-utilization fraction per kernel kind:

    frac = max(flops / PEAK_FLOPS, bytes / HBM_BW) / wall_seconds

i.e. how close the call came to the speed-of-light time its heavier
bottleneck allows (1.0 = on the roofline; CPU interpret-mode runs will
sit far below it, which is itself the point of reporting the fraction).

Two attachment styles:

* scheduler-level — ``ContinuousBatchingScheduler.enable_profiling()``
  times whole dispatches with token/context detail (decode batch size,
  prefill chunk length);
* ops-level — ``repro.kernels.ops.set_profile_hook(profiler.hook())``
  times individual kernel entry points with byte counts taken from the
  actual array arguments.

Profiling is read-only: it never touches model state, so profiled runs
emit byte-identical tokens (same contract as tracing).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax

__all__ = ["KernelProfiler", "PEAK_FLOPS", "HBM_BW", "PCIE_BW",
           "PCIE_LATENCY"]

# Modeled accelerator peaks (bf16 FLOPs, HBM bytes/s). These mirror the
# planning constants in repro/launch/dryrun.py — duplicated here rather
# than imported because dryrun sets XLA_FLAGS to force a 512-device host
# platform at import time, which must never happen as a side effect of
# turning profiling on.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
# Modeled host<->device interconnect (PCIe gen4 x16-class): sustained
# bytes/s plus a fixed per-transfer setup cost (DMA programming, host
# pinning, completion interrupt). The serving host-RAM KV tier's
# recompute-vs-transfer cost model compares a swap-in against re-running
# the prefill at PEAK_FLOPS — the fixed latency term is what makes short
# chains cheaper to recompute and long chains cheaper to move.
PCIE_BW = 32e9
PCIE_LATENCY = 100e-6


class KernelProfiler:
    """Accumulates per-kind dispatch timings and modeled work.

    ``cfg`` (a model config) enables the modeled-bytes/FLOPs defaults:
    2 * active_params FLOPs per generated token, weight bytes + paged-KV
    bytes per token of attended context. Without ``cfg`` only wall time
    and explicitly-passed work are recorded.
    """

    def __init__(self, cfg: Any = None, *, tp: int = 1,
                 dtype_bytes: int = 2, peak_flops: float = PEAK_FLOPS,
                 hbm_bw: float = HBM_BW) -> None:
        self.cfg = cfg
        self.tp = max(1, int(tp))
        self.peak_flops = float(peak_flops) * self.tp
        self.hbm_bw = float(hbm_bw) * self.tp
        self.enabled = True
        self._param_bytes = 0.0
        self._active_params = 0.0
        self._kv_bytes_per_token = 0.0
        if cfg is not None:
            # late import keeps `import repro.obs` free of serving deps
            from repro.serving import paged_cache as PC
            self._active_params = float(cfg.active_param_count())
            self._param_bytes = self._active_params * dtype_bytes
            self._kv_bytes_per_token = float(PC.page_bytes_per_token(cfg))
        self.records: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------ record --
    def _bucket(self, kind: str) -> Dict[str, float]:
        return self.records.setdefault(kind, {
            "calls": 0.0, "wall_s": 0.0,
            "modeled_flops": 0.0, "modeled_bytes": 0.0,
        })

    def record(self, kind: str, wall_s: float, *, tokens: int = 0,
               ctx_tokens: int = 0, flops: Optional[float] = None,
               bytes_moved: Optional[float] = None) -> None:
        """One dispatch: ``tokens`` generated/processed, ``ctx_tokens`` of
        KV context attended. FLOPs/bytes default to the cfg-derived model
        and can be overridden per call."""
        if not self.enabled:
            return
        if flops is None:
            flops = 2.0 * self._active_params * tokens
        if bytes_moved is None:
            bytes_moved = (self._param_bytes
                           + self._kv_bytes_per_token * (tokens + ctx_tokens))
        b = self._bucket(kind)
        b["calls"] += 1
        b["wall_s"] += float(wall_s)
        b["modeled_flops"] += float(flops)
        b["modeled_bytes"] += float(bytes_moved)

    def record_op(self, kind: str, wall_s: float, args: Any) -> None:
        """Ops-level record: bytes = actual array traffic (sum of argument
        buffer sizes), no FLOP model."""
        if not self.enabled:
            return
        nbytes = sum(getattr(leaf, "nbytes", 0)
                     for leaf in jax.tree_util.tree_leaves(args))
        self.record(kind, wall_s, flops=0.0, bytes_moved=float(nbytes))

    def hook(self) -> Callable[[str, float, Any], None]:
        """Adapter for ``repro.kernels.ops.set_profile_hook``."""
        return self.record_op

    def timed(self, kind: str, fn: Callable[..., Any], *args: Any,
              tokens: int = 0, ctx_tokens: int = 0, **kw: Any) -> Any:
        """Call ``fn``, block on its outputs, record the wall time."""
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        out = jax.block_until_ready(out)
        self.record(kind, time.perf_counter() - t0,
                    tokens=tokens, ctx_tokens=ctx_tokens)
        return out

    # ----------------------------------------------------------- report --
    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-kind totals plus the roofline-utilization fraction:
        modeled speed-of-light time (max of compute- and bandwidth-bound
        times) over measured wall time."""
        out: Dict[str, Dict[str, float]] = {}
        for kind, b in sorted(self.records.items()):
            wall = b["wall_s"]
            sol = max(b["modeled_flops"] / self.peak_flops,
                      b["modeled_bytes"] / self.hbm_bw)
            out[kind] = {
                "calls": int(b["calls"]),
                "wall_s": wall,
                "modeled_flops": b["modeled_flops"],
                "modeled_bytes": b["modeled_bytes"],
                "roofline_frac": (sol / wall) if wall > 0 else 0.0,
            }
        return out

    def reset(self) -> None:
        self.records.clear()
