"""Telemetry bus: windowed per-tick signals for the autoscaling loop.

One ``TelemetryBus`` aggregates everything the policy engine looks at:

* serving-scheduler signals (``sample_scheduler``) — queue depth, decode
  slot occupancy, page-pool occupancy, cumulative tokens out, admission
  blocks;
* heartbeat signals (``sample_monitor``) — DEAD / STRAGGLER host counts.

Samples are keyed on a monotonically increasing clock — the SimCloud clock
when the controller is cluster-wired, the scheduler tick otherwise — and
kept in bounded per-signal deques so a long serving run cannot grow host
memory. Aggregation (``mean``/``max``/``last``/``rate``) is computed over
a trailing window at read time; there is no background thread, the
controller drives sampling synchronously between decode ticks.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, Iterable, Optional, Tuple

from repro.core.heartbeat import HeartbeatMonitor, HostState


def sample_scheduler(sched) -> Dict[str, float]:
    """One tick's worth of signals from a ``ContinuousBatchingScheduler``."""
    # occupancy reads against the *effective* capacity: during a pending
    # shrink the retired pages are no longer allocatable, and reading load
    # against the old pool size would mask real pressure
    pages_total = max(sched.alloc.capacity, 1)
    due = sched.pending_due
    return {
        "queue_depth": float(due),
        "active": float(sched.num_active),
        "slots": float(sched.target_slots),
        "slot_occupancy": sched.num_active / max(sched.target_slots, 1),
        "demand": float(sched.num_active + due),
        # physical occupancy: with the shared-prefix cache a page may back
        # several sequences, so this counts each page once — the signal the
        # page autoscaler should track (pressure on the real pool)
        "pages_used": float(sched.pages_allocated),
        "pages_total": float(pages_total),
        "page_occupancy": sched.pages_allocated / pages_total,
        "reserved_pages": float(sched.reserved_pages),
        "tokens_out": float(sched.stats["tokens_out"]),
        "admit_blocked": float(sched.stats["admit_blocked"]),
        "prefix_hits": float(sched.stats["prefix_hits"]),
        "cached_tokens": float(sched.stats["cached_tokens"]),
        # host-tier working-set split: ``pages_hot`` backs live streams,
        # retained pages are cold session chains reclaimable at a swap or
        # re-prefill. Scaling HBM on hot occupancy instead of raw
        # page_occupancy is the tier's autoscaling dividend — a pool dense
        # with idle sessions no longer reads as full.
        "pages_hot": float(sched.hot_pages),
        "pages_retained": float(sched.retained_page_count),
        "hot_occupancy": sched.hot_pages / pages_total,
        "host_pages_used": float(sched.stats["host_pages_used"]),
        "swap_ins": float(sched.stats["swap_ins"]),
        "swap_outs": float(sched.stats["swap_outs"]),
    }


def sample_monitor(monitor: Optional[HeartbeatMonitor]) -> Dict[str, float]:
    """DEAD / STRAGGLER counts from the Ambari heartbeat monitor."""
    if monitor is None:
        return {"dead_hosts": 0.0, "straggler_hosts": 0.0}
    states = [h.state for h in monitor.hosts.values()]
    return {
        "dead_hosts": float(sum(s == HostState.DEAD for s in states)),
        "straggler_hosts": float(
            sum(s == HostState.STRAGGLER for s in states)),
    }


class TelemetryBus:
    """Bounded windowed series, one deque of ``(t, value)`` per signal."""

    def __init__(self, maxlen: int = 4096):
        self.maxlen = maxlen
        self.series: Dict[str, Deque[Tuple[float, float]]] = {}

    def record(self, t: float, values: Dict[str, float]) -> None:
        for name, v in values.items():
            self.series.setdefault(
                name, collections.deque(maxlen=self.maxlen)).append(
                    (t, float(v)))

    # ------------------------------------------------------------- reads --
    def _window(self, name: str, horizon: Optional[float]
                ) -> Iterable[Tuple[float, float]]:
        s = self.series.get(name)
        if not s:
            return []
        if horizon is None:
            return s
        cut = s[-1][0] - horizon
        return [(t, v) for t, v in s if t >= cut]

    def last(self, name: str, default: float = 0.0) -> float:
        s = self.series.get(name)
        return s[-1][1] if s else default

    def mean(self, name: str, horizon: Optional[float] = None,
             default: float = 0.0) -> float:
        w = list(self._window(name, horizon))
        return sum(v for _, v in w) / len(w) if w else default

    def max(self, name: str, horizon: Optional[float] = None,
            default: float = 0.0) -> float:
        w = list(self._window(name, horizon))
        return max(v for _, v in w) if w else default

    def rate(self, name: str, horizon: Optional[float] = None, *,
             default: float = 0.0, min_span_frac: float = 0.25) -> float:
        """Per-clock-unit rate of change of a cumulative counter (e.g.
        ``tokens_out`` -> tokens/s on the SimCloud clock).

        Horizon contract: the rate is the counter delta over the trailing
        ``horizon`` of clock time, differentiated between the window's
        endpoint samples — so it only means "sustained rate over the
        horizon" once the recorded samples actually *span* (most of) it.
        Early in a run they don't: with exactly two samples one tick
        apart, a single-tick burst of N reads as a steady N/tick and a
        scale-up policy fires on noise. Until the window covers at least
        ``min_span_frac`` of the requested horizon, ``default`` is
        returned instead (with ``horizon=None`` any 2+ samples qualify —
        the caller asked for the whole-series rate).
        """
        w = list(self._window(name, horizon))
        if len(w) < 2:
            return default
        (t0, v0), (t1, v1) = w[0], w[-1]
        if t1 <= t0:
            return default
        if horizon is not None and (t1 - t0) < min_span_frac * horizon:
            return default
        return (v1 - v0) / (t1 - t0)
