"""Serving throughput benchmark: continuous batching (paged KV) vs the
static-batch engine, on a mixed-length request workload.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--arch qwen3-32b]

Both engines serve the *same* request set (mixed prompt lengths, mixed
generation lengths) with the same batch width:

* **static** (``repro.serving.engine``): requests are grouped into fixed
  batches; each group pads every prompt to the group max and decodes until
  the group's *longest* generation finishes. That is what a fixed-batch
  server must do — the padding and the drained-slot steps are the cost
  being measured.
* **paged** (``repro.serving.scheduler``): one shared page pool, requests
  join any free slot on arrival and free their pages on finish, so slots
  stay occupied.

Both paths are warmed (one full pass) before the timed pass so jit
compilation is excluded; static prefill/decode are jit-wrapped the same
way the scheduler's step is. Reported ``useful_tok_per_s`` counts only
requested generation tokens. The memory line compares the static engine's
capacity-padded ring buffers against the pages the scheduler actually
touched (its peak page occupancy).

``--replicas 1,2,4`` switches to **fleet mode**: the same trace is served
through the replicated fabric (``repro.serving.router``) at each fleet
width, the per-replica slot/page budget divided so k replicas of
``batch/k`` slots hold the same total capacity, and the report carries
fleet throughput, p50/p99 latency in fleet ticks, and the router's
steady-state reserved-page imbalance. ``--smoke --replicas 2`` is the CI
fleet smoke step.

``--mixed`` switches to **mixed-workload mode**: a long-prompt + chat
mix (fp32) served through a small fabric three ways — monolithic
prefill, chunked prefill (``--chunk-budget`` tokens per tick), and
optionally chunked + prefill/decode disaggregation (``--disagg K``
prefill replicas donating KV pages to the decode side). Each variant
reports decode-side per-tick wall latency (p50/p99 over the slowest
decode-capable replica per tick — the parallel-fabric cost of a tick)
and useful throughput. Byte-identity across all variants is a *hard
gate*; the headline is p99 tick latency improving at equal-or-better
throughput once long prefills stop stalling decode ticks. ``--out``
writes the report (``BENCH_chunked.json``). ``--smoke --mixed
--disagg`` is the CI disaggregation smoke step.

``--tp 1,2,4`` switches to **shard-group mode**: the same trace (fp32)
is served by one scheduler at each tensor-parallel width — page pools and
attention heads split tp ways across a shard group — reporting
throughput, p50/p99 tick latency, and per-shard page-pool utilisation.
Byte-identity vs ``tp=1`` is a *hard gate*: any token difference exits
non-zero (the determinism contract in docs/sharding.md).
``--smoke --tp 2`` is the CI shard-group smoke step.

``--spec K`` switches to **speculative-decoding mode**: the paged
scheduler spec-off vs two spec-on draft sources — n-gram prompt lookup
and the incremental-cache draft model (self-drafting) — K drafts per
stream per verify tick on the staggered long-tail trace (fp32).
Byte identity is a *hard gate* for every variant — dense full-workload,
tp=2, and an SSM arch (sequential verify + state rollback) — and the
headline is ``tick_speedup``, spec-off decode dispatches over the draft
variant's (>=1.5x gate; wall clock is advisory on the compute-bound CPU
simulator — see the report's ``note``), with accept-rate and
emitted-per-verify stats. ``--out`` writes ``BENCH_spec.json``;
``--smoke --spec`` is the CI speculation smoke step.

``--sessions`` switches to **kv-tier mode**: a session-heavy trace —
3x ``--batch`` interactive multi-turn sessions with idle gaps between
turns — served by the paged scheduler with the host-RAM page tier on vs
off, on the *same* HBM page pool. Tier-off drops every chain at finish
and re-prefills each turn; tier-on retains chains, preempts cold ones
to host RAM under pressure, and the recompute-vs-transfer cost model
decides per chain whether resume swaps in or re-prefills. Byte identity
tier-on vs tier-off is the hard gate, fp32 AND int8 (swaps preserve
quantised pool bytes exactly); both cost-model paths firing and bounded
resume latency are gated alongside. ``--out`` writes
``BENCH_kv_tier.json``; ``--smoke --sessions`` is the CI kv-tier smoke
step.

Every ``--out`` report shares one schema: top-level ``bench`` names the
mode and ``gates`` maps hard-gate names to booleans —
``benchmarks/check_bench.py`` asserts them in CI.

``--trace-out`` / ``--metrics-out`` (any mode) run one extra pass of the
trace *after* the timed passes with the observability plane attached
(docs/observability.md) and export the lifecycle trace (Chrome
trace-event JSON) / the metric registries (Prometheus text). The bench
validates its own artifacts — an empty or unparsable export exits
non-zero — which is what the CI obs smoke step leans on. Latency
percentiles everywhere are nearest-rank (``repro.obs.metrics.percentile``),
the same estimator the histogram quantiles approximate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.registry import REDUCED
from repro.launch.serve import persona_workload
from repro.models import model as M
from repro.obs.metrics import percentile
from repro.obs.profile import HBM_BW, PEAK_FLOPS
from repro.obs.trace import Tracer
from repro.serving import engine as E
from repro.serving import paged_cache as PC
from repro.serving.request import make_request
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler


def write_report(args, out, bench, gates):
    """Every benchmark report under one schema: ``bench`` names the mode,
    ``gates`` holds the hard-gate booleans, and the mode-specific payload
    rides alongside. Prints the report, honours ``--out``, and returns
    the names of failed gates — ``benchmarks/check_bench.py`` asserts the
    same booleans in CI, one gate for every bench artifact."""
    report = {"bench": bench, **out, "gates": gates}
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
    return [k for k, ok in gates.items() if not ok]


def export_obs_artifacts(args, make_engine, workload):
    """One extra pass of ``workload`` with the observability plane attached
    (run after the timed passes so artifact export never shares a pass with
    a timing measurement), writing ``--trace-out`` / ``--metrics-out``.

    The bench validates its own exports — an empty or unparsable artifact
    is a hard failure, so the CI obs smoke step cannot silently write
    garbage. Returns the export counts (or None when neither flag is set).
    """
    if not (args.trace_out or args.metrics_out):
        return None
    eng = make_engine()            # scheduler or router: same surface
    tracer = Tracer()
    eng.set_tracer(tracer)
    base = eng.step_idx
    for i, (prompt, gen) in enumerate(workload):
        arrival = base + (i // args.arrivals_per_step
                          if args.arrivals_per_step else 0)
        eng.submit(prompt, gen, arrival_step=arrival)
    eng.run()
    tracer.finish_open()
    written = {}
    if args.trace_out:
        written["trace_events"] = tracer.write_chrome(args.trace_out)
        with open(args.trace_out) as fh:
            data = json.load(fh)       # unparsable -> json error -> nonzero
        if not [e for e in data.get("traceEvents", [])
                if e.get("ph") != "M"]:
            raise SystemExit(f"--trace-out {args.trace_out}: no lifecycle "
                             "events recorded — tracing wiring broken")
    if args.metrics_out:
        text = (eng.expose() if hasattr(eng, "expose")
                else eng.registry.expose())
        with open(args.metrics_out, "w") as fh:
            fh.write(text)
        written["metrics_written"] = text.count("# TYPE")
        if not written["metrics_written"]:
            raise SystemExit(f"--metrics-out {args.metrics_out}: empty "
                             "exposition — metrics wiring broken")
    return written


def bench_cfg(arch: str, wide: int, deep: int):
    """REDUCED config scaled to serving-realistic width/depth — at the
    default reduced dims (d_model 64, 2 layers) python dispatch dominates
    and neither engine's structure is visible."""
    c = REDUCED[arch]
    return dataclasses.replace(
        c, name=f"{c.name}-serve-bench", d_model=c.d_model * wide,
        d_ff=c.d_ff * wide, n_heads=c.n_heads * wide,
        n_kv_heads=c.n_kv_heads * wide, n_layers=c.n_layers * deep)


def make_workload(cfg, rng, n, p_lo, p_hi, g_lo, g_hi, long_frac):
    """Mixed prompts; bimodal generation lengths (``long_frac`` of requests
    generate ``g_hi`` tokens, the rest ``g_lo``..2*``g_lo``). The long tail
    is what head-of-line-blocks a static batch: one long member pins the
    whole group while its finished neighbours' slots idle."""
    out = []
    for _ in range(n):
        plen = int(rng.randint(p_lo, p_hi + 1))
        if rng.rand() < long_frac:
            gen = g_hi
        else:
            gen = int(rng.randint(g_lo, 2 * g_lo + 1))
        out.append((rng.randint(0, cfg.vocab_size, size=plen
                                ).astype(np.int32), gen))
    return out


# the persona trace builder is shared with the launcher's --shared-prefix
# mode (one generator, one definition of "the persona workload")


def make_mixed_workload(cfg, rng, n, long_frac, long_len,
                        chat_lo, chat_hi, gen_lo, gen_hi):
    """Long-prompt + chat mix: ``long_frac`` of requests carry a
    document-sized prompt (3/4..1x ``long_len``) with a terse answer, the
    rest are short chat turns with mixed generations. The long prompts are
    what a monolithic prefill turns into decode-tick latency spikes —
    every decoding stream stalls behind one giant compiled call."""
    out = []
    for _ in range(n):
        if rng.rand() < long_frac:
            plen = int(rng.randint(max(3 * long_len // 4, 1), long_len + 1))
            gen = gen_lo
        else:
            plen = int(rng.randint(chat_lo, chat_hi + 1))
            gen = int(rng.randint(gen_lo, gen_hi + 1))
        out.append((rng.randint(0, cfg.vocab_size, size=plen
                                ).astype(np.int32), gen))
    return out


# ---------------------------------------------------------------- static --

def run_static(cfg, params, workload, batch_width):
    """Fixed batches in arrival order; group-max padding and decode length.

    Uses the shared Request lifecycle (``engine.serve_requests``), so the
    static baseline fills the same bookkeeping the paged scheduler does.
    """
    reqs = [make_request(i, p, g) for i, (p, g) in enumerate(workload)]
    E.serve_requests(cfg, params, reqs, batch_width)
    return sum(g for _, g in workload)


# ----------------------------------------------------------------- paged --

def run_paged(sched, workload, arrivals_per_step):
    base = sched.step_idx
    for i, (prompt, gen) in enumerate(workload):
        arrival = base + (i // arrivals_per_step if arrivals_per_step else 0)
        sched.submit(prompt, gen, arrival_step=arrival)
    before = dict(sched.stats)
    sched.run()
    return {k: sched.stats[k] - before[k] for k in before}


# --------------------------------------------------------- shared prefix --

def _timed_pass(sched, workload, arrivals_per_step):
    """One timed scheduler pass; returns (wall, stats delta, requests).

    The single measurement harness for the shared-prefix and shard-group
    modes — submit with staggered arrivals, run, delta the stats."""
    base = sched.step_idx
    reqs = []
    for i, (prompt, gen) in enumerate(workload):
        arrival = base + (i // arrivals_per_step if arrivals_per_step else 0)
        reqs.append(sched.submit(prompt, gen, arrival_step=arrival))
    before = dict(sched.stats)
    t0 = time.time()
    sched.run()
    wall = time.time() - t0
    delta = {k: sched.stats[k] - before[k] for k in before}
    return wall, delta, reqs


def bench_shared_prefix(cfg, params, args):
    """Head-to-head of the paged scheduler with the copy-on-write prefix
    cache on vs off, on the persona workload. The claim being reproduced:
    sharing the persona's pages skips the dominant prefill and collapses
    the page-pool footprint, at byte-identical output tokens."""
    rng = np.random.RandomState(args.seed)
    user_hi = max(args.user_len, 2)
    # short generations ([gen-lo, 2*gen-lo], not --gen-hi: that flag shapes
    # the head-to-head's bimodal tail) keep prefill the dominant cost the
    # prefix cache removes — the workload the mode is named after
    g_lo = max(args.gen_lo, 1)
    workload = persona_workload(
        cfg.vocab_size, rng, args.personas, args.users_per_persona,
        args.persona_len, max(user_hi // 2, 1), user_hi, g_lo, 2 * g_lo)
    max_seq = args.persona_len + user_hi + 2 * g_lo + 1
    gen_total = sum(g for _, g in workload)

    sides = {}
    tokens = {}
    for mode, pc in (("no_sharing", False), ("shared", True)):
        sched = ContinuousBatchingScheduler(
            cfg, params, max_slots=args.batch, page_size=args.page_size,
            max_seq_len=max_seq, prefix_cache=pc)
        _timed_pass(sched, workload, args.arrivals_per_step)        # warm
        best = None
        for _ in range(args.repeats):
            res = _timed_pass(sched, workload, args.arrivals_per_step)
            if best is None or res[0] < best[0]:
                best = res
        wall, delta, best_reqs = best
        tokens[mode] = [list(r.out_tokens) for r in best_reqs]
        sides[mode] = {
            "useful_tok_per_s": round(gen_total / wall, 1),
            "wall_s": round(wall, 3),
            "peak_pages": sched.stats["peak_pages"],
            "prefix_hits": delta["prefix_hits"],
            "cached_tokens": delta["cached_tokens"],
            "cow_forks": delta["cow_forks"],
            "hit_rate": round(delta["prefix_hits"]
                              / max(delta["prefills"], 1), 3),
        }
    base_pages = max(sides["no_sharing"]["peak_pages"], 1)
    out = {
        "arch": cfg.name,
        "mode": "shared-prefix",
        "workload": {"personas": args.personas,
                     "users_per_persona": args.users_per_persona,
                     "persona_len": args.persona_len,
                     "requests": len(workload)},
        "no_sharing": sides["no_sharing"],
        "shared": sides["shared"],
        "throughput_ratio": round(sides["shared"]["useful_tok_per_s"]
                                  / sides["no_sharing"]["useful_tok_per_s"],
                                  2),
        "page_savings_frac": round(
            1 - sides["shared"]["peak_pages"] / base_pages, 3),
        "tokens_identical": tokens["shared"] == tokens["no_sharing"],
    }
    return out


# ----------------------------------------------------------- shard groups --

def bench_tp(cfg, params, args, widths):
    """Shard-group mode: one scheduler serving the same trace at each tp
    width, with byte-identity vs tp=1 as a hard gate. fp32 for the same
    reason as the shared-prefix gate: exact argmax equality across
    differently-grouped compiled paths is an fp32 property."""
    rng = np.random.RandomState(args.seed)
    workload = make_workload(cfg, rng, args.requests, args.prompt_lo,
                             args.prompt_hi, args.gen_lo, args.gen_hi,
                             args.long_frac)
    max_seq = args.prompt_hi + args.gen_hi + 1
    gen_total = sum(g for _, g in workload)
    sides, tokens = [], {}
    for k in widths:
        sched = ContinuousBatchingScheduler(
            cfg, params, max_slots=args.batch, page_size=args.page_size,
            max_seq_len=max_seq, tp=k)
        _timed_pass(sched, workload, args.arrivals_per_step)       # warm
        best = None
        for _ in range(args.repeats):
            res = _timed_pass(sched, workload, args.arrivals_per_step)
            if best is None or res[0] < best[0]:
                best = res
        best_wall, delta, reqs = best
        tokens[k] = [list(r.out_tokens) for r in reqs]
        lat = [float(r.finish_step - r.arrival_step) for r in reqs]
        shard = sched.shard_stats()
        per0 = shard["per_shard"][0]
        sides.append({
            "tp": k,
            "useful_tok_per_s": round(gen_total / best_wall, 1),
            "wall_s": round(best_wall, 3),
            "decode_steps": delta["decode_steps"],
            "p50_latency_ticks": percentile(lat, 50),
            "p99_latency_ticks": percentile(lat, 99),
            "peak_pages": sched.stats["peak_pages"],
            "per_shard_pool": {
                "shards": k,
                "peak_pages": per0["peak_pages"],
                "peak_utilization": per0["peak_utilization"],
                "pool_bytes_per_shard": per0["pool_bytes"],
            },
        })
    base_tp = widths[0]
    identical = all(tokens[k] == tokens[base_tp] for k in widths[1:])
    return {
        "arch": cfg.name,
        "mode": "shard-group",
        "requests": len(workload),
        "batch_width": args.batch,
        "tp": sides,
        "tokens_identical": identical,
    }


# ----------------------------------------------------------------- mixed --

def run_mixed(router, workload, arrivals_per_step):
    """One timed pass with per-tick replica timings; returns
    (wall, finished requests, decode-side tick walls, stats delta)."""
    base = router.step_idx
    reqs = []
    for i, (prompt, gen) in enumerate(workload):
        arrival = base + (i // arrivals_per_step if arrivals_per_step else 0)
        reqs.append(router.submit(prompt, gen, arrival_step=arrival))
    router.tick_timings.clear()
    keys = ("prefill_chunk_tokens", "prefill_dispatches", "decode_steps")
    before = {k: router.fleet_stats().get(k, 0) for k in keys}
    t0 = time.time()
    # max_fuse=1: tick latency only means something at real ticks — a
    # fused k-tick scan would report one giant wall for k ticks on the
    # monolithic side and nothing comparable on the chunked side (which
    # pins k=1 while chunks are in flight)
    router.run(max_fuse=1)
    wall = time.time() - t0
    after = router.fleet_stats()
    delta = {k: after.get(k, 0) - before[k] for k in keys}
    # a real fabric steps its replicas in parallel: one tick costs the
    # slowest decode-capable member, and prefill-role replicas are off the
    # decode critical path entirely — that is the latency disaggregation buys
    ticks = []
    for timing in router.tick_timings:
        decode_walls = [dt for (role, dt) in timing.values()
                        if role != "prefill"]
        if decode_walls:
            ticks.append(max(decode_walls))
    return wall, reqs, ticks, delta


def bench_mixed(cfg, params, args):
    """Monolithic vs chunked vs chunked+disaggregated on the same mixed
    trace and the same fleet width. The contract: every variant emits
    byte-identical tokens (hard gate) while chunking bounds the work a
    single tick can absorb, so the decode-tick p99 tightens."""
    rng = np.random.RandomState(args.seed)
    workload = make_mixed_workload(
        cfg, rng, args.requests, args.long_frac, args.long_prompt,
        args.prompt_lo, args.prompt_hi, args.gen_lo, args.gen_hi)
    max_seq = max(args.long_prompt, args.prompt_hi) + args.gen_hi + 1
    gen_total = sum(g for _, g in workload)
    replicas = (args.disagg + 1) if args.disagg else 2

    variants = [("monolithic", None, 0),
                ("chunked", args.chunk_budget, 0)]
    if args.disagg:
        variants.append(("chunked_disagg", args.chunk_budget, args.disagg))

    sides, tokens = {}, {}
    for name, budget, disagg in variants:
        router = ServingRouter(cfg, params, replicas=replicas,
                               max_slots=args.batch,
                               page_size=args.page_size, max_seq_len=max_seq,
                               prefill_budget=budget, disagg=disagg)
        router.record_timing = True
        run_mixed(router, workload, args.arrivals_per_step)        # warm
        best = None
        for _ in range(args.repeats):
            res = run_mixed(router, workload, args.arrivals_per_step)
            if best is None or res[0] < best[0]:
                best = res
        wall, reqs, ticks, delta = best
        tokens[name] = [list(r.out_tokens) for r in reqs]
        lat = [float(r.finish_step - r.arrival_step) for r in reqs]
        dispatches = delta["prefill_dispatches"] + delta["decode_steps"]
        sides[name] = {
            "useful_tok_per_s": round(gen_total / wall, 1),
            "wall_s": round(wall, 3),
            "ticks": len(ticks),
            "p50_tick_ms": round(percentile(ticks, 50) * 1e3, 3),
            "p99_tick_ms": round(percentile(ticks, 99) * 1e3, 3),
            "p99_latency_ticks": percentile(lat, 99),
            "prefill_dispatches": delta["prefill_dispatches"],
            "dispatches_per_tick": round(dispatches / max(len(ticks), 1), 2),
        }
        if budget is not None:
            sides[name]["prefill_chunk_tokens"] = delta[
                "prefill_chunk_tokens"]
        if disagg:
            sides[name]["migrations"] = router.stats["migrations"]

    mono, chunk = sides["monolithic"], sides["chunked"]
    out = {
        "arch": cfg.name,
        "mode": "mixed",
        "workload": {"requests": len(workload),
                     "long_frac": args.long_frac,
                     "long_prompt": args.long_prompt,
                     "chat_prompt": [args.prompt_lo, args.prompt_hi]},
        "replicas": replicas,
        "chunk_budget": args.chunk_budget,
        "disagg": args.disagg,
        "variants": sides,
        "p99_tick_speedup": round(
            mono["p99_tick_ms"] / max(chunk["p99_tick_ms"], 1e-9), 2),
        "throughput_ratio": round(
            chunk["useful_tok_per_s"] / max(mono["useful_tok_per_s"], 1e-9),
            2),
        "tokens_identical": all(tokens[n] == tokens["monolithic"]
                                for n in tokens),
        # structured (machine-readable) caveat: downstream tooling keys on
        # ``kind`` and the per-variant ``dispatches_per_tick`` instead of
        # parsing prose
        "note": {
            "kind": "cpu_dispatch_caveat",
            "detail": "each prefill chunk is a separate host dispatch on "
                      "the CPU simulator, so wall throughput under-reports "
                      "chunked prefill (a real engine coalesces the chunk "
                      "with the decode batch)",
            "headline_metric": "p99_tick_ms",
            "affected_metric": "useful_tok_per_s",
        },
    }
    if "chunked_disagg" in sides:
        out["p99_tick_speedup_disagg"] = round(
            mono["p99_tick_ms"]
            / max(sides["chunked_disagg"]["p99_tick_ms"], 1e-9), 2)
    return out


# ------------------------------------------------------------ speculative --

def bench_spec(cfg, params, args, spec_k):
    """Speculative decoding head-to-head (``BENCH_spec.json``): the paged
    scheduler spec-off vs spec-on, ``spec_k`` drafts per stream per verify
    tick from each draft source — n-gram prompt lookup (``spec_ngram``)
    and the incremental-cache draft model (``spec_draft``, self-drafting:
    the target arch drafts for itself, the only checkpoint-free stand-in
    whose accept rate is meaningful on random-init weights) — on the
    staggered long-tail workload, the regime speculation targets.

    Byte identity is the hard gate, checked four ways: each spec variant
    emits spec-off's exact tokens on the full dense workload; at tp=2
    (the grouped verify's sharded path) on a workload slice; and on an
    SSM arch (sequential verify + in-dispatch state rollback) on its own
    slice. The headline is ``tick_speedup`` — spec-off decode dispatches
    over ``spec_draft``'s (>=1.5x gate): on the memory-bound accelerators
    this simulates, a verify of k+1 tokens streams the same weight bytes
    as one decode step, so dispatch count is the hardware-true cost.
    Wall clock is reported per variant but advisory (see ``note``): the
    CPU simulator is forward-compute-bound and a self-draft doubles
    compute per token, where a production draft is ~10x smaller.
    """
    rng = np.random.RandomState(args.seed)
    workload = make_workload(cfg, rng, args.requests, args.prompt_lo,
                             args.prompt_hi, args.gen_lo, args.gen_hi,
                             args.long_frac)
    max_seq = args.prompt_hi + args.gen_hi + 1
    gen_total = sum(g for _, g in workload)

    def build(c=cfg, p=params, k=None, tp=1, draft=False):
        return ContinuousBatchingScheduler(
            c, p, max_slots=args.batch, page_size=args.page_size,
            max_seq_len=max_seq, spec_k=k, tp=tp,
            spec_draft=(c, p) if draft else None)

    def timed(mk, wl):
        sched = mk()
        _timed_pass(sched, wl, args.arrivals_per_step)            # warm
        best = None
        for _ in range(args.repeats):
            res = _timed_pass(sched, wl, args.arrivals_per_step)
            if best is None or res[0] < best[0]:
                best = res
        return best, sched

    sides, tokens = {}, {}
    for name, k, draft in (("spec_off", None, False),
                           ("spec_ngram", spec_k, False),
                           ("spec_draft", spec_k, True)):
        (wall, delta, reqs), sched = timed(
            lambda k=k, d=draft: build(k=k, draft=d), workload)
        tokens[name] = [list(r.out_tokens) for r in reqs]
        lat = [float(r.finish_step - r.arrival_step) for r in reqs]
        sides[name] = {
            "useful_tok_per_s": round(gen_total / wall, 1),
            "wall_s": round(wall, 3),
            "decode_steps": delta["decode_steps"],
            "p50_latency_ticks": percentile(lat, 50),
            "p99_latency_ticks": percentile(lat, 99),
        }
        if k is not None:
            h = sched.h_spec_accept
            sides[name].update({
                "spec_ticks": delta["spec_ticks"],
                "spec_drafted": delta["spec_drafted"],
                "spec_accepted": delta["spec_accepted"],
                "spec_accept_rate": sched.stats["spec_accept_rate"],
                "tokens_per_verify": round(h.sum / max(h.count, 1), 3),
                "p50_verify_emit_tokens": h.quantile(50),
                "p90_verify_emit_tokens": h.quantile(90),
            })

    gates = {
        "tokens_identical": all(tokens[n] == tokens["spec_off"]
                                for n in tokens),
        # the incremental draft cache tracks the committed context: a
        # self-draft that fell out of sync would reject nearly everything
        "draft_accept_high":
            sides["spec_draft"]["spec_accept_rate"] >= 0.75,
    }
    # identity gates on a slice: per-request tokens are schedule-independent
    # for dense/SSM fp32 archs, so a slice gates the same contract cheaply
    gate_wl = workload[:max(4, min(len(workload), 8))]
    _, _, r_b = _timed_pass(build(), gate_wl, args.arrivals_per_step)
    base_toks = [list(r.out_tokens) for r in r_b]
    if cfg.n_kv_heads % 2 == 0:
        _, _, r_t = _timed_pass(build(k=spec_k, tp=2), gate_wl,
                                args.arrivals_per_step)
        gates["tp2_spec_tokens_identical"] = (
            [list(r.out_tokens) for r in r_t] == base_toks)
    # SSM gate: sequential verify scan + PC.select_ssm_steps rollback
    # (n-gram drafts — the draft model is attention-only by construction)
    hcfg = dataclasses.replace(REDUCED["mamba2-1.3b"], dtype="float32")
    hparams = M.init(hcfg, jax.random.PRNGKey(args.seed))
    hrng = np.random.RandomState(args.seed + 1)
    h_wl = make_workload(hcfg, hrng, min(args.requests, 6), args.prompt_lo,
                         min(args.prompt_hi, 24), args.gen_lo,
                         min(args.gen_hi, 16), args.long_frac)
    _, _, r_h0 = _timed_pass(build(c=hcfg, p=hparams), h_wl,
                             args.arrivals_per_step)
    _, _, r_h1 = _timed_pass(build(c=hcfg, p=hparams, k=spec_k), h_wl,
                             args.arrivals_per_step)
    gates["ssm_spec_tokens_identical"] = (
        [list(r.out_tokens) for r in r_h1]
        == [list(r.out_tokens) for r in r_h0])

    tick_speedup = round(
        sides["spec_off"]["decode_steps"]
        / max(sides["spec_draft"]["decode_steps"], 1), 2)
    gates["tick_speedup_ge_1_5"] = tick_speedup >= 1.5
    return {
        "arch": cfg.name,
        "mode": "spec",
        "spec_k": spec_k,
        "workload": {"requests": len(workload),
                     "long_frac": args.long_frac,
                     "gen": [args.gen_lo, args.gen_hi],
                     "arrivals_per_step": args.arrivals_per_step},
        "variants": sides,
        "tick_speedup": tick_speedup,
        "tick_speedup_ngram": round(
            sides["spec_off"]["decode_steps"]
            / max(sides["spec_ngram"]["decode_steps"], 1), 2),
        "wall_speedup_draft": round(
            sides["spec_draft"]["useful_tok_per_s"]
            / max(sides["spec_off"]["useful_tok_per_s"], 1e-9), 2),
        "wall_speedup_ngram": round(
            sides["spec_ngram"]["useful_tok_per_s"]
            / max(sides["spec_off"]["useful_tok_per_s"], 1e-9), 2),
        "gates": gates,
        # structured caveat, same contract as BENCH_chunked's
        # cpu_dispatch_caveat: wall clock on the CPU simulator mismeasures
        # what speculation buys on real hardware, so the headline is the
        # dispatch-count ratio and wall numbers ride along as evidence
        "note": {
            "kind": "cpu_dispatch_caveat",
            "detail": "the CPU simulator is compute-bound per forward, so "
                      "a self-draft (2x compute/token) cannot win wall "
                      "clock here; on memory-bound accelerators a verify "
                      "of k+1 tokens costs ~one decode step of HBM "
                      "traffic and a production draft is ~10x smaller "
                      "than its target, so decode_steps ratio is the "
                      "faithful speedup",
            "headline_metric": "tick_speedup",
            "affected_metric": "useful_tok_per_s",
        },
    }


# --------------------------------------------------------------- prefill --

def _prefill_bytes_model(cfg, workload, budget, fused):
    """Analytic KV bytes the prefill path moves (roofline denominator).

    Per chunk of ``c`` tokens at context position ``pos``:

    * fused — writes ``c`` tokens' K/V straight into their pages and
      streams the ``pos + c`` context tokens once through attention;
    * legacy first chunk — dense prefill writes a contiguous KV which
      ``write_prefill`` then re-reads and re-writes into pages (3x the
      write traffic) plus one attention read of the chunk;
    * legacy later chunks — the batched-rows suffix trick gathers the full
      ``pos + c`` context *per row*: ``c * (pos + c)`` token-reads, the
      quadratic term the fused path removes.

    A scheduler splits its budget FCFS across concurrent prefills, so real
    chunk boundaries can differ from this per-request model; the totals
    (and the legacy/fused asymmetry) are what the roofline compare needs.
    """
    bpt = PC.page_bytes_per_token(cfg)
    read = write = 0
    for prompt, _ in workload:
        plen, pos = len(prompt), 0
        while pos < plen:
            c = plen - pos if budget is None else min(budget, plen - pos)
            if fused:
                write += c * bpt
                read += (pos + c) * bpt
            elif pos == 0:
                write += 3 * c * bpt
                read += c * bpt
            else:
                write += c * bpt
                read += c * (pos + c) * bpt
            pos += c
    total = read + write
    return {"kv_read_bytes": read, "kv_write_bytes": write,
            "kv_total_bytes": total,
            "hbm_roofline_s": round(total / HBM_BW, 6)}


def bench_prefill(cfg, params, args):
    """Prefill-path head-to-head (``BENCH_prefill.json``): monolithic vs
    the legacy chunked path vs fused chunked prefill (direct page writes,
    one dispatch per chunk), at one chunk budget, on the long-prompt mix.

    Byte-identity is the hard gate, checked four ways on a workload slice:
    all timed variants agree; the Pallas write+attend kernel pair agrees
    with the fused XLA lowering; fp8 pools agree kernel-on vs kernel-off
    at the prefill boundary (the matching-dtype contract — docs/kernels.md
    explains why full fp8 rollouts are reported, not gated); and tp=2
    agrees with tp=1. The Pallas variants run interpret-mode on CPU, so
    their walls are correctness artifacts, not throughput — the
    structured note says so.
    """
    rng = np.random.RandomState(args.seed)
    workload = make_mixed_workload(
        cfg, rng, args.requests, args.long_frac, args.long_prompt,
        args.prompt_lo, args.prompt_hi, args.gen_lo, args.gen_hi)
    max_seq = max(args.long_prompt, args.prompt_hi) + args.gen_hi + 1
    gen_total = sum(g for _, g in workload)
    prompt_total = sum(len(p) for p, _ in workload)

    def build(c=cfg, p=params, budget=args.chunk_budget, fused=True,
              kernel=False, tp=1):
        return ContinuousBatchingScheduler(
            c, p, max_slots=args.batch, page_size=args.page_size,
            max_seq_len=max_seq, prefill_budget=budget, prefill_fused=fused,
            prefill_kernel=kernel, tp=tp)

    def timed(mk, wl):
        sched = mk()
        _timed_pass(sched, wl, args.arrivals_per_step)            # warm
        best = None
        for _ in range(args.repeats):
            res = _timed_pass(sched, wl, args.arrivals_per_step)
            if best is None or res[0] < best[0]:
                best = res
        return best, sched

    variants = {
        "monolithic": dict(budget=None),
        "chunked": dict(fused=False),       # the pre-fused (legacy) path
        "chunked_fused": dict(),
    }
    sides, tokens = {}, {}
    for name, kw in variants.items():
        (wall, delta, reqs), sched = timed(lambda kw=kw: build(**kw),
                                           workload)
        tokens[name] = [list(r.out_tokens) for r in reqs]
        sides[name] = {
            "useful_tok_per_s": round(gen_total / wall, 1),
            "prefill_tok_per_s": round(prompt_total / wall, 1),
            "wall_s": round(wall, 3),
            "prefill_dispatches": delta["prefill_dispatches"],
            "prefill_compiles": sched.stats["prefill_compiles"],
            "bytes_model": _prefill_bytes_model(
                cfg, workload, kw.get("budget", args.chunk_budget),
                kw.get("fused", True)),
        }

    # identity gates on a workload slice (per-request tokens are schedule-
    # independent for dense fp32 archs, so a slice gates the same contract)
    # one pass per configuration — identity gates compare tokens, so
    # best-of-repeats buys nothing and the interpret-mode kernel passes
    # are the expensive part of the whole bench
    gate_wl = workload[:max(4, min(len(workload), 8))]
    gate_gen = sum(g for _, g in gate_wl)
    wx, _, rx = _timed_pass(build(), gate_wl, args.arrivals_per_step)
    wk, _, rk = _timed_pass(build(kernel=True), gate_wl,
                            args.arrivals_per_step)
    cfg8 = dataclasses.replace(cfg, cache_quant="fp8")
    w8, _, r8 = _timed_pass(build(c=cfg8), gate_wl, args.arrivals_per_step)
    w8k, _, r8k = _timed_pass(build(c=cfg8, kernel=True), gate_wl,
                              args.arrivals_per_step)
    toks = {k: [list(r.out_tokens) for r in v]
            for k, v in (("xla", rx), ("kernel", rk),
                         ("fp8", r8), ("fp8_kernel", r8k))}
    # fp8 is gated where it is deterministic: the prefill boundary. The
    # attend kernel's online softmax differs from the XLA oracle by ~1 ulp;
    # under fp8's coarse grid that can flip a quantisation boundary in a
    # deeper layer's pool, so a long greedy rollout may diverge at an
    # argmax near-tie. First-token identity + the bitwise write contract
    # (tests/test_paged_prefill.py) are the hard gates; full-rollout
    # agreement is reported as a fraction. See docs/kernels.md.
    fp8_matches = sum(a == b for a, b in zip(toks["fp8"],
                                             toks["fp8_kernel"]))
    gates = {
        "tokens_identical": all(tokens[n] == tokens["monolithic"]
                                for n in tokens),
        "kernel_tokens_identical": toks["kernel"] == toks["xla"],
        "fp8_prefill_tokens_identical": (
            [t[:1] for t in toks["fp8"]]
            == [t[:1] for t in toks["fp8_kernel"]]),
    }
    if cfg.n_kv_heads % 2 == 0:
        wt, _, rt = _timed_pass(build(tp=2), gate_wl,
                                args.arrivals_per_step)
        gates["tp_tokens_identical"] = (
            [list(r.out_tokens) for r in rt] == toks["xla"])

    chunked = sides["chunked"]["useful_tok_per_s"]
    out = {
        "arch": cfg.name,
        "mode": "prefill",
        "workload": {"requests": len(workload),
                     "prompt_tokens": prompt_total,
                     "long_frac": args.long_frac,
                     "long_prompt": args.long_prompt,
                     "chat_prompt": [args.prompt_lo, args.prompt_hi]},
        "chunk_budget": args.chunk_budget,
        "variants": sides,
        "fused_speedup_vs_chunked": round(
            sides["chunked_fused"]["useful_tok_per_s"]
            / max(chunked, 1e-9), 2),
        "fused_speedup_vs_monolithic": round(
            sides["chunked_fused"]["useful_tok_per_s"]
            / max(sides["monolithic"]["useful_tok_per_s"], 1e-9), 2),
        "roofline": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW},
        "gates": gates,
        "kernel_gate": {
            "useful_tok_per_s": round(gate_gen / wk, 1),
            "xla_tok_per_s_same_slice": round(gate_gen / wx, 1),
            "fp8_tok_per_s": round(gate_gen / w8, 1),
            "fp8_kernel_tok_per_s": round(gate_gen / w8k, 1),
            "fp8_rollout_match_frac": round(
                fp8_matches / max(len(gate_wl), 1), 3),
        },
        "note": {
            "kind": "interpret_mode_caveat",
            "detail": "Pallas kernel variants run interpret-mode on CPU; "
                      "their walls gate byte-identity, not throughput — "
                      "the fused-vs-chunked speedup is the XLA lowering of "
                      "the same direct-page-write program structure",
            "headline_metric": "fused_speedup_vs_chunked",
        },
    }
    return out


# -------------------------------------------------------------- sessions --

def make_session_bases(cfg, rng, n, short_lo, short_hi, long_lo, long_hi):
    """Opening prompts for ``n`` interactive sessions: alternating short
    chats and document-grounded sessions. The long sessions' chains are
    what the cost model swaps to host RAM; the short ones are what it
    re-prefills — the workload needs both sides of the crossover."""
    out = []
    for i in range(n):
        lo, hi = (long_lo, long_hi) if i % 2 else (short_lo, short_hi)
        plen = int(rng.randint(lo, hi + 1))
        out.append(rng.randint(0, cfg.vocab_size, size=plen
                               ).astype(np.int32))
    return out


def run_sessions(sched, bases, turns, gen, new_lo, new_hi, gap, seed):
    """Drive ``turns`` rounds of multi-turn sessions: each round submits
    every session's running transcript plus fresh user tokens (staggered
    arrivals), drains the scheduler, then appends the assistant reply to
    the transcript — the drain is the idle gap every session shares
    between turns. The extension draws come from ``seed`` alone, so two
    runs diverge only if their output tokens do (the identity gate
    cascades through every turn). Returns (wall, per-session per-turn
    tokens, stats delta)."""
    rng = np.random.RandomState(seed)
    prompts = [np.asarray(b, dtype=np.int32) for b in bases]
    history = [[] for _ in bases]
    before = dict(sched.stats)
    t0 = time.time()
    for t in range(turns):
        base = sched.step_idx + (gap if t else 0)
        reqs = [sched.submit(p, gen, arrival_step=base + i // 4)
                for i, p in enumerate(prompts)]
        sched.run()
        for i, r in enumerate(reqs):
            history[i].append(list(r.out_tokens))
            ext = rng.randint(0, sched.cfg.vocab_size,
                              size=int(rng.randint(new_lo, new_hi + 1))
                              ).astype(np.int32)
            prompts[i] = np.concatenate(
                [prompts[i], np.asarray(r.out_tokens, np.int32), ext])
    wall = time.time() - t0
    delta = {k: sched.stats[k] - before[k] for k in before}
    return wall, history, delta


def bench_sessions(cfg, params, args):
    """Host-RAM KV tier head-to-head (``BENCH_kv_tier.json``); see the
    module docstring's kv-tier paragraph for the contract being gated."""
    rng = np.random.RandomState(args.seed)
    n_sessions = 3 * args.batch
    gen = max(args.gen_lo, 4)
    new_lo, new_hi = 4, 8
    short_lo, short_hi = max(args.prompt_lo, 4), max(2 * args.prompt_lo, 8)
    long_lo, long_hi = 3 * args.long_prompt // 4, args.long_prompt
    bases = make_session_bases(cfg, rng, n_sessions, short_lo, short_hi,
                               long_lo, long_hi)
    # crossover sits between the longest short-session chain and the
    # shortest long-session chain, so the cost model demonstrably picks
    # both paths: short chains re-prefill, long chains swap
    short_final = short_hi + args.turns * (gen + new_hi)
    crossover = (short_final + long_lo + gen) // 2
    max_seq = long_hi + args.turns * (gen + new_hi) + 1
    n_pg = -(-max_seq // args.page_size)
    # the HBM pool is sized for the *live* slots only (the scheduler's
    # default) and is identical tier-on vs tier-off — retained session
    # chains exceed it by construction, that is the pressure under test
    num_pages = args.batch * n_pg + 1
    host_pages = n_sessions * n_pg

    def run_variant(c, host):
        kw = dict(max_slots=args.batch, page_size=args.page_size,
                  num_pages=num_pages, max_seq_len=max_seq,
                  prefix_cache=True)
        if host:
            kw.update(host_pages=host_pages, swap_crossover=crossover)
        sched = ContinuousBatchingScheduler(c, params, **kw)
        wall, hist, delta = run_sessions(
            sched, bases, args.turns, gen, new_lo, new_hi,
            gap=8, seed=args.seed + 1)
        return sched, wall, hist, delta

    sides, toks, completed = {}, {}, {}
    for prec in ("fp32", "int8"):
        c = cfg if prec == "fp32" else dataclasses.replace(
            cfg, cache_quant="int8")
        for mode, host in (("tier_off", False), ("tier_on", True)):
            sched, wall, hist, delta = run_variant(c, host)
            key = f"{prec}_{mode}"
            toks[key] = hist
            completed[key] = all(
                len(h) == args.turns and all(len(t) == gen for t in h)
                for h in hist)
            gen_total = sum(len(t) for h in hist for t in h)
            side = {
                "wall_s": round(wall, 3),
                "useful_tok_per_s": round(gen_total / wall, 1),
                "num_pages": sched.alloc.num_pages,
                "peak_pages": sched.stats["peak_pages"],
                "prefills": delta["prefills"],
                "prefix_hits": delta["prefix_hits"],
                "cached_tokens": delta["cached_tokens"],
                "admit_blocked": delta["admit_blocked"],
            }
            if host:
                h = sched.h_resume
                side.update({
                    "swap_outs": delta["swap_outs"],
                    "swap_out_pages": delta["swap_out_pages"],
                    "swap_ins": delta["swap_ins"],
                    "swap_in_pages": delta["swap_in_pages"],
                    "swap_reprefills": delta["swap_reprefills"],
                    "host_evictions": delta["host_evictions"],
                    "host_pages_used": sched.stats["host_pages_used"],
                    "retained_pages": sched.stats["retained_pages"],
                    "resumes": h.count,
                    "p50_resume_ticks": h.quantile(50),
                    "p99_resume_ticks": h.quantile(99),
                })
            sides[key] = side

    on = sides["fp32_tier_on"]
    gates = {
        # 3x max_concurrent_seqs open sessions, every turn fully served,
        # on a pool both variants share unchanged
        "sessions_3x_slots": (n_sessions >= 3 * args.batch
                              and all(completed.values())),
        "hbm_pool_unchanged": all(
            s["num_pages"] == num_pages for s in sides.values()),
        "tokens_identical_fp32":
            toks["fp32_tier_on"] == toks["fp32_tier_off"],
        "tokens_identical_int8":
            toks["int8_tier_on"] == toks["int8_tier_off"],
        # the cost model must demonstrably pick both resume paths
        "swap_ins_nonzero": on["swap_ins"] > 0,
        "swap_reprefills_nonzero": on["swap_reprefills"] > 0,
        # bounded resume latency: swap-in resumes were recorded and their
        # p99 stays within a few admission waves of the arrival tick
        "resume_p99_bounded": (on["resumes"] > 0
                               and on["p99_resume_ticks"] <= 64),
    }
    return {
        "arch": cfg.name,
        "mode": "sessions",
        "workload": {
            "sessions": n_sessions,
            "turns": args.turns,
            "gen_per_turn": gen,
            "short_prompt": [short_lo, short_hi],
            "long_prompt": [long_lo, long_hi],
            "new_tokens_per_turn": [new_lo, new_hi],
        },
        "batch_width": args.batch,
        "num_pages": num_pages,
        "host_pages": host_pages,
        "swap_crossover_tokens": crossover,
        "cost_model_crossover_tokens": PC.swap_crossover_tokens(
            cfg, args.page_size),
        "variants": sides,
        "throughput_ratio": round(
            sides["fp32_tier_on"]["useful_tok_per_s"]
            / max(sides["fp32_tier_off"]["useful_tok_per_s"], 1e-9), 2),
        "gates": gates,
        # the REDUCED dims put the analytic crossover out of range (toy
        # prefills are cheaper than any PCIe transfer), so the bench pins
        # an explicit crossover mid-workload; at full-model dims the
        # roofline constants drive the decision (docs/serving.md)
        "note": {
            "kind": "reduced_dims_caveat",
            "detail": "swap_crossover_tokens(cfg) is degenerate at "
                      "REDUCED dims — recompute wins at any length — so "
                      "the bench pins the crossover between the short and "
                      "long session populations to exercise both paths",
            "headline_metric": "gates",
        },
    }


# ----------------------------------------------------------------- fleet --

def run_fleet(router, workload, arrivals_per_step):
    """One pass of the trace through the fabric; returns (stats delta over
    the pass, this pass's finished requests for latency percentiles)."""
    base = router.step_idx
    reqs = []
    for i, (prompt, gen) in enumerate(workload):
        arrival = base + (i // arrivals_per_step if arrivals_per_step else 0)
        reqs.append(router.submit(prompt, gen, arrival_step=arrival))
    before = router.fleet_stats()
    router.run()
    after = router.fleet_stats()
    delta = {k: after[k] - before[k]
             for k in ("tokens_out", "decode_steps", "prefills", "routed",
                       "spillovers")}
    return delta, reqs


def bench_fleet(cfg, params, workload, k, args):
    """Fleet at width k: batch budget split as k replicas of batch/k slots
    (matching serving_page_plan's per-replica split semantics)."""
    slots = max(args.batch // k, 1)
    max_seq = args.prompt_hi + args.gen_hi + 1
    router = ServingRouter(cfg, params, replicas=k, max_slots=slots,
                           page_size=args.page_size, max_seq_len=max_seq)
    run_fleet(router, workload, args.arrivals_per_step)        # warm
    t_best, delta, reqs = None, None, None
    for _ in range(args.repeats):
        t0 = time.time()
        delta, reqs = run_fleet(router, workload, args.arrivals_per_step)
        t = time.time() - t0
        t_best = t if t_best is None else min(t_best, t)
    lat = [float(r.finish_step - r.arrival_step) for r in reqs]
    out = {
        "replicas": k,
        "slots_per_replica": slots,
        "fleet_tok_per_s": round(delta["tokens_out"] / t_best, 1),
        "wall_s": round(t_best, 2),
        "p50_latency_ticks": percentile(lat, 50),
        "p99_latency_ticks": percentile(lat, 99),
        "spillovers": delta["spillovers"],
    }
    imb = router.imbalance()
    if imb is not None:
        out["reserved_page_imbalance"] = round(imb, 3)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b", choices=sorted(REDUCED))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8,
                    help="static batch width == paged decode slots")
    ap.add_argument("--prompt-lo", type=int, default=8)
    ap.add_argument("--prompt-hi", type=int, default=48)
    ap.add_argument("--gen-lo", type=int, default=4)
    ap.add_argument("--gen-hi", type=int, default=64)
    ap.add_argument("--long-frac", type=float, default=0.25,
                    help="fraction of requests generating gen-hi tokens")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--wide", type=int, default=4,
                    help="width multiplier over the REDUCED config")
    ap.add_argument("--deep", type=int, default=2,
                    help="depth multiplier over the REDUCED config")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per engine; min wall is reported")
    ap.add_argument("--arrivals-per-step", type=int, default=0,
                    help="requests becoming due per tick; 0 = all at once "
                    "(matching the static baseline, which batches the whole "
                    "workload upfront)")
    ap.add_argument("--replicas", default=None,
                    help="fleet mode: comma-separated fleet widths (e.g. "
                    "1,2,4) served through the fabric router instead of "
                    "the static-vs-paged head-to-head")
    ap.add_argument("--tp", default=None,
                    help="shard-group mode: comma-separated tensor-parallel "
                    "widths (e.g. 1,2,4); each width serves the same trace "
                    "fp32 with page pools and heads split tp ways, and "
                    "byte-identity vs the first width is a hard gate")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-workload mode: long-prompt + chat trace "
                    "served monolithic vs chunked (vs chunked+disagg with "
                    "--disagg) through the fabric; decode-tick p50/p99 "
                    "wall latency and a byte-identity hard gate")
    ap.add_argument("--prefill", action="store_true",
                    help="prefill mode: monolithic vs legacy-chunked vs "
                    "fused-chunked (direct page writes) on the long-prompt "
                    "mix, with Pallas-kernel / fp8 / tp=2 byte-identity "
                    "hard gates and an analytic bytes-vs-roofline model "
                    "(writes BENCH_prefill.json via --out)")
    ap.add_argument("--spec", type=int, nargs="?", const=4, default=None,
                    metavar="K",
                    help="speculative-decoding mode: paged scheduler "
                    "spec-off vs spec-on (K n-gram draft tokens per stream "
                    "per verify tick, default 4) on the staggered long-tail "
                    "trace, with byte-identity hard gates (dense, tp=2, "
                    "SSM) and the >=1.5x useful tok/s target (writes "
                    "BENCH_spec.json via --out); defaults "
                    "--arrivals-per-step to 1 when unset")
    ap.add_argument("--sessions", action="store_true",
                    help="kv-tier mode: 3x --batch interactive multi-turn "
                    "sessions served tier-on vs tier-off on the same HBM "
                    "pool; byte-identity (fp32 AND int8), both cost-model "
                    "resume paths, and bounded resume latency are hard "
                    "gates (writes BENCH_kv_tier.json via --out)")
    ap.add_argument("--turns", type=int, default=3,
                    help="sessions mode: conversation turns per session")
    ap.add_argument("--chunk-budget", type=int, default=16,
                    help="mixed mode: prefill tokens a tick may land "
                    "(the chunked variants' per-tick budget)")
    ap.add_argument("--long-prompt", type=int, default=224,
                    help="mixed mode: document prompt length (the long "
                    "side of the mix; chat prompts use --prompt-lo/hi)")
    ap.add_argument("--disagg", type=int, nargs="?", const=1, default=0,
                    metavar="K",
                    help="mixed mode: add a chunked+disaggregated variant "
                    "with K prefill-role replicas (fleet is K+1 wide "
                    "for every variant so the hardware matches)")
    ap.add_argument("--out", default=None,
                    help="also write the report JSON to this path")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a request-lifecycle trace (Chrome "
                    "trace-event JSON) from one extra pass run after the "
                    "timed passes; the bench fails if the artifact is "
                    "empty or unparsable")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="export the metric registries (Prometheus text) "
                    "from the same extra pass; fails if empty")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix mode: persona workload served by "
                    "the paged scheduler with the copy-on-write prefix "
                    "cache on vs off (throughput, page savings, and a "
                    "byte-identity check); generations draw from "
                    "[gen-lo, 2*gen-lo] (--gen-hi is the head-to-head's "
                    "long-tail knob and is not used here)")
    ap.add_argument("--personas", type=int, default=4,
                    help="shared-prefix mode: distinct system prompts")
    ap.add_argument("--users-per-persona", type=int, default=8,
                    help="shared-prefix mode: requests per persona")
    ap.add_argument("--persona-len", type=int, default=96,
                    help="shared-prefix mode: tokens per persona prompt")
    ap.add_argument("--user-len", type=int, default=16,
                    help="shared-prefix mode: max tokens per user suffix")
    ap.add_argument("--seed", type=int, default=0,
                    help="drives parameter init AND workload generation")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI: exercises both engines "
                    "end-to-end, ignores the speedup number")
    args = ap.parse_args()

    modes = [flag for flag, on in (("--tp", args.tp),
                                   ("--shared-prefix", args.shared_prefix),
                                   ("--mixed", args.mixed),
                                   ("--prefill", args.prefill),
                                   ("--spec", args.spec is not None),
                                   ("--sessions", args.sessions),
                                   ("--replicas", args.replicas)) if on]
    if len(modes) > 1:
        ap.error("bench modes are mutually exclusive; got "
                 + " and ".join(modes))
    if args.disagg and not args.mixed:
        ap.error("--disagg is a --mixed variant")

    if args.smoke:
        args.requests, args.repeats, args.wide, args.deep = 8, 1, 1, 1
        if args.shared_prefix:
            args.personas, args.users_per_persona = 2, 4
            args.persona_len, args.user_len = 32, 8
        if args.mixed:
            args.long_prompt, args.chunk_budget = 48, 8
        if args.prefill:
            args.requests, args.long_prompt, args.chunk_budget = 6, 48, 8
        if args.spec is not None:
            args.gen_hi = min(args.gen_hi, 24)
        if args.sessions:
            args.batch, args.turns, args.long_prompt = 4, 2, 64

    cfg = bench_cfg(args.arch, args.wide, args.deep)
    params = M.init(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)

    # ---- shard-group mode: tensor-parallel widths + byte-identity gate ----
    if args.tp:
        widths = [int(k) for k in str(args.tp).split(",")]
        bad = [k for k in widths if k > 1 and cfg.n_kv_heads % k]
        if bad:
            raise SystemExit(
                f"--tp {bad} does not divide n_kv_heads={cfg.n_kv_heads} "
                f"at --wide {args.wide}; widen the config")
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        out = bench_tp(cfg, params, args, widths)
        obs = export_obs_artifacts(
            args,
            lambda: ContinuousBatchingScheduler(
                cfg, params, max_slots=args.batch,
                page_size=args.page_size,
                max_seq_len=args.prompt_hi + args.gen_hi + 1,
                tp=widths[-1]),
            make_workload(cfg, np.random.RandomState(args.seed),
                          args.requests, args.prompt_lo, args.prompt_hi,
                          args.gen_lo, args.gen_hi, args.long_frac))
        if obs:
            out["obs_artifacts"] = obs
        bad = write_report(args, out, "shard-group",
                           {"tokens_identical": out["tokens_identical"]})
        if bad:
            raise SystemExit("shard-group serving changed output tokens "
                             "— tp determinism contract broken (see "
                             "docs/sharding.md)")
        return

    # ---- spec mode: draft-and-verify vs plain decode ----------------------
    if args.spec is not None:
        if REDUCED[args.arch].n_routed_experts:
            raise SystemExit("--spec covers dense/SSM archs; MoE capacity "
                             "grouping breaks the byte-determinism contract "
                             "speculation relies on (docs/serving.md)")
        # fp32 for the byte-identity hard gates, same contract as the
        # shared-prefix / mixed / shard-group gates
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        if args.arrivals_per_step == 0:
            # all-at-once arrivals let spec-off amortise through big fused
            # scans; the staggered trace is the regime speculation targets
            args.arrivals_per_step = 1
        out = bench_spec(cfg, params, args, args.spec)
        gates = out.pop("gates")
        gates["spec_ticks_nonzero"] = all(
            out["variants"][v]["spec_ticks"] > 0
            for v in ("spec_ngram", "spec_draft"))
        bad = write_report(args, out, "spec", gates)
        if bad:
            raise SystemExit("speculative byte-identity gate(s) failed: "
                             + ", ".join(bad) + " — greedy accept/rollback "
                             "broke determinism (see docs/serving.md)")
        if not args.smoke and out["tick_speedup"] < 1.5:
            import sys
            print("warning: speculative decoding below the >=1.5x useful "
                  "tok/s target on this run — CPU timing is noisy; try "
                  "more --repeats or longer --gen-hi generations",
                  file=sys.stderr)
        return

    # ---- sessions mode: host-RAM KV tier on vs off ------------------------
    if args.sessions:
        if REDUCED[args.arch].n_routed_experts:
            raise SystemExit("--sessions covers dense/SSM archs; a MoE "
                             "prefix-resume regroups expert capacity vs "
                             "the full prefill, breaking the tier's "
                             "byte-identity contract (docs/serving.md)")
        # fp32 for the tier-on/off byte-identity gates (the int8 side
        # quantises *pools* over fp32 compute, so identity holds there too)
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        out = bench_sessions(cfg, params, args)
        bad = write_report(args, out, "kv-tier", out.pop("gates"))
        if bad:
            raise SystemExit("kv-tier gate(s) failed: " + ", ".join(bad)
                             + " — host-tier byte-identity / cost-model "
                             "contract broken (see docs/serving.md)")
        return

    # ---- prefill mode: monolithic vs legacy-chunked vs fused-chunked ------
    if args.prefill:
        if REDUCED[args.arch].n_routed_experts or any(
                REDUCED[args.arch].block_kind(i) == "ssm"
                for i in range(REDUCED[args.arch].n_layers)):
            raise SystemExit("--prefill benches the fused dense-arch path; "
                             "MoE/SSM archs keep exact sequential prefill")
        # fp32 for the four byte-identity hard gates, same contract as the
        # mixed / shared-prefix / shard-group gates
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        out = bench_prefill(cfg, params, args)
        gates = out.pop("gates")
        gates["prefill_dispatches_nonzero"] = (
            out["variants"]["chunked_fused"]["prefill_dispatches"] > 0)
        bad = write_report(args, out, "prefill", gates)
        if bad:
            raise SystemExit("prefill byte-identity gate(s) failed: "
                             + ", ".join(bad) + " — determinism contract "
                             "broken (see docs/kernels.md)")
        if not args.smoke and out["fused_speedup_vs_chunked"] < 1.5:
            import sys
            print("warning: fused chunked prefill below the >=1.5x target "
                  "vs the legacy chunked path on this run — CPU timing is "
                  "noisy; try more --repeats or a longer --long-prompt",
                  file=sys.stderr)
        return

    # ---- mixed mode: monolithic vs chunked vs disaggregated ---------------
    if args.mixed:
        # fp32 for the cross-variant byte-identity gate — same contract as
        # the shared-prefix and shard-group gates; a chunked continuation
        # reuses the suffix paths those gates already pin down
        cfg = dataclasses.replace(cfg, dtype="float32")
        if cfg.n_routed_experts:
            cfg = dataclasses.replace(
                cfg, moe_capacity_factor=float(cfg.n_routed_experts)
                / cfg.moe_top_k)
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        out = bench_mixed(cfg, params, args)
        # the traced pass reuses the most featureful variant's fabric so
        # the exported trace shows chunks (and migrations under --disagg)
        obs = export_obs_artifacts(
            args,
            lambda: ServingRouter(
                cfg, params,
                replicas=(args.disagg + 1) if args.disagg else 2,
                max_slots=args.batch, page_size=args.page_size,
                max_seq_len=(max(args.long_prompt, args.prompt_hi)
                             + args.gen_hi + 1),
                prefill_budget=args.chunk_budget, disagg=args.disagg),
            make_mixed_workload(cfg, np.random.RandomState(args.seed),
                                args.requests, args.long_frac,
                                args.long_prompt, args.prompt_lo,
                                args.prompt_hi, args.gen_lo, args.gen_hi))
        if obs:
            out["obs_artifacts"] = obs
        bad = write_report(args, out, "mixed",
                           {"tokens_identical": out["tokens_identical"]})
        if bad:
            raise SystemExit("chunked/disaggregated serving changed output "
                             "tokens — determinism contract broken (see "
                             "docs/serving.md)")
        if not args.smoke and (out["p99_tick_speedup"] < 1.0
                               or out["throughput_ratio"] < 0.95):
            import sys
            print("warning: chunked prefill did not tighten the decode-tick "
                  "p99 at equal throughput on this run — CPU timing is "
                  "noisy; try more --repeats or a longer --long-prompt",
                  file=sys.stderr)
        return

    # ---- shared-prefix mode: COW prefix cache on vs off -------------------
    if args.shared_prefix:
        # fp32: the byte-identity gate below compares the shared run's
        # tokens against no-sharing; exact argmax equality across the two
        # compiled paths is an fp32 property (bf16 reassociation drift can
        # flip near-tie argmaxes — same caveat as the fabric's re-prefill)
        cfg = dataclasses.replace(cfg, dtype="float32")
        if cfg.n_routed_experts:
            # MoE archs are prefix_cache-off by default because a cached
            # suffix regroups expert capacity vs the full prefill; the
            # bench opts in, so capacity must be non-binding or the
            # identity gate would flag that documented caveat as a bug
            cfg = dataclasses.replace(
                cfg, moe_capacity_factor=float(cfg.n_routed_experts)
                / cfg.moe_top_k)
        params = M.init(cfg, jax.random.PRNGKey(args.seed))
        out = bench_shared_prefix(cfg, params, args)
        user_hi = max(args.user_len, 2)
        g_lo = max(args.gen_lo, 1)
        obs = export_obs_artifacts(
            args,
            lambda: ContinuousBatchingScheduler(
                cfg, params, max_slots=args.batch,
                page_size=args.page_size,
                max_seq_len=args.persona_len + user_hi + 2 * g_lo + 1,
                prefix_cache=True),
            persona_workload(cfg.vocab_size,
                             np.random.RandomState(args.seed),
                             args.personas, args.users_per_persona,
                             args.persona_len, max(user_hi // 2, 1),
                             user_hi, g_lo, 2 * g_lo))
        if obs:
            out["obs_artifacts"] = obs
        bad = write_report(args, out, "shared-prefix",
                           {"tokens_identical": out["tokens_identical"]})
        if bad:
            raise SystemExit("shared-prefix serving changed output tokens "
                             "— COW/prefix-cache correctness bug")
        if not args.smoke and (out["throughput_ratio"] < 1.5
                               or out["page_savings_frac"] < 0.4):
            import sys
            print("warning: shared-prefix run below the >=1.5x throughput / "
                  ">=40% page-savings target — CPU timing is noisy; try "
                  "more --repeats or a longer --persona-len",
                  file=sys.stderr)
        return
    workload = make_workload(cfg, rng, args.requests, args.prompt_lo,
                             args.prompt_hi, args.gen_lo, args.gen_hi,
                             args.long_frac)
    max_seq = args.prompt_hi + args.gen_hi + 1

    # ---- fleet mode: fabric at each requested width -----------------------
    if args.replicas:
        widths = [int(k) for k in str(args.replicas).split(",")]
        out = {"arch": cfg.name, "requests": args.requests,
               "batch_budget": args.batch, "mode": "fleet",
               "fleet": [bench_fleet(cfg, params, workload, k, args)
                         for k in widths]}
        obs = export_obs_artifacts(
            args,
            lambda: ServingRouter(
                cfg, params, replicas=widths[-1],
                max_slots=max(args.batch // widths[-1], 1),
                page_size=args.page_size, max_seq_len=max_seq),
            workload)
        if obs:
            out["obs_artifacts"] = obs
        write_report(args, out, "fleet", {})
        return

    # ---- static engine: warm, then time -----------------------------------
    run_static(cfg, params, workload, args.batch)
    t_static, useful = None, 0
    for _ in range(args.repeats):
        t0 = time.time()
        useful = run_static(cfg, params, workload, args.batch)
        t = time.time() - t0
        t_static = t if t_static is None else min(t_static, t)

    # ---- continuous batching: warm, then time ------------------------------
    sched = ContinuousBatchingScheduler(
        cfg, params, max_slots=args.batch, page_size=args.page_size,
        max_seq_len=max_seq)
    run_paged(sched, workload, args.arrivals_per_step)
    t_paged, delta = None, None
    for _ in range(args.repeats):
        t0 = time.time()
        delta = run_paged(sched, workload, args.arrivals_per_step)
        t = time.time() - t0
        t_paged = t if t_paged is None else min(t_paged, t)

    dense_bytes = PC.dense_cache_bytes(cfg, args.batch, max_seq)
    paged_bytes = PC.pool_bytes(cfg, sched.stats["peak_pages"] + 1,
                                args.page_size)
    out = {
        "arch": cfg.name,
        "requests": args.requests,
        "batch_width": args.batch,
        "static": {
            "useful_tok_per_s": round(useful / t_static, 1),
            "wall_s": round(t_static, 2),
        },
        "paged": {
            "useful_tok_per_s": round(delta["tokens_out"] / t_paged, 1),
            "wall_s": round(t_paged, 2),
            "decode_steps": delta["decode_steps"],
            "occupancy": round(
                (delta["tokens_out"] - delta["prefills"])
                / max(delta["decode_steps"] * args.batch, 1), 3),
        },
        "speedup": round((delta["tokens_out"] / t_paged)
                         / (useful / t_static), 2),
        "cache_bytes": {"static_ring": dense_bytes,
                        "paged_peak": paged_bytes,
                        "ratio": round(dense_bytes / max(paged_bytes, 1), 2)},
    }
    obs = export_obs_artifacts(
        args,
        lambda: ContinuousBatchingScheduler(
            cfg, params, max_slots=args.batch, page_size=args.page_size,
            max_seq_len=max_seq),
        workload)
    if obs:
        out["obs_artifacts"] = obs
    write_report(args, out, "paged-vs-static", {})
    if out["speedup"] <= 1.0:
        import sys
        print("warning: continuous batching did not beat the static engine "
              "on this run — CPU timing is noisy; try more --repeats",
              file=sys.stderr)


if __name__ == "__main__":
    main()
