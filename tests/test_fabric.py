"""Replicated serving fabric: router determinism and byte-identity vs the
single scheduler (including forced replica preemption with prefix
re-prefill), least-pages routing with spill-over, drain/remove lifecycle,
heartbeat wiring, per-replica page-plan splits, and the shared Request
lifecycle through the static engine."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, REDUCED
from repro.core.blueprint import serving_page_plan
from repro.core.heartbeat import HeartbeatMonitor
from repro.models import model as M
from repro.serving import engine as E
from repro.serving.request import (Request, RequestState, make_request,
                                   worst_case_pages)
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler

CFG = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


def _trace(rng, lens, gens):
    prompts = [rng.randint(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in lens]
    return list(zip(prompts, gens))


def _reference_tokens(cfg, params, trace, max_seq=64):
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=max_seq)
    reqs = [s.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(trace)]
    s.run()
    return [r.out_tokens for r in reqs]


# ------------------------------------------------------- request lifecycle --

def test_request_states_and_validation():
    r = make_request(0, [1, 2, 3], 4, arrival_step=2)
    assert r.state is RequestState.WAITING and r.plen == 3
    assert r.remaining_tokens == 4
    assert worst_case_pages(r, 4) == 2          # ceil((3+4)/4)
    r.admit_step = 3
    r.out_tokens.append(11)
    assert r.state is RequestState.ACTIVE and r.remaining_tokens == 3
    r.out_tokens.extend([12, 13, 14])
    assert r.done and r.state is RequestState.FINISHED
    with pytest.raises(ValueError, match="max_new_tokens"):
        make_request(1, [1], 0)


def test_static_engine_shared_request_lifecycle(params):
    """serve_requests fills the same bookkeeping the scheduler does, on a
    serial group clock (group n+1 admits after group n's longest)."""
    rng = np.random.RandomState(0)
    reqs = [make_request(i, rng.randint(0, CFG.vocab_size, size=5), g)
            for i, g in enumerate([4, 7, 3, 5])]
    E.serve_requests(CFG, params, reqs, batch_width=2)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    # group 0 = reqs[0:2] admits at 0, decodes max(4,7)=7 ticks
    assert reqs[0].admit_step == 0 and reqs[1].admit_step == 0
    assert reqs[0].finish_step == 4 and reqs[1].finish_step == 7
    assert reqs[2].admit_step == 7          # head-of-line blocked by group 0
    assert reqs[3].finish_step == 12


# ------------------------------------------------------ fleet token parity --

def test_fleet_tokens_identical_to_single_scheduler(params):
    """Acceptance: fixed seed, dense arch — the k-replica fabric emits
    byte-identical tokens per request vs the single-replica scheduler."""
    rng = np.random.RandomState(0)
    trace = _trace(rng, (5, 9, 7, 11, 6, 8), (6, 8, 5, 7, 4, 9))
    want = _reference_tokens(CFG, params, trace)

    router = ServingRouter(CFG, params, replicas=2, max_slots=1,
                           page_size=8, max_seq_len=64)
    reqs = [router.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(trace)]
    done = router.run()
    assert len(done) == len(trace)
    assert [r.out_tokens for r in reqs] == want
    # both replicas actually served traffic
    stats = router.fleet_stats()["per_replica"]
    assert all(s["prefills"] > 0 for s in stats.values())
    # fleet-clock latency bookkeeping is filled in
    assert all(r.finish_step is not None and
               r.finish_step >= r.arrival_step for r in reqs)


def test_fleet_tokens_identical_after_preemption(params):
    """Acceptance: one forced replica preemption mid-run; the lost streams
    re-prefill (prompt + emitted tokens) on survivors, token-identical."""
    rng = np.random.RandomState(1)
    trace = _trace(rng, (5, 9, 7, 11), (12, 14, 10, 13))
    want = _reference_tokens(CFG, params, trace)

    router = ServingRouter(CFG, params, replicas=2, max_slots=1,
                           page_size=8, max_seq_len=64)
    reqs = [router.submit(p, g) for p, g in trace]
    for _ in range(5):
        router.step(max_fuse=1)             # force mid-flight state
    victim = max(router.replicas)
    assert router.replicas[victim].num_unfinished > 0
    rerouted = router.fail_replica(victim)
    assert rerouted and router.stats["reroutes"] == len(rerouted)
    router.add_replica()                    # replacement capacity
    router.run(max_fuse=1)
    assert [r.out_tokens for r in reqs] == want
    assert any(r.reroutes > 0 for r in reqs)
    for rep in router.replicas.values():    # allocator hygiene fleet-wide
        assert rep.sched.alloc.num_allocated == 0
        assert rep.sched.reserved_pages == 0


def test_fleet_tokens_identical_after_preemption_ssm_hybrid():
    """Same preemption re-route property through the SSM dense-state path
    (jamba hybrid): a re-prefilled prefix folds the SSM state exactly.

    Expert capacity is set non-binding (capacity_factor = E / top_k): a
    re-prefill groups its tokens differently than the original prefill +
    decode ticks did, and with a binding capacity MoE legitimately drops
    different tokens per grouping — the documented MoE caveat, not the
    SSM property under test. (This was latent until parameter init became
    process-deterministic; the old builtin-hash path-seeding made the test
    a per-process parameter lottery.)"""
    cfg = dataclasses.replace(
        REDUCED["jamba-v0.1-52b"], dtype="float32",
        moe_capacity_factor=float(REDUCED["jamba-v0.1-52b"].n_routed_experts)
        / REDUCED["jamba-v0.1-52b"].moe_top_k)
    p = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 6, 5)]
    gens = [8, 9, 7]
    trace = list(zip(prompts, gens))
    want = _reference_tokens(cfg, p, trace, max_seq=32)

    router = ServingRouter(cfg, p, replicas=2, max_slots=1, page_size=8,
                           max_seq_len=32)
    reqs = [router.submit(pr, g) for pr, g in trace]
    for _ in range(3):
        router.step(max_fuse=1)
    victim = max(router.replicas)
    assert router.replicas[victim].num_unfinished > 0
    router.fail_replica(victim)
    router.run(max_fuse=1)
    assert [r.out_tokens for r in reqs] == want


# ---------------------------------------------------------------- routing --

def test_least_pages_routing_deterministic_tiebreak(params):
    router = ServingRouter(CFG, params, replicas=3, max_slots=2,
                           page_size=8, max_seq_len=64)
    rng = np.random.RandomState(3)
    r1 = router.submit(rng.randint(0, CFG.vocab_size, size=8), 8)
    router.route_due()
    assert r1.replica == 0                  # all-equal load -> lowest id
    r2 = router.submit(rng.randint(0, CFG.vocab_size, size=8), 8)
    router.route_due()
    assert r2.replica == 1                  # replica 0 now holds reserved
    r3 = router.submit(rng.randint(0, CFG.vocab_size, size=20), 8)
    router.route_due()
    assert r3.replica == 2


def test_round_robin_routing(params):
    router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                           page_size=8, max_seq_len=64,
                           route_policy="round-robin")
    rng = np.random.RandomState(4)
    reqs = [router.submit(rng.randint(0, CFG.vocab_size, size=4), 2)
            for _ in range(4)]
    router.route_due()
    assert [r.replica for r in reqs] == [0, 1, 0, 1]


def test_admission_spillover_to_larger_pool(params):
    """The least-loaded replica's pool can never hold the request: it must
    spill to the next candidate rather than queue unservable work."""
    router = ServingRouter(CFG, params, replicas=1, max_slots=2,
                           page_size=8, num_pages=4, max_seq_len=64)
    router.add_replica(num_pages=17)        # heterogeneous fleet member
    rng = np.random.RandomState(5)
    big = router.submit(rng.randint(0, CFG.vocab_size, size=40), 16)
    router.route_due()
    assert big.replica == 1 and router.stats["spillovers"] == 1
    # a request no fleet member could ever hold still fails at submit
    with pytest.raises(ValueError, match="no replica"):
        router.submit(rng.randint(0, CFG.vocab_size, size=40), 30)
    router.run()
    assert len(big.out_tokens) == 16


def test_reserved_page_imbalance_under_25_percent(params):
    """Acceptance: least-pages routing keeps steady-state reserved-page
    imbalance across replicas <= 25% on a mixed-length trace."""
    rng = np.random.RandomState(6)
    router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                           page_size=8, max_seq_len=64)
    for i in range(16):
        plen = int(rng.randint(4, 17))
        gen = int(rng.randint(6, 15))
        router.submit(rng.randint(0, CFG.vocab_size, size=plen), gen,
                      arrival_step=i // 2)
    router.run(max_fuse=1)
    imb = router.imbalance()
    assert imb is not None, "fleet never reached a 2-busy-replica steady state"
    assert imb <= 0.25, f"steady-state imbalance {imb:.3f} > 25%"


# ------------------------------------------------------ lifecycle + nodes --

def test_drain_then_remove_and_busy_remove_rejected(params):
    rng = np.random.RandomState(7)
    router = ServingRouter(CFG, params, replicas=2, max_slots=1,
                           page_size=8, max_seq_len=64,
                           placement=["slave-0", "slave-1"])
    reqs = [router.submit(rng.randint(0, CFG.vocab_size, size=5), 6)
            for _ in range(4)]
    router.step(max_fuse=1)
    router.drain_replica(1)
    with pytest.raises(RuntimeError, match="drain it first"):
        router.remove_replica(1)
    router.run(max_fuse=1)                  # drained replica finishes work
    assert all(r.done for r in reqs)
    assert router.remove_replica(1) == "slave-1"
    assert router.stats["reroutes"] == 0    # drain never re-routes
    # fleet totals survive the removal
    assert router.fleet_stats()["tokens_out"] == sum(
        r.max_new_tokens for r in reqs)


def test_heartbeat_death_fails_host_replicas(params):
    """monitor.on_dead -> router.fail_host: replicas on the dead host are
    failed and their streams finish elsewhere."""
    rng = np.random.RandomState(8)
    router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                           page_size=8, max_seq_len=64,
                           placement=["slave-0", "slave-1"])
    monitor = HeartbeatMonitor()
    monitor.register("slave-0", now=0.0)
    monitor.register("slave-1", now=0.0)
    monitor.on_dead(router.fail_host)
    reqs = [router.submit(rng.randint(0, CFG.vocab_size, size=6), 8)
            for _ in range(4)]
    router.step(max_fuse=1)
    monitor.beat("slave-0", now=100.0)
    monitor.check(100.0)                    # slave-1 silent past dead_after
    assert [r.hostname for r in router.replicas.values()] == ["slave-0"]
    router.run(max_fuse=1)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)


def test_failed_replica_hostname_purged(params):
    """Regression (PR 5 satellite): ServingReplica.fail() must purge its
    hostnames so a dead member can't read as still occupying its node —
    before the fix, a directly-failed replica (member death observed ahead
    of the router) kept its hostname, so hostname-derived occupancy checks
    (e.g. the fleet controller's release guard) saw a ghost on the node
    and prefix-affinity stats could still attribute cached pages to it
    until the replacement booted."""
    rng = np.random.RandomState(11)
    router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                           page_size=8, max_seq_len=64,
                           route_policy="prefix-affinity",
                           placement=["slave-0", "slave-1"])
    persona = rng.randint(0, CFG.vocab_size, size=16).astype(np.int32)
    # warm replica 1's prefix index with the persona (replica 0 is busy)
    r0 = router.submit(rng.randint(0, CFG.vocab_size, size=24), 20)
    r1 = router.submit(persona, 4)
    router.step(max_fuse=1)
    assert r1.replica == 1
    rep = router.replicas[1]
    assert rep.prefix_match_len(persona) > 0
    # member dies; fail() observed directly, before any router bookkeeping
    rep.fail()
    assert rep.hostnames == [] and rep.hostname is None
    # no hostname-derived signal sees the dead replica on its node
    assert not any("slave-1" in r.hostnames
                   for r in router.replicas.values())
    assert rep.prefix_match_len(persona) == 0   # cached pages died with it
    # a follow-up persona request routes to a live replica, never the ghost
    r2 = router.submit(np.concatenate([persona, persona[:2]]), 4)
    router.route_due()
    assert r2.replica == 0
    # and the router-side sweep of the host is a clean no-op (no double
    # failure, the replica slot is simply removed)
    assert router.fail_host("slave-1") == []
    router.fail_replica(1)
    router.run()
    assert len(r0.out_tokens) == 20 and len(r2.out_tokens) == 4


# ----------------------------------------------- per-replica plan + Ambari --

def test_page_plan_replica_split_all_archs():
    """Satellite sweep: every paged-servable arch gets a coherent
    per-replica split — each replica's pool covers its slot budget's
    worst-case reservations (pages >= reservation floor for min slots)."""
    mesh = {"model": 8, "data": 4}
    covered = 0
    for name, cfg in ARCHS.items():
        for k in (1, 2, 4):
            plan = serving_page_plan(cfg, SHAPES["decode_32k"], mesh,
                                     replicas=k)
            if plan is None:                 # MLA / enc-dec / pure-SSM
                assert cfg.attn_impl == "mla" or cfg.is_encdec or all(
                    cfg.block_kind(i) == "ssm"
                    for i in range(cfg.n_layers)), name
                continue
            covered += 1
            assert plan["replicas"] == k
            assert plan["slots_per_replica"] >= plan["min_slots"], name
            # reservation floor: the pool admits slots_per_replica
            # full-length sequences, sink page included
            floor = (plan["slots_per_replica"] * plan["pages_per_seq"] + 1
                     if plan["slots_per_replica"] else 0)
            assert plan["pages_per_replica"] >= floor, (name, k, plan)
            assert plan["pages_per_replica"] >= plan["min_pages"], (name, k)
            assert plan["max_replicas"] >= plan["min_slots"], name
            # max_replicas is the largest in-budget fleet: every replica
            # pays one full-length reservation + its own sink page
            mr = plan["max_replicas"]
            assert mr * (plan["pages_per_seq"] + 1) <= plan["num_pages"]
            assert (mr + 1) * (plan["pages_per_seq"] + 1) > plan["num_pages"]
    assert covered > 0


def test_provision_serving_with_replicas():
    from repro.core.provisioner import ClusterProvisioner
    from repro.core.services import AmbariServer
    from repro.core.simcloud import SimCloud
    cloud = SimCloud(seed=11)
    cloud.register_key("AK", "SK")
    prov = ClusterProvisioner(cloud, region="us-east-1", access_key_id="AK",
                              secret_key="SK")
    cluster = prov.provision(n_slaves=2)
    server = AmbariServer(cloud, cluster)
    svc = server.provision_serving(ARCHS["qwen3-32b"], SHAPES["decode_32k"],
                                   {"model": 8, "data": 4}, replicas=3)
    cfgd = svc.config
    assert cfgd["replicas"] == 3
    assert cfgd["replica_placement"] == ["slave-0", "slave-1", "slave-0"]
    assert cfgd["pages_per_replica"] >= cfgd["pages_per_seq"] + 1
    assert cfgd["slots_per_replica"] >= 1
    # the install event records the fleet width
    evt = [e for e in cluster.log.events
           if e.action == "install_service" and
           e.detail.get("service") == "serve"][-1]
    assert evt.detail["replicas"] == 3
