"""Benchmark harness — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV:
  * Table "25 minutes vs hours": bring-up time, InstaCluster vs manual,
    plus real control-plane wall-clock at fleet sizes (benchmarks/bringup).
  * Table 1: service-matrix coverage counts.
  * Table 2: port registry check.
  * Use cases 1-8: end-to-end wall-clock of each demo operation.
  * Roofline: per (arch x shape x mesh) dry-run terms (benchmarks/roofline;
    requires the dry-run sweep to have populated results/dryrun).
"""
from __future__ import annotations

import time


def _use_case_rows():
    from repro.core.cluster import ClusterManager
    rows = []
    mgr = ClusterManager()
    t0 = time.perf_counter()
    ic = mgr.build_cluster(n_slaves=6)
    rows.append(f"uc1_provision_6node,{(time.perf_counter()-t0)*1e6:.0f},"
                f"sim_min={ic.bringup_seconds/60:.1f}")
    t0 = time.perf_counter()
    ic.lifecycle.stop(ic.cluster)
    rows.append(f"uc2_stop,{(time.perf_counter()-t0)*1e6:.0f},")
    t0 = time.perf_counter()
    ic.lifecycle.start(ic.cluster)
    rows.append(f"uc3_start_slaves_first,{(time.perf_counter()-t0)*1e6:.0f},")
    t0 = time.perf_counter()
    ic.lifecycle.extend(ic.cluster, 3)
    rows.append(f"uc4_extend_plus3,{(time.perf_counter()-t0)*1e6:.0f},"
                f"slaves={len(ic.cluster.directory.slaves())}")
    data = b"the quick brown fox jumps over the lazy dog " * 200
    t0 = time.perf_counter()
    ic.hue.upload_file("/bench/corpus.txt", data)
    rows.append(f"uc7_upload,{(time.perf_counter()-t0)*1e6:.0f},"
                f"bytes={len(data)}")
    t0 = time.perf_counter()
    ic.hue.browse_storage("/bench")
    rows.append(f"uc5_browse,{(time.perf_counter()-t0)*1e6:.0f},")
    t0 = time.perf_counter()
    job = ic.hue.submit_job("spark", lambda: 42)
    rows.append(f"uc6_submit_job,{(time.perf_counter()-t0)*1e6:.0f},"
                f"status={job.status}")
    t0 = time.perf_counter()
    counts = ic.hue.run_wordcount("/bench/corpus.txt")
    rows.append(f"uc8_wordcount,{(time.perf_counter()-t0)*1e6:.0f},"
                f"distinct={len(counts)}")
    return rows


def _table_rows():
    from repro.core.services import PORTS, SERVICE_MATRIX
    rows = []
    provisionable = sum(1 for p, _, _ in SERVICE_MATRIX.values()
                        if p is not None)
    interactable = sum(1 for _, i, _ in SERVICE_MATRIX.values()
                       if i is not None)
    rows.append(f"table1_services_provisionable,,{provisionable}/"
                f"{len(SERVICE_MATRIX)}")
    rows.append(f"table1_services_interactable,,{interactable}/"
                f"{len(SERVICE_MATRIX)}")
    ok = (PORTS['spark-driver'] == 7077 and PORTS['spark-webui'] == 8888
          and PORTS['spark-jobserver'] == 8090 and PORTS['hue'] == 8808)
    rows.append(f"table2_ports_match_paper,,{'yes' if ok else 'NO'}")
    return rows


def main() -> None:
    rows = ["name,us_per_call,derived"]
    from benchmarks import bringup
    rows += bringup.rows()
    rows += _table_rows()
    rows += _use_case_rows()
    try:
        from benchmarks import roofline
        recs = roofline.load()
        s = roofline.summary(recs)
        rows.append(f"dryrun_cells,,ok={s['ok']};skipped={s['skipped']};"
                    f"error={s['error']}")
        rows += roofline.csv_rows(recs)
    except Exception as e:  # noqa: BLE001
        rows.append(f"roofline,,unavailable({type(e).__name__})")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
