"""AdamW + warmup-cosine schedule + global-norm clipping (pure JAX).

Optimizer state shards exactly like the parameters (same tree structure, same
logical axes), so FSDP covers params, m and v — the piece that makes 100B+
configs fit (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(ocfg: OptimConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum((step + 1.0) / max(ocfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - ocfg.warmup_steps)
                    / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = ocfg.min_lr_ratio + (1 - ocfg.min_lr_ratio) * cos
    return ocfg.peak_lr * warm * decay


def opt_init(params: Any) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def opt_update(ocfg: OptimConfig, params: Any, grads: Any, m: Any, v: Any,
               step: jnp.ndarray) -> Tuple[Any, Any, Any, jnp.ndarray]:
    """-> (new_params, new_m, new_v, grad_norm). step is 0-based."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / (gnorm + 1e-12))
    lr = lr_at(ocfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - ocfg.b1 ** t
    bc2 = 1.0 - ocfg.b2 ** t

    def upd(p, g, m_, v_):
        g = g.astype(jnp.float32) * scale
        m_n = ocfg.b1 * m_ + (1 - ocfg.b1) * g
        v_n = ocfg.b2 * v_ + (1 - ocfg.b2) * jnp.square(g)
        mhat = m_n / bc1
        vhat = v_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps) + ocfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m_n, v_n

    flat, treedef = jax.tree.flatten(params)
    gflat = jax.tree.leaves(grads)
    mflat = jax.tree.leaves(m)
    vflat = jax.tree.leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat, gflat, mflat, vflat)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v, gnorm
