"""End-to-end driver: train a ~100M-parameter LM with the full stack —
provisioned cluster, blueprint, deterministic data pipeline, fault-tolerant
trainer with async checkpoints, heartbeats into the monitor.

Default runs a ~100M model for 300 steps (CPU: ~20-40 min); ``--quick``
drops to a ~20M model for 60 steps for a fast demonstration.

Run:  PYTHONPATH=src python examples/train_100m.py [--quick] [--steps N]
"""
import argparse
import json
import pathlib
import time

from repro.configs.base import ModelConfig
from repro.core.cluster import ClusterManager
from repro.core.heartbeat import HeartbeatMonitor
from repro.optim.adamw import OptimConfig
from repro.train.trainer import Trainer

LM_100M = ModelConfig(
    name="repro-lm-100m", family="dense", n_layers=16, d_model=640,
    n_heads=10, n_kv_heads=5, d_ff=1920, vocab_size=32768,
    tie_embeddings=True, rope_theta=10000.0)

LM_20M = ModelConfig(
    name="repro-lm-20m", family="dense", n_layers=8, d_model=320,
    n_heads=5, n_kv_heads=5, d_ff=960, vocab_size=16384,
    tie_embeddings=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--out", default="/tmp/train_100m")
    args = ap.parse_args()

    cfg = LM_20M if args.quick else LM_100M
    steps = args.steps or (60 if args.quick else 300)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{steps} steps @ batch={args.batch} seq={args.seq}")

    # the cluster control plane (heartbeats feed the Ambari-analogue monitor)
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=2, services=("hdfs", "spark", "hue"))
    monitor: HeartbeatMonitor = ic.ambari.monitor

    def heartbeat(step: int, step_time: float) -> None:
        for node in ic.cluster.directory.slaves():
            ic.ambari.agent_heartbeat(node.hostname, step_time=step_time)
        mgr.cloud._advance(step_time)
        if step % 20 == 0:
            states = ic.ambari.check_agents()
            assert all(s != "dead" for s in states.values()), states

    ocfg = OptimConfig(peak_lr=3e-4, warmup_steps=min(50, steps // 5),
                       total_steps=steps, weight_decay=0.01)
    trainer = Trainer(cfg, ocfg, batch=args.batch, seq=args.seq,
                      ckpt_dir=f"{args.out}/ckpt", ckpt_every=max(steps // 4, 10),
                      heartbeat_cb=heartbeat)

    t0 = time.time()
    report = trainer.run(steps)
    dt = time.time() - t0
    tokens = steps * args.batch * args.seq
    print(f"done: {report.final_step} steps in {dt/60:.1f} min "
          f"({tokens/dt:.0f} tok/s)")
    print(f"loss: first={report.losses[0]:.3f} "
          f"min={min(report.losses):.3f} last={report.losses[-1]:.3f}")
    assert report.losses[-1] < report.losses[0], "loss must improve"

    out = {"config": cfg.name, "params_m": cfg.param_count() / 1e6,
           "steps": report.final_step, "wall_min": dt / 60,
           "tokens_per_s": tokens / dt,
           "loss_first": report.losses[0], "loss_last": report.losses[-1],
           "losses_every_10": report.losses[::10],
           "checkpoints": trainer.ckpt.all_steps()}
    path = pathlib.Path(args.out) / "report.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    print(f"report -> {path}")


if __name__ == "__main__":
    main()
