"""Deterministic observability plane for the serving fleet.

* :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram registry with
  log-spaced buckets, Prometheus text exposition, and the shared
  nearest-rank percentile definition;
* :mod:`repro.obs.trace` — request-lifecycle spans on the sim tick
  clock, exported as Chrome trace-event JSON (Perfetto) or JSONL;
* :mod:`repro.obs.slo` — SLO objectives with multi-window burn-rate
  alerts feeding the autoscale ``TelemetryBus``;
* :mod:`repro.obs.profile` — opt-in kernel dispatch timing with modeled
  bytes/FLOPs and roofline-utilization fractions.

Everything here is read-only over serving state: observability on vs
off is byte-identical in emitted tokens (see tests/test_obs_plane.py).
"""
from repro.obs.metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, StatsView,
    TICK_BUCKETS, SECONDS_BUCKETS, log_buckets, nearest_rank, percentile,
)
from repro.obs.trace import Tracer, Span, Instant, TICK_US
from repro.obs.slo import (
    SLObjective, SLOMonitor, histogram_threshold_source,
    counter_ratio_source,
)
from repro.obs.profile import KernelProfiler, PEAK_FLOPS, HBM_BW

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "TICK_BUCKETS", "SECONDS_BUCKETS", "log_buckets", "nearest_rank",
    "percentile",
    "Tracer", "Span", "Instant", "TICK_US",
    "SLObjective", "SLOMonitor", "histogram_threshold_source",
    "counter_ratio_source",
    "KernelProfiler", "PEAK_FLOPS", "HBM_BW",
]
