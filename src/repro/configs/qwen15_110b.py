"""qwen1.5-110b [dense] — QKV bias, GQA kv=8.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064
[hf:Qwen/Qwen1.5-0.5B scaled per assignment; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    rms_eps=1e-6,
)

REDUCED = ModelConfig(
    name="qwen1.5-110b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
    tie_embeddings=False,
)
