"""Tensor-parallel shard groups: byte-identity of tp>1 serving vs tp=1
(dense, hybrid-SSM, MoE), sharded page-pool/COW atomicity, the per-shard
kernel wrapper vs the unsharded one, serving_page_plan(tp=k) budget sums,
provision_serving shard-group placement, and fleet scaling/preemption in
shard-group units. See docs/sharding.md for the contracts under test."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ARCHS, REDUCED
from repro.core.blueprint import serving_page_plan
from repro.models import model as M
from repro.parallel.context import ShardGroup
from repro.serving import paged_cache as PC
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler

# widened so tp in (2, 4) divides the kv-head count
CFG = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32",
                          n_heads=8, n_kv_heads=4)


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


def _persona_trace(rng, n_users=5, extra=2):
    """Shared-prefix-heavy trace: exercises full prefill, suffix prefill,
    page sharing, and COW forks — every sharded cache op — in one run."""
    persona = rng.randint(0, CFG.vocab_size, size=18).astype(np.int32)
    out = []
    for _ in range(n_users):
        user = rng.randint(0, CFG.vocab_size,
                           size=int(rng.randint(3, 8))).astype(np.int32)
        out.append((np.concatenate([persona, user]),
                    int(rng.randint(4, 9))))
    for _ in range(extra):
        out.append((rng.randint(0, CFG.vocab_size,
                                size=int(rng.randint(5, 12))
                                ).astype(np.int32),
                    int(rng.randint(4, 8))))
    return out


def _run_sched(cfg, params, trace, tp, **kw):
    s = ContinuousBatchingScheduler(cfg, params, max_slots=3, page_size=8,
                                    max_seq_len=64, tp=tp, **kw)
    reqs = [s.submit(p, g, arrival_step=i) for i, (p, g) in enumerate(trace)]
    s.run()
    return [r.out_tokens for r in reqs], s


# ----------------------------------------------------------- shard group --

def test_shard_group_validation():
    with pytest.raises(ValueError, match="tp must be >= 1"):
        ShardGroup(0)
    sg = ShardGroup(3)
    with pytest.raises(ValueError, match="must divide"):
        sg.validate_model(CFG)              # 3 does not divide 8/4 heads
    ShardGroup(2).validate_model(CFG)
    assert ShardGroup(2).shard_heads(CFG.n_kv_heads) == 2
    assert not ShardGroup(1).is_sharded and ShardGroup(2).is_sharded
    with pytest.raises(ValueError, match="MLA"):
        ShardGroup(2).validate_model(REDUCED["deepseek-v2-236b"])


def test_scheduler_rejects_undividable_tp(params):
    with pytest.raises(ValueError, match="must divide"):
        ContinuousBatchingScheduler(CFG, params, max_slots=2, page_size=8,
                                    max_seq_len=32, tp=3)


# ------------------------------------------------------- token identity --

def test_tp_tokens_identical_dense(params):
    """Acceptance: tp=2 and tp=4 emit byte-identical tokens to tp=1 on a
    dense fp32 arch, across full prefills, prefix-cache hits, and COW
    forks (the sharded suffix/COW paths must all agree)."""
    rng = np.random.RandomState(0)
    trace = _persona_trace(rng)
    want, s1 = _run_sched(CFG, params, trace, tp=1)
    for tp in (2, 4):
        got, s = _run_sched(CFG, params, trace, tp=tp)
        assert got == want, f"tp={tp} diverged from tp=1"
        # the interesting sharded paths actually ran
        assert s.stats["prefix_hits"] > 0
        assert s.stats["cow_forks"] > 0
        # allocator ledger is tp-invariant (pages are logical)
        assert s.stats["peak_pages"] == s1.stats["peak_pages"]
        assert s.alloc.num_allocated == 0


def test_tp_tokens_identical_hybrid_ssm():
    """Sharded attention + replicated SSM slot state (jamba hybrid)."""
    cfg = dataclasses.replace(
        REDUCED["jamba-v0.1-52b"], dtype="float32",
        moe_capacity_factor=float(REDUCED["jamba-v0.1-52b"].n_routed_experts)
        / REDUCED["jamba-v0.1-52b"].moe_top_k)
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    trace = [(rng.randint(0, cfg.vocab_size,
                          size=int(rng.randint(4, 9))).astype(np.int32),
              int(rng.randint(4, 7))) for _ in range(3)]
    want, _ = _run_sched(cfg, params, trace, tp=1)
    got, _ = _run_sched(cfg, params, trace, tp=2)
    assert got == want


def test_tp_tokens_identical_moe_expert_sharded():
    """Expert-sharded MoE: routing replicated, expert FFN sliced per shard,
    expert-axis concat combine — token-identical to tp=1 (the EP
    all-gather reconstructs the exact slot buffer)."""
    cfg = dataclasses.replace(REDUCED["qwen2-moe-a2.7b"], dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    trace = [(rng.randint(0, cfg.vocab_size,
                          size=int(rng.randint(4, 9))).astype(np.int32),
              int(rng.randint(3, 6))) for _ in range(3)]
    want, _ = _run_sched(cfg, params, trace, tp=1)
    got, _ = _run_sched(cfg, params, trace, tp=2)
    assert got == want


def test_fleet_tp_tokens_identical_to_single(params):
    """Acceptance: a tp=2 *fleet* run (2 shard-group replicas behind the
    router) emits byte-identical tokens to the single tp=1 scheduler."""
    rng = np.random.RandomState(3)
    trace = _persona_trace(rng, n_users=4, extra=2)
    want, _ = _run_sched(CFG, params, trace, tp=1)

    router = ServingRouter(CFG, params, replicas=2, max_slots=3,
                           page_size=8, max_seq_len=64, tp=2,
                           placement=[["slave-0", "slave-1"],
                                      ["slave-2", "slave-3"]])
    reqs = [router.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(trace)]
    router.run()
    assert [r.out_tokens for r in reqs] == want
    assert all(len(rep.hostnames) == 2
               for rep in router.replicas.values())


# --------------------------------------------------- sharded cache ops --

def test_init_paged_cache_shard_axis():
    cache = PC.init_paged_cache(CFG, num_pages=5, page_size=4, max_slots=2,
                                tp=2)
    leaf = cache["stack"]["0"]["k_pages"]
    # (n_periods, tp, P, ps, KVH/tp, hd)
    assert leaf.shape == (CFG.n_layers, 2, 5, 4, CFG.n_kv_heads // 2,
                          CFG.resolved_head_dim)
    with pytest.raises(ValueError, match="must divide"):
        PC.init_paged_cache(CFG, 5, 4, 2, tp=3)


def test_copy_page_sharded_atomic():
    """A COW fork copies the source page's slice in *every* shard in one
    call — no shard can be left holding stale contents."""
    cache = PC.init_paged_cache(CFG, num_pages=4, page_size=2, max_slots=1,
                                tp=2)

    def stamp(leaf):
        # distinct value per (shard, page) so copies are attributable
        idx = np.arange(leaf.size, dtype=np.float32).reshape(leaf.shape)
        return idx

    cache = jax.tree.map(lambda x: jax.numpy.asarray(stamp(x), x.dtype),
                         cache)
    out = PC.copy_page(cache, 1, 3, tp=2)

    def check(node, stacked):
        if isinstance(node, dict) and "k_pages" in node:
            ax = PC.page_axis(stacked, 2)
            for k in PC.PAGE_LEAVES:
                if k not in node:
                    continue
                leaf = np.asarray(node[k])
                src = np.take(leaf, 1, axis=ax)
                dst = np.take(leaf, 3, axis=ax)
                np.testing.assert_array_equal(src, dst)
            return
        for k, v in node.items():
            check(v, stacked or k == "stack")

    check(out, False)


def test_write_prefill_sharded_matches_unsharded(params):
    """The per-shard pools hold exactly the kv-head slices of the tp=1
    pool after a prefill insert (same pages, same block row)."""
    from repro.models.transformer import lm_forward
    rng = np.random.RandomState(4)
    prompt = rng.randint(0, CFG.vocab_size, size=11).astype(np.int32)
    _, _, pre = lm_forward(CFG, params, jax.numpy.asarray(prompt[None]),
                           mode="prefill")
    row = np.array([1, 2, PC.SINK_PAGE], np.int32)
    kw = dict(block_row=jax.numpy.asarray(row), slot=0,
              plen=len(prompt), n_write=len(prompt), page_size=8)
    c1 = PC.write_prefill(CFG, PC.init_paged_cache(CFG, 4, 8, 1), pre, **kw)
    c2 = PC.write_prefill(CFG, PC.init_paged_cache(CFG, 4, 8, 1, tp=2), pre,
                          tp=2, **kw)
    k1 = np.asarray(c1["stack"]["0"]["k_pages"])   # (L, P, ps, KVH, hd)
    k2 = np.asarray(c2["stack"]["0"]["k_pages"])   # (L, tp, P, ps, KVH/2, hd)
    KVH = CFG.n_kv_heads
    np.testing.assert_array_equal(k2[:, 0], k1[..., :KVH // 2, :])
    np.testing.assert_array_equal(k2[:, 1], k1[..., KVH // 2:, :])


# ------------------------------------------------------------- kernel --

def test_paged_decode_kernel_sharded_matches_unsharded():
    """The per-shard Pallas kernel invocation (head-slice q against each
    shard's pool slice, concat combine) equals the one-shot kernel on the
    logical pool."""
    from repro.kernels import ops
    rng = np.random.RandomState(5)
    B, H, KVH, d, P, ps, n_pg, tp = 2, 8, 4, 16, 6, 4, 3, 2
    q = jax.numpy.asarray(rng.randn(B, H, d).astype(np.float32))
    k_pages = jax.numpy.asarray(rng.randn(P, ps, KVH, d).astype(np.float32))
    v_pages = jax.numpy.asarray(rng.randn(P, ps, KVH, d).astype(np.float32))
    bt = jax.numpy.asarray(
        np.array([[1, 2, 3], [4, 5, 0]], np.int32))
    lens = jax.numpy.asarray(np.array([9, 5], np.int32))
    want = ops.paged_decode_attention(q, k_pages, v_pages, bt, lens,
                                      interpret=True)
    def shard(a):
        return jax.numpy.stack([a[..., :KVH // tp, :],
                                a[..., KVH // tp:, :]])

    got = ops.paged_decode_attention_sharded(
        q, shard(k_pages), shard(v_pages), bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- planner --

def test_page_plan_tp_budgets_sum_within_one_page_per_shard():
    """Acceptance: serving_page_plan(tp=k) per-shard budgets sum to the
    unsharded budget within one page per shard (flooring only)."""
    mesh = {"model": 8, "data": 4}
    checked = 0
    for name, cfg in ARCHS.items():
        try:
            base = serving_page_plan(cfg, SHAPES["decode_32k"], mesh)
        except ValueError:
            continue
        if base is None:
            continue
        for k in (2, 4, 8):
            if cfg.n_kv_heads % k:
                with pytest.raises(ValueError, match="must divide"):
                    serving_page_plan(cfg, SHAPES["decode_32k"], mesh, tp=k)
                continue
            plan = serving_page_plan(cfg, SHAPES["decode_32k"], mesh, tp=k)
            checked += 1
            total = k * plan["pages_budget_per_shard"]
            assert 0 <= base["num_pages"] - total <= k, (name, k)
            assert abs(plan["num_pages"] - base["num_pages"]) <= k
            # per-shard bytes times tp reassembles the whole pool
            assert plan["shard_pool_bytes"] * k == plan["pool_bytes"]
            assert plan["tp"] == k
    assert checked > 0


def test_page_plan_tight_pool_raises_with_minimum():
    """Satellite: page_size not dividing max_len on a tight pool used to
    floor silently to zero admissible sequences; now it names the minimum
    viable pool."""
    tight = ShapeConfig("tight", 1000, 1, "decode")
    with pytest.raises(ValueError, match="minimum viable"):
        serving_page_plan(ARCHS["qwen1.5-110b"], tight, {"model": 1},
                          page_size=48)


# -------------------------------------------------- placement + fleet --

def _mini_cluster(n_slaves, spares=0):
    from repro.core.cluster import ClusterManager
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=n_slaves, spot=True)
    if spares:
        ic.lifecycle.provision_spares(ic.cluster, spares)
    return mgr, ic


def test_provision_serving_tp_contiguous_groups():
    """Acceptance: provision_serving(tp=k) places each shard group on
    exactly k (contiguous, distinct) nodes."""
    from repro.core.services import AmbariServer
    mgr, ic = _mini_cluster(4)
    server = AmbariServer(mgr.cloud, ic.cluster)
    svc = server.provision_serving(ARCHS["qwen3-32b"], SHAPES["decode_32k"],
                                   {"model": 8, "data": 4}, replicas=2, tp=2)
    groups = svc.config["replica_placement"]
    assert groups == [["slave-0", "slave-1"], ["slave-2", "slave-3"]]
    assert all(len(g) == 2 == len(set(g)) for g in groups)
    assert svc.config["tp"] == 2
    with pytest.raises(ValueError, match="need 6 slaves"):
        server.provision_serving(ARCHS["qwen3-32b"], SHAPES["decode_32k"],
                                 {"model": 8, "data": 4}, replicas=3, tp=2)


def test_fleet_controller_scales_in_shard_group_units(params):
    """Scale-out acquires tp nodes in one extend; a completed drain
    releases all tp members' nodes."""
    from repro.autoscale import FleetController
    from repro.core.heartbeat import HeartbeatMonitor
    mgr, ic = _mini_cluster(2)
    monitor = HeartbeatMonitor()
    for node in ic.cluster.directory.slaves():
        monitor.register(node.hostname, now=mgr.cloud.clock)
    router = ServingRouter(CFG, params, replicas=1, max_slots=1,
                           page_size=8, max_seq_len=64, tp=2,
                           placement=[["slave-0", "slave-1"]])
    ctl = FleetController(router, min_replicas=1, max_replicas=2,
                          eval_interval=2, lifecycle=ic.lifecycle,
                          cluster=ic.cluster, monitor=monitor)
    rng = np.random.RandomState(6)
    for i in range(10):
        router.submit(rng.randint(0, CFG.vocab_size, size=6), 10,
                      arrival_step=0)
    for _ in range(3):
        router.submit(rng.randint(0, CFG.vocab_size, size=6), 4,
                      arrival_step=180 + 40 * _)   # quiet tail -> scale-in
    done = ctl.run()
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)
    adds = [e for e in ctl.log.events if e.action == "add_replica"]
    assert adds and all(e.detail["nodes"] == 2 for e in adds)
    ext = [e for e in ctl.log.events if e.action == "extend_cluster"]
    assert ext and all(len(e.detail["added"]) == 2 for e in ext)
    # the drained group's two nodes were both released
    ctl.log.assert_order("extend_cluster", "drain_replica",
                         "remove_replica", "shrink_cluster")
    shrunk = [h for e in ctl.log.events if e.action == "shrink_cluster"
              for h in e.detail["removed"]]
    assert len(shrunk) == 2
    assert len(ic.cluster.directory.slaves()) == 2


def test_shard_member_preemption_replaced_without_losing_streams(params):
    """Tentpole contract: one preempted member of a tp=2 group is swapped
    from the warm-spare pool under its stable hostname and the group's
    streams never re-route; with no spare the whole group fails and its
    streams re-prefill elsewhere."""
    from repro.autoscale import FleetController
    from repro.core.heartbeat import HeartbeatMonitor
    mgr, ic = _mini_cluster(4, spares=1)
    monitor = HeartbeatMonitor()
    for node in ic.cluster.directory.slaves():
        monitor.register(node.hostname, now=mgr.cloud.clock)
    router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                           page_size=8, max_seq_len=64, tp=2,
                           placement=[["slave-0", "slave-1"],
                                      ["slave-2", "slave-3"]])
    ctl = FleetController(router, min_replicas=2, max_replicas=2,
                          eval_interval=4, lifecycle=ic.lifecycle,
                          cluster=ic.cluster, monitor=monitor)
    rng = np.random.RandomState(7)
    reqs = [router.submit(rng.randint(0, CFG.vocab_size, size=6), 12)
            for _ in range(6)]
    for _ in range(3):
        ctl.tick()
        router.step(max_fuse=1)
    # preempt one member of group 0 mid-decode: spare swaps in, no failure
    victim_id = ic.cluster.directory.nodes["slave-1"].instance_id
    mgr.cloud.preempt_spot(victim_id)
    assert router.stats["reroutes"] == 0
    assert len(router.replicas) == 2
    assert any(e.action == "shard_member_replaced"
               for e in ctl.log.events)
    # the stable hostname survived with fresh hardware
    assert ic.cluster.directory.nodes["slave-1"].instance_id != victim_id
    # second member loss: spare pool is empty -> the whole group fails,
    # streams re-route to the surviving group, and its nodes are released
    mgr.cloud.preempt_spot(
        ic.cluster.directory.nodes["slave-0"].instance_id)
    assert router.stats["reroutes"] >= 1
    assert len(router.replicas) == 1
    done = ctl.run()
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    assert len(done) == len(reqs)
    hostnames = [n.hostname for n in ic.cluster.directory.slaves()]
    assert "slave-0" not in hostnames and "slave-2" in hostnames
