"""Provisioning event log — lets tests assert the paper's Fig. 1 sequence.

The log round-trips through JSON lines (``write_jsonl``/``from_jsonl``), so
a full provision/scale/serve run can be exported and replayed — the paper's
reproducibility claim (§4, "share the experimental environment") made
concrete for the event stream as well as the cluster spec. ``launch.serve
--events-out`` and ``benchmarks/autoscale_bench.py --events-out`` write
this format.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Event:
    t: float
    actor: str        # "slave-3", "master", "cloud"
    action: str       # e.g. "create_temp_user"
    detail: Dict[str, Any]

    def to_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "actor": self.actor, "action": self.action,
                "detail": self.detail}


class EventLog:
    def __init__(self) -> None:
        self.events: List[Event] = []

    def emit(self, t: float, actor: str, action: str, **detail: Any) -> None:
        self.events.append(Event(t, actor, action, dict(detail)))

    # -------------------------------------------------------------- export --
    def to_jsonl(self) -> str:
        """One JSON object per line, in emission order."""
        return "".join(json.dumps(e.to_dict(), sort_keys=True,
                                  default=str) + "\n"
                       for e in self.events)

    def write_jsonl(self, path: str) -> int:
        """Write the log to ``path``; returns the number of events."""
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return len(self.events)

    @classmethod
    def from_jsonl(cls, path: str) -> "EventLog":
        """Replay an exported log: every assertion helper (``assert_order``,
        ``actions`` …) works on the loaded copy exactly as on the live one.

        Malformed input raises ``ValueError`` naming the offending line
        number (1-based), so a truncated or hand-edited export fails loud
        instead of replaying a silently wrong event stream.
        """
        log = cls()
        with open(path) as f:
            for lineno, line in enumerate(f, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError as e:
                    raise ValueError(
                        f"{path}: line {lineno} is not valid JSON "
                        f"({e.msg} at column {e.colno})") from e
                if not isinstance(d, dict):
                    raise ValueError(
                        f"{path}: line {lineno} holds a JSON "
                        f"{type(d).__name__}, not an event object")
                missing = [k for k in ("t", "actor", "action", "detail")
                           if k not in d]
                if missing:
                    raise ValueError(
                        f"{path}: line {lineno} is missing event field(s) "
                        f"{missing} (has {sorted(d)})")
                if not isinstance(d["detail"], dict):
                    raise ValueError(
                        f"{path}: line {lineno} has a non-object 'detail' "
                        f"({type(d['detail']).__name__})")
                log.events.append(Event(d["t"], d["actor"], d["action"],
                                        dict(d["detail"])))
        return log

    def actions(self, actor: Optional[str] = None) -> List[str]:
        return [e.action for e in self.events
                if actor is None or e.actor == actor
                or (actor.endswith("*") and e.actor.startswith(actor[:-1]))]

    def first_index(self, action: str) -> int:
        for i, e in enumerate(self.events):
            if e.action == action:
                return i
        raise KeyError(action)

    def last_index(self, action: str) -> int:
        idx = -1
        for i, e in enumerate(self.events):
            if e.action == action:
                idx = i
        if idx < 0:
            raise KeyError(action)
        return idx

    def assert_order(self, *actions: str) -> None:
        """Every listed action occurs, in the given order (first occurrences,
        except consecutive duplicates which use last-of-previous)."""
        prev = -1
        for a in actions:
            idx = next((i for i, e in enumerate(self.events)
                        if e.action == a and i > prev), None)
            if idx is None:
                raise AssertionError(
                    f"action {a!r} not found after index {prev} "
                    f"(log: {[e.action for e in self.events]})")
            prev = idx
