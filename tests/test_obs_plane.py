"""The observability plane end to end: tracing/metrics/profiling must be
read-only (byte-identical tokens with the plane on or off, dense and
hybrid archs), the disaggregated fleet's trace must show the full
prefill-replica -> page-migration -> decode-replica lifecycle (the PR's
acceptance trace), histogram quantiles must agree with the bench's
nearest-rank percentiles on real latencies, and the kernel profiler must
report sane dispatch summaries."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.obs.metrics import percentile
from repro.obs.trace import Tracer
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler


def _fp32(arch):
    cfg = dataclasses.replace(REDUCED[arch], dtype="float32")
    if cfg.n_routed_experts:
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=float(cfg.n_routed_experts)
            / cfg.moe_top_k)
    return cfg


_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        cfg = _fp32(arch)
        _PARAMS[arch] = (cfg, M.init(cfg, jax.random.PRNGKey(0)))
    return _PARAMS[arch]


def _trace(cfg, seed, n=4, p_lo=3, p_hi=26, g_hi=6):
    rng = np.random.RandomState(seed)
    out = []
    for _ in range(n):
        plen = int(rng.randint(p_lo, p_hi + 1))
        gen = int(rng.randint(2, g_hi + 1))
        out.append((rng.randint(0, cfg.vocab_size, size=plen
                                ).astype(np.int32), gen))
    return out


def _run_sched(cfg, params, workload, *, observe=False):
    sched = ContinuousBatchingScheduler(cfg, params, max_slots=3,
                                        page_size=8, max_seq_len=64,
                                        prefill_budget=4)
    tracer = profiler = None
    if observe:
        tracer = Tracer()
        sched.set_tracer(tracer)
        profiler = sched.enable_profiling()
    for i, (prompt, gen) in enumerate(workload):
        sched.submit(prompt, gen, arrival_step=i // 2)
    done = sched.run()
    return done, sched, tracer, profiler


# ------------------------------------------------------- byte identity --

@pytest.mark.parametrize("arch", ("qwen3-32b", "jamba-v0.1-52b"))
def test_observed_run_emits_identical_tokens(arch):
    """The hard contract: tracing + profiling observe the scheduler and
    never steer it — chunked-prefill serving with the full plane attached
    emits exactly the tokens an unobserved run emits."""
    cfg, params = _params(arch)
    workload = _trace(cfg, seed=1)
    plain, _, _, _ = _run_sched(cfg, params, workload)
    observed, sched, tracer, profiler = _run_sched(cfg, params, workload,
                                                   observe=True)
    assert [list(r.out_tokens) for r in observed] == \
        [list(r.out_tokens) for r in plain]
    # and the plane actually recorded the run it watched
    assert {s.name for s in tracer.spans} >= {"queued", "decode"}
    assert sched.h_latency.count == len(workload)
    assert profiler.summary()["decode"]["calls"] > 0


# -------------------------------------------------- disagg acceptance --

def test_disagg_trace_shows_prefill_migration_decode(tmp_path):
    """Acceptance: a --mixed --disagg style run traced to Chrome JSON
    shows, for a long-prompt request, >= 2 prefill chunks on a
    prefill-role replica, a page-migration instant, and a decode span on
    a decode-role replica — with tokens byte-identical to tracing off."""
    cfg, params = _params("qwen3-32b")

    def build():
        return ServingRouter(cfg, params, replicas=3, max_slots=3,
                             page_size=8, max_seq_len=64,
                             prefill_budget=4, disagg=1)

    rng = np.random.RandomState(3)
    chats = [(rng.randint(0, cfg.vocab_size, size=5).astype(np.int32), 3)
             for _ in range(3)]
    long_prompt = rng.randint(0, cfg.vocab_size, size=24).astype(np.int32)

    def run(router, tracer=None):
        if tracer is not None:
            router.set_tracer(tracer)
        reqs = [router.submit(p, g, arrival_step=i // 2)
                for i, (p, g) in enumerate(chats)]
        long_req = router.submit(long_prompt, 4, arrival_step=0)
        done = router.run()
        return done, long_req.rid

    plain_done, _ = run(build())
    tracer = Tracer()
    traced_done, long_rid = run(build(), tracer)
    assert sorted([r.rid] + list(r.out_tokens) for r in traced_done) == \
        sorted([r.rid] + list(r.out_tokens) for r in plain_done)

    router = build()                          # roles are deterministic
    prefill_ids = {r.replica_id for r in router.replicas.values()
                   if r.role == "prefill"}
    decode_ids = {r.replica_id for r in router.replicas.values()
                  if r.role == "decode"}

    path = tmp_path / "trace.json"
    tracer.finish_open()
    tracer.write_chrome(str(path))
    evs = json.loads(path.read_text())["traceEvents"]
    mine = [e for e in evs if e.get("args", {}).get("rid") == long_rid]

    chunks = [e for e in mine if e["name"] == "prefill_chunk"]
    assert len(chunks) >= 2                   # 24 tokens / budget 4
    assert all(e["args"]["replica"] in prefill_ids for e in chunks)
    assert [e["args"]["chunk"] for e in chunks] == list(range(len(chunks)))

    migr = [e for e in mine if e["name"] == "page_migration"]
    assert len(migr) == 1 and migr[0]["ph"] == "i"
    assert migr[0]["args"]["src"] in prefill_ids
    assert migr[0]["args"]["dst"] in decode_ids
    assert migr[0]["args"]["pages"] > 0 and migr[0]["args"]["bytes"] > 0

    dec = [e for e in mine if e["name"] == "decode" and e["ph"] == "X"]
    assert len(dec) == 1
    assert dec[0]["args"]["replica"] in decode_ids
    assert dec[0]["dur"] > 0
    # the parked span sits between the last chunk and the decode span
    parked = next(e for e in mine if e["name"] == "parked")
    assert parked["ts"] >= chunks[-1]["ts"]
    assert dec[0]["ts"] >= parked["ts"]


# ------------------------------------------------ percentile agreement --

def test_histogram_latency_agrees_with_bench_percentile():
    """The scheduler's latency histogram and the bench's retained-sample
    nearest-rank percentile answer the same question within one bucket's
    growth factor — the S1 contract that lets dashboards drop samples."""
    cfg, params = _params("qwen3-32b")
    workload = _trace(cfg, seed=2, n=8)
    done, sched, _, _ = _run_sched(cfg, params, workload)
    lats = [float(r.finish_step - r.arrival_step) for r in done]
    step = 10.0 ** 0.25                       # TICK_BUCKETS growth factor
    for q in (50, 90, 99):
        exact = percentile(lats, q)
        approx = sched.h_latency.quantile(q)
        assert exact <= approx <= exact * step, (q, exact, approx)


# ----------------------------------------------------------- profiler --

def test_profiler_summary_is_sane():
    cfg, params = _params("qwen3-32b")
    workload = _trace(cfg, seed=4)
    _, _, _, profiler = _run_sched(cfg, params, workload, observe=True)
    summary = profiler.summary()
    assert {"prefill", "decode"} <= set(summary)
    for kind, s in summary.items():
        assert s["calls"] > 0, kind
        assert s["wall_s"] > 0.0, kind
        assert s["modeled_flops"] > 0.0, kind
        assert s["modeled_bytes"] > 0.0, kind
        # CPU interpreter walls are far off the roofline but the fraction
        # must be a positive finite number
        assert 0.0 < s["roofline_frac"] < 1.0, (kind, s)
