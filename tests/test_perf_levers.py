"""Correctness of the §Perf optimization levers (each vs its baseline)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.models.attention import attend, quantize_kv
from repro.models.moe import moe_apply
from repro.models.schema import init_params
from repro.serving import engine as E

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "qwen2-moe-a2.7b",
                                  "jamba-v0.1-52b"])
@pytest.mark.parametrize("decode", [False, True])
def test_moe_scatter_combine_equals_gather(arch, decode):
    cfg_g = dataclasses.replace(REDUCED[arch], moe_combine="gather")
    cfg_s = dataclasses.replace(cfg_g, moe_combine="scatter")
    p = init_params(moe_mod.moe_schema(cfg_g), KEY)
    x = jax.random.normal(KEY, (2, 24, cfg_g.d_model), jnp.float32)
    yg, auxg = moe_apply(cfg_g, p, x, decode=decode)
    ys, auxs = moe_apply(cfg_s, p, x, decode=decode)
    np.testing.assert_array_equal(np.asarray(yg), np.asarray(ys))
    assert float(auxg) == float(auxs)


@pytest.mark.parametrize("window", [None, 1024])
def test_attn_mask_opt_is_exact(window):
    B, S, H, KVH, d = 1, 8192, 4, 2, 64
    q = jax.random.normal(KEY, (B, S, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KVH, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KVH, d))
    a = attend(q, k, v, causal=True, window=window, mask_opt=False)
    b = attend(q, k, v, causal=True, window=window, mask_opt=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantize_kv_roundtrip_error():
    x = jax.random.normal(KEY, (2, 64, 4, 128), jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    deq = q.astype(jnp.float32) * s[..., None]
    err = np.abs(np.asarray(deq - x))
    # bound: half a quantisation step per element
    bound = np.asarray(s[..., None]) * 0.5 + 1e-6
    assert (err <= bound).all()


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen3-32b"])
def test_int8_cache_decode_close_to_fp32(arch):
    cfg_f = dataclasses.replace(REDUCED[arch], dtype="float32")
    cfg_q = dataclasses.replace(cfg_f, cache_quant=True)
    B, S = 2, 24
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg_f.vocab_size)
    params = M.init(cfg_f, KEY)
    ref_lg, _ = M.prefill(cfg_f, params, {"tokens": tokens})
    _, cache, cur = E.prefill(cfg_q, params, {"tokens": tokens[:, :S]}, S + 8)
    lg, _ = E.decode_step(cfg_q, params, cache, tokens[:, S:S + 1], cur)
    rel = (float(jnp.max(jnp.abs(ref_lg - lg)))
           / (float(jnp.max(jnp.abs(ref_lg))) + 1e-9))
    assert rel < 0.05, rel
    # the quantised cache leaves really are int8
    leaf = jax.tree.leaves({"k": cache})[0]
    flat = jax.tree.leaves(cache)
    assert any(l.dtype == jnp.int8 for l in flat)


def test_chunk_budget_lever_caps_prefill_not_decode():
    """Chunked prefill (§SLO lever): the per-tick budget exactly caps
    prompt tokens landed per tick and spreads a long prompt over
    ceil(plen/budget) chunk ticks — while a co-resident chat stream keeps
    gaining one token *every* tick and finishes on the same tick as under
    monolithic prefill. The lever trades prefill latency, never decode
    progress, and never the tokens themselves."""
    from repro.serving.scheduler import ContinuousBatchingScheduler

    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")
    params = M.init(cfg, KEY)
    rng = np.random.RandomState(3)
    chat = rng.randint(0, cfg.vocab_size, size=5).astype(np.int32)
    long_p = rng.randint(0, cfg.vocab_size, size=40).astype(np.int32)

    def serve(budget):
        s = ContinuousBatchingScheduler(
            cfg, params, max_slots=2, page_size=8, max_seq_len=64,
            prefix_cache=False, prefill_budget=budget)
        a = s.submit(chat, 12, arrival_step=0)
        b = s.submit(long_p, 2, arrival_step=1)
        deltas, gains = [], []
        for _ in range(200):
            if a.done and b.done:
                break
            before = s.stats["prefill_chunk_tokens"]
            n0 = len(a.out_tokens)
            decoding = (a.admit_step is not None and a.prefill_pos is None
                        and not a.done)
            s.step(max_fuse=1)
            deltas.append(s.stats["prefill_chunk_tokens"] - before)
            if decoding:
                gains.append(len(a.out_tokens) - n0)
        assert a.done and b.done
        return [list(a.out_tokens), list(b.out_tokens)], deltas, gains, \
            a.finish_step

    base, _, _, base_finish = serve(None)
    # budgets >= chat's plen: the chat stream lands in one chunk, so any
    # timeline change could only come from the long prompt's chunking
    for budget in (16, 8):
        toks, deltas, gains, finish = serve(budget)
        assert toks == base, f"budget {budget} changed tokens"
        assert max(deltas) <= budget
        # one chunk tick for chat + exactly ceil(40/budget) for the long
        # prompt: the budget is spent, not hoarded
        assert sum(d > 0 for d in deltas) == 1 + -(-len(long_p) // budget)
        assert all(g == 1 for g in gains), "decode starved mid-prefill"
        assert finish == base_finish


def test_bf16_serve_params_spec_override():
    from repro.configs.base import SHAPES
    from repro.core.blueprint import suggest_plan
    from repro.launch.mesh import make_mesh_for
    from repro.launch.specs import abstract_params_only
    import dataclasses as dc
    cfg = REDUCED["qwen3-32b"]
    mesh = make_mesh_for(1, 1)
    plan = suggest_plan(cfg, SHAPES["decode_32k"], {"data": 1, "model": 1})
    plan_bf16 = dc.replace(plan, serve_param_dtype="bfloat16")
    p32 = abstract_params_only(cfg, mesh, plan)
    p16 = abstract_params_only(cfg, mesh, plan_bf16)
    l32 = jax.tree.leaves(p32)
    l16 = jax.tree.leaves(p16)
    assert any(l.dtype == jnp.float32 for l in l32)
    assert all(l.dtype != jnp.float32 for l in l16)
    assert sum(np.prod(l.shape) * l.dtype.itemsize for l in l16) < \
        sum(np.prod(l.shape) * l.dtype.itemsize for l in l32)
