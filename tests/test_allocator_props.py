"""PageAllocator live-resize invariants (hypothesis stateful testing).

The allocator is the serving engine's memory-safety keystone: admission
reservations, live grow, and drain-before-shrink all assume that at every
point in *any* operation sequence the page-id space partitions cleanly
into {free} ∪ {owned} ∪ {retired-by-pending-shrink} with the sink page in
none of them. These properties drive random interleavings of
alloc / free / grow / request_shrink / complete_shrink and check the
partition (free + used + retired == pool size − sink) plus
no-double-ownership after every step — the state-machine analogue of the
hand-written sequences in tests/test_autoscale.py.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

from repro.serving.paged_cache import SINK_PAGE, PageAllocator


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(8)
        self.owned = {}                    # page -> owner tag (shadow model)
        self.next_owner = 0

    # ------------------------------------------------------------- rules --
    @rule(n=st.integers(min_value=1, max_value=6))
    def alloc_pages(self, n):
        if self.alloc.can_alloc(n):
            pages = self.alloc.alloc(n, owner=self.next_owner)
            assert len(set(pages)) == n, "duplicate page in one alloc"
            assert SINK_PAGE not in pages, "sink page handed out"
            for p in pages:
                assert p not in self.owned, f"page {p} double-owned"
                self.owned[p] = self.next_owner
            self.next_owner += 1
        else:
            with pytest.raises(MemoryError):
                self.alloc.alloc(n)

    @precondition(lambda self: self.owned)
    @rule(data=st.data())
    def free_one_owner(self, data):
        owner = data.draw(st.sampled_from(
            sorted(set(self.owned.values()))), label="owner")
        pages = [p for p, o in self.owned.items() if o == owner]
        self.alloc.free(pages)
        for p in pages:
            del self.owned[p]
        with pytest.raises(ValueError):
            self.alloc.free(pages)         # double free always raises

    @rule(k=st.integers(min_value=0, max_value=8))
    def grow(self, k):
        self.alloc.grow(self.alloc.num_pages + k)
        assert not self.alloc.shrink_pending   # grow cancels pending shrinks

    @rule(data=st.data())
    def request_shrink(self, data):
        target = data.draw(st.integers(min_value=2,
                                       max_value=self.alloc.num_pages),
                           label="target")
        self.alloc.request_shrink(target)
        assert self.alloc.effective_pages == min(self.alloc.num_pages, target)

    @precondition(lambda self: self.alloc.shrink_ready())
    @rule()
    def complete_shrink(self):
        new = self.alloc.complete_shrink()
        assert new == self.alloc.num_pages
        assert not self.alloc.shrink_pending
        assert all(p < new for p in self.owned)

    # -------------------------------------------------------- invariants --
    @invariant()
    def partition_covers_pool(self):
        a = self.alloc
        free = set(a._free)
        owned = set(a._owner)
        every = set(range(1, a.num_pages))
        retired = every - free - owned
        # free + used + retired == pool size (sink excluded from all three)
        assert len(free) + len(owned) + len(retired) == a.num_pages - 1
        assert len(a._free) == len(free), "duplicate ids on the free list"
        assert not (free & owned), "page both free and owned"
        assert SINK_PAGE not in free and SINK_PAGE not in owned
        # retired pages exist only under a pending shrink, above its target
        if retired:
            assert a.shrink_pending
            assert all(p >= a._shrink_target for p in retired)
        # free pages below a pending shrink target only
        if a.shrink_pending:
            assert all(p < a._shrink_target for p in free)

    @invariant()
    def shadow_model_agrees(self):
        assert set(self.alloc._owner) == set(self.owned)
        assert self.alloc.num_allocated == len(self.owned)
        assert self.alloc.capacity >= 0


TestAllocatorProps = AllocatorMachine.TestCase
TestAllocatorProps.settings = settings(max_examples=60,
                                       stateful_step_count=40,
                                       deadline=None)
