"""Fleet autoscaling: the replica axis of the serving fabric.

PR 2's ``AutoscaleController`` moves capacity *within* one scheduler —
decode slots and page pool, plus the nodes backing them. This module adds
the second actuation axis the replicated fabric opens up: whole replicas.
The two compose: a ``FleetController`` optionally gives every replica its
own engine-level controller (slot/page resize inside the replica's
blueprint bands) while its fleet policy adds/removes fabric members on
fleet-wide queue depth.

Scale-out order is cheapest-first: un-drain a draining replica (instant —
its scheduler never went away), else add a fresh replica, acquiring a node
through ``ClusterLifecycle.extend`` when cluster-wired. Scale-in never
kills: the victim (least outstanding work; newest on ties) is *drained* —
routing stops, its admitted and queued streams finish — and only an empty
drained replica is removed and its node released. Replica death is the
involuntary path: a heartbeat DEAD host or a SimCloud spot preemption
fails every replica on the host, the router re-prefills the lost streams
on survivors (token-identical for dense/SSM archs), and the node is
replaced from the warm-spare pool when one is available.

Shard groups: with ``tp > 1`` on the router, the controller scales in
*group* units — every scale-out acquires ``tp`` nodes (one
``ClusterLifecycle.extend`` call, contiguous ranks), every completed drain
releases all ``tp``. A single preempted group *member* is the one failure
the group survives: when a warm spare exists the controller swaps the
node under its stable hostname and the group's streams never stop (the
surviving shards re-materialise the lost pool slice onto the spare);
only with no spare left does the whole group fail and its streams
re-route, with the surviving members' nodes released.

Disaggregated fleets (``router.disagg``) scale the two roles on separate
signals: prefill replicas on backlog *tokens* (queued prompts plus
in-flight chunk remainders, per prefill replica), decode replicas on
stream demand (active + parked-for-handoff, per decode replica) — long
prompts stress the first, long generations the second, and coupling them
under one ladder would overshoot whichever role is idle. Scale-in keeps
at least one replica per role; the colocated (non-disagg) path is
untouched.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.autoscale.controller import AutoscaleController, CapacityBands
from repro.autoscale.metrics import TelemetryBus
from repro.autoscale.policy import ScaleDecision, StepScalingPolicy
from repro.core.events import EventLog
from repro.serving.replica import ServingReplica
from repro.serving.router import ServingRouter


def default_role_policies(max_replicas: int, slots_per_replica: int,
                          prefill_budget: Optional[int] = None):
    """Separate ladders for a disaggregated fleet's two roles.

    Prefill replicas scale on *backlog tokens per prefill replica* — the
    prompt tokens queued or mid-chunk, normalised by the per-tick chunk
    budget (a replica retires about one budget's worth per tick, so
    ``2 * budget`` outstanding means ~2 ticks of prompt latency). Decode
    replicas scale on *streams per decode replica* against their slot
    count, the same ladder shape the colocated fleet uses. Each role keeps
    at least one replica — a fleet that can prefill but never decode (or
    vice versa) deadlocks.
    """
    b = float(max(prefill_budget or 8 * max(slots_per_replica, 1), 1))
    prefill = StepScalingPolicy(
        metric="prefill_backlog_per_replica",
        steps_out=[(2.0 * b, 1), (6.0 * b, 2)],
        scale_in_below=0.5 * b, scale_in_step=1,
        min_cap=1, max_cap=max_replicas,
        cooldown_out=2.0, cooldown_in=12.0, resource="prefill_replicas")
    s = max(slots_per_replica, 1)
    decode = StepScalingPolicy(
        metric="decode_demand_per_replica",
        steps_out=[(1.25 * s, 1), (3.0 * s, 2)],
        scale_in_below=0.5 * s, scale_in_step=1,
        min_cap=1, max_cap=max_replicas,
        cooldown_out=2.0, cooldown_in=12.0, resource="decode_replicas")
    return prefill, decode


def default_fleet_policy(min_replicas: int, max_replicas: int,
                         slots_per_replica: int) -> StepScalingPolicy:
    """Queue-depth ladder on fleet demand per live replica.

    Scale out when a replica's worth of extra demand is outstanding
    (demand = active + queued fleet-wide), again when three are; scale in
    when the whole window stayed under half a replica's slot width.
    Scale-in cooldown is the hysteresis: a drain takes ticks to empty, and
    re-draining every eval would thrash the router's candidate set.
    """
    s = max(slots_per_replica, 1)
    return StepScalingPolicy(
        metric="demand_per_replica",
        steps_out=[(1.25 * s, 1), (3.0 * s, 2)],
        scale_in_below=0.5 * s, scale_in_step=1,
        min_cap=min_replicas, max_cap=max_replicas,
        cooldown_out=2.0, cooldown_in=12.0, resource="replicas")


class FleetController:
    """Replica-count control loop over a ``ServingRouter``.

    ``replica_bands`` (a ``CapacityBands``) turns on within-replica
    autoscaling: each fabric member gets its own engine-only
    ``AutoscaleController`` so slots/pages track that replica's load while
    this controller tracks the fleet's.
    """

    def __init__(self, router: ServingRouter, *, min_replicas: int = 1,
                 max_replicas: int = 4, policy=None,
                 eval_interval: int = 4, tick_seconds: float = 1.0,
                 lifecycle=None, cluster=None, monitor=None,
                 replica_bands: Optional[CapacityBands] = None,
                 log: Optional[EventLog] = None, slo_monitors=None):
        self.router = router
        self.tp = router.replica_kw.get("tp", 1)   # nodes per shard group
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.policy = policy or default_fleet_policy(
            min_replicas, max_replicas, router.replica_kw["max_slots"])
        # disaggregated fleets scale the two roles on separate signals:
        # prefill on backlog tokens, decode on stream demand
        self.prefill_policy = self.decode_policy = None
        if router.disagg:
            self.prefill_policy, self.decode_policy = default_role_policies(
                max_replicas, router.replica_kw["max_slots"],
                router.replica_kw.get("prefill_budget"))
        self.eval_interval = eval_interval
        self.tick_seconds = tick_seconds
        self.lifecycle = lifecycle
        self.cluster = cluster
        self.monitor = monitor
        self.replica_bands = replica_bands
        self.bus = TelemetryBus()
        # SLO burn-rate monitors (repro.obs.slo): sampled every tick, their
        # slo_<name>_{burn_short,burn_long,firing} signals land on the bus
        # so a scaling policy can target burn rate like any other metric
        self.slo_monitors = list(slo_monitors or [])
        self.log = log if log is not None else (
            cluster.log if cluster is not None else EventLog())
        self.decisions: List[ScaleDecision] = []
        self.replica_ticks = 0.0
        self.capacity_log: List[tuple] = []    # (tick, live, draining)
        self._next_eval = router.step_idx
        self._inner: Dict[int, AutoscaleController] = {}
        if replica_bands is not None:
            for rep in router.replicas.values():
                self._attach_inner(rep)
        if monitor is not None:
            monitor.on_dead(self._on_host_dead)
        if lifecycle is not None and cluster is not None:
            lifecycle.cloud.on_preempt(self._on_preempt)

    # ------------------------------------------------------------- clock --
    @property
    def now(self) -> float:
        return self.router.step_idx * self.tick_seconds

    def _live(self) -> List[ServingReplica]:
        return [r for r in self.router.replicas.values() if r.live]

    def _draining(self) -> List[ServingReplica]:
        return [r for r in self.router.replicas.values()
                if r.draining and not r.failed]

    def _hit_rate(self) -> float:
        return self.router.prefix_hit_rate()

    # ---------------------------------------------------- inner controllers --
    def _attach_inner(self, rep: ServingReplica) -> None:
        if self.replica_bands is None:
            return
        self._inner[rep.replica_id] = AutoscaleController(
            rep.sched, self.replica_bands,
            eval_interval=self.eval_interval,
            tick_seconds=self.tick_seconds, log=self.log)

    # --------------------------------------------------------------- tick --
    def tick(self) -> None:
        """One fleet control pass; call before each ``router.step``."""
        live = self._live()
        self.replica_ticks += len(self.router.replicas) - sum(
            r.failed for r in self.router.replicas.values())
        self._finish_drains()
        demand = self.router.pending_due + sum(
            r.num_unfinished for r in live)
        sample = {
            "replicas": float(len(live)),
            "fleet_demand": float(demand),
            "demand_per_replica": demand / max(len(live), 1),
            "fleet_queue": float(self.router.pending_due),
            # prefix-cache hit rate scales each replica's effective
            # capacity: a hit skips the shared prefill and shares pages, so
            # at a given hit rate the same fleet absorbs more demand before
            # the ladder trips — see docs/autoscaling.md for retuning the
            # demand thresholds under shared-prefix traffic
            "fleet_hit_rate": self._hit_rate(),
            # host-tier working set: hot pages back live streams; retained
            # pages are reclaimable idle-session chains. Page-pressure
            # policies should key on the hot sum — a fleet full of parked
            # sessions is *not* a reason to add HBM (InstaCluster's
            # size-to-the-working-set argument applied to the KV pool)
            "fleet_hot_pages": float(sum(r.hot_pages for r in live)),
            "fleet_retained_pages": float(sum(
                r.sched.retained_page_count for r in live)),
            "fleet_host_pages": float(sum(
                r.sched.stats["host_pages_used"] for r in live)),
        }
        if self.router.disagg:
            n_pre = len(self.router.live_by_role("prefill"))
            n_dec = len([r for r in live if r.role != "prefill"])
            backlog = float(self.router.prefill_backlog())
            dem = float(self.router.decode_demand())
            sample.update({
                "prefill_replicas": float(n_pre),
                "decode_replicas": float(n_dec),
                "prefill_backlog": backlog,
                "prefill_backlog_per_replica": backlog / max(n_pre, 1),
                "decode_demand": dem,
                "decode_demand_per_replica": dem / max(n_dec, 1),
            })
        for m in self.slo_monitors:
            sample.update(m.sample(self.now))
        self.bus.record(self.now, sample)
        if self.router.step_idx >= self._next_eval:
            self._next_eval = self.router.step_idx + self.eval_interval
            self._evaluate()
        for rid, ctl in list(self._inner.items()):
            if rid in self.router.replicas \
                    and not self.router.replicas[rid].failed:
                ctl.tick()

    def _evaluate(self) -> None:
        if self.router.disagg:
            self._evaluate_role("prefill", self.prefill_policy)
            self._evaluate_role("decode", self.decode_policy)
            return
        d = self.policy.evaluate(
            self.now, self._windowed(self.policy.metric),
            len(self._live()))
        self._act(d)

    def _evaluate_role(self, role: str, policy) -> None:
        d = policy.evaluate(self.now, self._windowed(policy.metric),
                            len(self.router.live_by_role(role)))
        self._act(d, role=role)

    def _windowed(self, metric: str) -> float:
        return self.bus.max(metric,
                            self.eval_interval * self.tick_seconds)

    def _act(self, d, role: Optional[str] = None) -> None:
        if d is None:
            return
        self.decisions.append(d)
        self.log.emit(d.at, "autoscale", f"scale_{d.direction}",
                      resource=d.resource, desired=d.desired, delta=d.delta,
                      reason=d.reason)
        if self.router.tracer is not None:
            self.router.tracer.instant(
                "autoscale", t=self.router.step_idx,
                direction=d.direction, resource=d.resource,
                desired=d.desired, delta=d.delta, reason=d.reason,
                role=role)
        if d.delta > 0:
            self._scale_out(d.delta, role=role)
        else:
            self._scale_in(-d.delta, role=role)

    # ------------------------------------------------------------ actuate --
    def _scale_out(self, n: int, role: Optional[str] = None) -> None:
        for _ in range(n):
            if len(self._live()) >= self.max_replicas:
                return
            draining = [r for r in self._draining()
                        if role is None or r.role == role]
            if draining:
                # cheapest capacity: a drain not yet completed reverses
                rep = max(draining, key=lambda r: r.replica_id)
                self.router.undrain_replica(rep.replica_id)
                self.log.emit(self.now, "autoscale", "undrain_replica",
                              replica=rep.replica_id)
                continue
            hostnames = self._acquire_nodes()
            kw = {} if role is None else {"role": role}
            if self.tp > 1:
                rep = self.router.add_replica(hostnames=hostnames, **kw)
            else:
                rep = self.router.add_replica(
                    hostname=hostnames[0] if hostnames else None, **kw)
            self._attach_inner(rep)
            self.log.emit(self.now, "autoscale", "add_replica",
                          replica=rep.replica_id, role=rep.role,
                          hostname=hostnames[0] if hostnames else None,
                          nodes=len(hostnames) if hostnames else 0)

    def _scale_in(self, n: int, role: Optional[str] = None) -> None:
        for _ in range(n):
            live = self._live()
            if role is not None:
                live = [r for r in live if r.role == role]
                floor = 1          # both roles must survive — see
            else:                  # default_role_policies
                floor = self.min_replicas
            if len(live) <= floor:
                return
            # least outstanding work drains fastest; newest id on ties
            rep = min(live, key=lambda r: (r.outstanding_pages,
                                           -r.replica_id))
            self.router.drain_replica(rep.replica_id)
            self.log.emit(self.now, "autoscale", "drain_replica",
                          replica=rep.replica_id,
                          outstanding=rep.num_unfinished)

    def _finish_drains(self) -> None:
        for rep in self._draining():
            if rep.idle:
                hostnames = list(rep.hostnames)   # before removal purges them
                self.router.remove_replica(rep.replica_id)
                self._inner.pop(rep.replica_id, None)
                self.log.emit(self.now, "autoscale", "remove_replica",
                              replica=rep.replica_id,
                              hostname=hostnames[0] if hostnames else None)
                for hostname in hostnames:        # a group frees tp nodes
                    self._release_node(hostname)

    # -------------------------------------------------------------- nodes --
    def _acquire_nodes(self) -> Optional[List[str]]:
        """Acquire one replica's worth of nodes: ``tp`` per shard group,
        in one extend call so the group lands on contiguous ranks."""
        if self.lifecycle is None or self.cluster is None:
            return None
        nodes = self.lifecycle.extend(self.cluster, self.tp)
        if self.monitor is not None:
            for n in nodes:
                self.monitor.register(n.hostname,
                                      now=self.lifecycle.cloud.clock)
        return [n.hostname for n in nodes]

    def _release_node(self, hostname: Optional[str]) -> None:
        if hostname is None or self.lifecycle is None or self.cluster is None:
            return
        if hostname not in self.cluster.directory.nodes:
            return                           # already gone (failed host)
        # only release nodes no other replica still occupies
        if any(hostname in r.hostnames
               for r in self.router.replicas.values()):
            return
        self.lifecycle.shrink(self.cluster, [hostname])
        if self.monitor is not None:
            self.monitor.deregister(hostname)

    # ----------------------------------------------------------- failures --
    def _on_host_dead(self, hostname: str) -> None:
        """Heartbeat DEAD (or preemption) on a replica host.

        tp == 1 (or no spare): fail + re-route the replica's streams, then
        replace the node from the warm-spare pool when one exists (a fresh
        replica lands on the stable hostname).

        tp > 1 with a warm spare: *member replacement* — the spare swaps in
        under the dead member's stable hostname and the group keeps
        decoding; its streams, pools, and clocks never notice (the
        surviving tp-1 shards re-materialise the lost pool slice onto the
        spare). The group only fails — streams re-routed, surviving
        members' nodes released — when the spare pool is empty.
        """
        group = next((r for r in self.router.replicas.values()
                      if hostname in r.hostnames and not r.failed), None)
        if group is not None and group.tp > 1 and self.lifecycle is not None \
                and self.cluster is not None and self.lifecycle.spares:
            self.lifecycle.replace_failed(self.cluster, hostname)
            if self.monitor is not None:
                self.monitor.register(hostname,
                                      now=self.lifecycle.cloud.clock)
            self.log.emit(self.now, "autoscale", "shard_member_replaced",
                          hostname=hostname, replica=group.replica_id,
                          tp=group.tp)
            return
        had_replica = group is not None
        member_hosts = list(group.hostnames) if group is not None else []
        rerouted = self.router.fail_host(hostname)
        if not had_replica:
            return
        self.log.emit(self.now, "autoscale", "replica_failed",
                      hostname=hostname, rerouted=len(rerouted))
        if self.lifecycle is None or self.cluster is None:
            return
        # a failed group's surviving members are healthy nodes with nothing
        # to serve — release them before deciding on replacement capacity
        for other in member_hosts:
            if other != hostname:
                self._release_node(other)
        if self.lifecycle.spares and self.tp == 1:
            self.lifecycle.replace_failed(self.cluster, hostname)
            rep = self.router.add_replica(hostname=hostname)
            self._attach_inner(rep)
            self.log.emit(self.now, "autoscale", "preempt_replaced",
                          hostname=hostname, replica=rep.replica_id)
        else:
            if hostname in self.cluster.directory.nodes:
                self.lifecycle.shrink(self.cluster, [hostname])
            if self.monitor is not None:
                self.monitor.deregister(hostname)
            self.log.emit(self.now, "autoscale", "preempt_drained",
                          hostname=hostname)

    def _on_preempt(self, inst) -> None:
        if self.cluster is None:
            return
        for node in self.cluster.directory.slaves():
            if node.instance_id == inst.instance_id:
                self._on_host_dead(node.hostname)
                return

    # ---------------------------------------------------------------- run --
    def snapshot(self) -> None:
        self.capacity_log.append(
            (self.router.step_idx, len(self._live()),
             len(self._draining())))

    def run(self, max_steps: int = 100_000) -> list:
        router = self.router
        while router.num_unfinished and max_steps:
            self.tick()
            router.step(max_fuse=max(self.eval_interval, 1))
            self.snapshot()
            max_steps -= 1
        if router.num_unfinished:
            raise RuntimeError("fleet run exhausted max_steps")
        self.tick()                   # settle drains + accounting
        return router.finished

    # ------------------------------------------------------------ summary --
    def summary(self) -> Dict[str, Any]:
        return {
            "replica_seconds": self.replica_ticks * self.tick_seconds,
            "decisions": len(self.decisions),
            "scale_out": sum(1 for d in self.decisions if d.delta > 0),
            "scale_in": sum(1 for d in self.decisions if d.delta < 0),
            "peak_replicas": max((n for _, n, _ in self.capacity_log),
                                 default=len(self.router.replicas)),
            "final_replicas": len(self._live()),
            "reroutes": self.router.stats["reroutes"],
            "migrations": self.router.stats.get("migrations", 0),
            "prefix_hit_rate": round(self._hit_rate(), 3),
        }
