"""MoE routing invariants (hypothesis) + optimizer unit tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import REDUCED
from repro.models import moe as moe_mod
from repro.models.moe import _positions_in_expert, capacity, moe_apply
from repro.models.schema import init_params
from repro.optim.adamw import OptimConfig, global_norm, lr_at, opt_init, \
    opt_update

KEY = jax.random.PRNGKey(3)


# ------------------------------------------------------------ routing ----

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(2, 16), st.integers(1, 4))
def test_positions_in_expert_are_dense_ranks(n_tokens, n_expert, k):
    flat = np.asarray(jax.random.randint(
        jax.random.fold_in(KEY, n_tokens * 131 + n_expert * 7 + k),
        (n_tokens * k,), 0, n_expert))
    pos = np.asarray(_positions_in_expert(jnp.asarray(flat), n_expert))
    for e in range(n_expert):
        got = sorted(pos[flat == e].tolist())
        assert got == list(range(len(got)))   # dense 0..n_e-1 ranks
    # earlier slots win lower ranks (priority by token order)
    for e in range(n_expert):
        idxs = np.nonzero(flat == e)[0]
        assert (np.diff(pos[idxs]) > 0).all()


def _moe_cfg(**kw):
    cfg = REDUCED["qwen2-moe-a2.7b"]
    return dataclasses.replace(cfg, **kw)


def test_moe_capacity_drops_lowest_priority():
    cfg = _moe_cfg(moe_capacity_factor=0.25)
    p = init_params(moe_mod.moe_schema(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_moe_no_drops_at_high_capacity_matches_dense_gather():
    """With capacity >> needed, MoE output == explicit per-token expert sum."""
    cfg = _moe_cfg(moe_capacity_factor=8.0, n_shared_experts=0)
    p = init_params(moe_mod.moe_schema(cfg), KEY)
    B, S = 2, 8
    x = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    y, _ = moe_apply(cfg, p, x)

    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-20)  # norm_topk
    want = np.zeros_like(np.asarray(x))
    xin = np.asarray(x)
    for b in range(B):
        for s in range(S):
            for j in range(cfg.moe_top_k):
                e = int(idx[b, s, j])
                h = (jax.nn.silu(xin[b, s] @ p["w_gate"][e])
                     * (xin[b, s] @ p["w_up"][e]))
                want[b, s] += float(gates[b, s, j]) * np.asarray(
                    h @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_uniform_router_is_one_coef():
    """With a perfectly uniform router, E * sum(f_e * P_e) * k == k (Switch
    normalisation), so aux == coef * k."""
    cfg = _moe_cfg(router_aux_coef=0.01)
    p = init_params(moe_mod.moe_schema(cfg), KEY)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform probs
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_apply(cfg, p, x)
    assert abs(float(aux) - 0.01 * cfg.moe_top_k) < 2e-3


def test_decode_grouping_single_global_group():
    cfg = _moe_cfg()
    p = init_params(moe_mod.moe_schema(cfg), KEY)
    x = jax.random.normal(KEY, (4, 1, cfg.d_model), jnp.float32)
    y, _ = moe_apply(cfg, p, x, decode=True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_capacity_formula():
    cfg = _moe_cfg(moe_capacity_factor=1.25)
    c = capacity(cfg, 4096)
    assert c == int(np.ceil(4096 * cfg.moe_top_k / cfg.n_routed_experts
                            * 1.25))
    assert capacity(cfg, 1) >= 1


# ----------------------------------------------------------- optimizer ----

def test_lr_schedule_shape():
    o = OptimConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    # (step+1)/warmup ramp: step 0 already has a non-zero lr
    assert abs(float(lr_at(o, jnp.asarray(0))) - 0.1) < 1e-6
    assert abs(float(lr_at(o, jnp.asarray(9))) - 1.0) < 1e-6
    assert abs(float(lr_at(o, jnp.asarray(110))) - 0.1) < 1e-6
    mid = float(lr_at(o, jnp.asarray(60)))
    assert 0.1 < mid < 1.0


def test_adamw_clip_and_decay():
    o = OptimConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                    clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}   # huge -> clipped
    st_ = opt_init(params)
    new_p, m, v, gn = opt_update(o, params, grads, st_["m"], st_["v"],
                                 jnp.asarray(0))
    assert float(gn) == pytest.approx(np.sqrt(16 * 100.0 ** 2), rel=1e-5)
    # update magnitude bounded by lr (Adam normalises) regardless of scale
    delta = np.abs(np.asarray(new_p["w"] - params["w"]))
    assert delta.max() <= 1e-2 * 1.2


def test_adamw_deterministic():
    o = OptimConfig()
    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    s = opt_init(params)
    a = opt_update(o, params, grads, s["m"], s["v"], jnp.asarray(5))
    b = opt_update(o, params, grads, s["m"], s["v"], jnp.asarray(5))
    np.testing.assert_array_equal(np.asarray(a[0]["w"]),
                                  np.asarray(b[0]["w"]))


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
