"""Pure-jnp oracles for every kernel (ground truth for allclose tests)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """Naive full-matrix attention. q: (B,Sq,H,d), k/v: (B,Skv,KVH,d)."""
    B, Sq, H, d = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, d)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, *, softcap=None, scale=None,
                         valid_len=None):
    """q: (B,H,d); caches: (B,S,KVH,d) -> (B,H,d)."""
    B, H, d = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(B, KVH, G, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if valid_len is not None:
        ok = jnp.arange(S)[None] < valid_len[:, None]
        s = jnp.where(ok[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_table, seq_lens, *,
                               softcap=None, window=None, scale=None):
    """Oracle for the paged flash-decode kernel: gather pages, mask, attend.

    q: (B,H,d); pools: (P,ps,KVH,d); block_table: (B,n_pg) int32;
    seq_lens: (B,) live token counts -> (B,H,d).
    """
    B = q.shape[0]
    ps = k_pages.shape[1]
    n_pg = block_table.shape[1]
    k = k_pages[block_table].reshape(B, n_pg * ps, *k_pages.shape[2:])
    v = v_pages[block_table].reshape(B, n_pg * ps, *v_pages.shape[2:])
    if window is None:
        return decode_attention_ref(q, k, v, softcap=softcap, scale=scale,
                                    valid_len=seq_lens)
    pos = jnp.arange(n_pg * ps)[None]
    ok = (pos < seq_lens[:, None]) & (pos >= seq_lens[:, None] - window)
    H, d = q.shape[1:]
    KVH = k.shape[2]
    G = H // KVH
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(B, KVH, G, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32)) * sc
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(ok[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, d).astype(q.dtype)


def paged_prefill_write_ref(k_new, v_new, pool, block_table, start,
                            chunk_lens, *, quant=None):
    """Oracle for the paged-prefill write kernel: quantise + scatter.

    k_new/v_new: (B,S,KVH,d) chunk K/V (rows past chunk_lens[b] are
    padding); pool: dict with k_pages/v_pages (P,ps,KVH,d) and, when
    ``quant`` ("int8"/"fp8") is set, fp32 scale planes (P,ps,KVH).
    Chunk token t of sequence b lands at absolute position start[b]+t in
    the pages named by block_table[b]. Returns the updated pool dict.
    """
    from repro.models.attention import quantize_kv
    B, S = k_new.shape[:2]
    ps = pool["k_pages"].shape[1]
    n_pg = block_table.shape[1]
    pos = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None]     # (B,S)
    live = jnp.arange(S, dtype=jnp.int32)[None] < chunk_lens[:, None]
    # dead rows route to sink (0, 0) and re-write its existing value, so
    # they can never clobber a live row's slot via scatter duplicate-index
    pg_idx = jnp.clip(pos // ps, 0, n_pg - 1)
    page = jnp.where(live, jnp.take_along_axis(block_table, pg_idx, axis=1), 0)
    slot = jnp.where(live, pos % ps, 0)
    out = dict(pool)
    for name, val in (("k", k_new), ("v", v_new)):
        if quant:
            qv, sc = quantize_kv(val, quant)
            old_q = out[f"{name}_pages"][page, slot]
            old_s = out[f"{name}_scale_pages"][page, slot]
            qv = jnp.where(live[..., None, None], qv, old_q)
            sc = jnp.where(live[..., None], sc, old_s)
            out[f"{name}_pages"] = out[f"{name}_pages"].at[page, slot].set(qv)
            out[f"{name}_scale_pages"] = \
                out[f"{name}_scale_pages"].at[page, slot].set(sc)
        else:
            dt = out[f"{name}_pages"].dtype
            old = out[f"{name}_pages"][page, slot]
            vv = jnp.where(live[..., None, None], val.astype(dt), old)
            out[f"{name}_pages"] = out[f"{name}_pages"].at[page, slot].set(vv)
    return out


def paged_prefill_attention_ref(q, k_pages, v_pages, block_table, start,
                                chunk_lens, *, k_scale_pages=None,
                                v_scale_pages=None, softcap=None,
                                window=None, scale=None):
    """Oracle for the paged-prefill attend kernel (call after the write):
    gather the pages (prefix AND chunk tokens), dequantise, mask per
    absolute query position, attend.

    q: (B,S,H,d) — query t of sequence b sits at absolute position
    start[b]+t; rows past chunk_lens[b] are padding (output unspecified).
    """
    B, S, H, d = q.shape
    ps, KVH = k_pages.shape[1], k_pages.shape[2]
    n_pg = block_table.shape[1]
    G = H // KVH
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    k = k_pages[block_table].reshape(B, n_pg * ps, KVH, d).astype(jnp.float32)
    v = v_pages[block_table].reshape(B, n_pg * ps, KVH, d).astype(jnp.float32)
    if k_scale_pages is not None:
        k = k * k_scale_pages[block_table].reshape(B, n_pg * ps, KVH)[..., None]
        v = v * v_scale_pages[block_table].reshape(B, n_pg * ps, KVH)[..., None]
    qf = q.astype(jnp.float32).reshape(B, S, KVH, G, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k) * sc
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_abs = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None]   # (B,S)
    k_pos = jnp.arange(n_pg * ps, dtype=jnp.int32)
    ok = (k_pos[None, None] <= q_abs[..., None]) \
        & (k_pos[None, None] < (start + chunk_lens)[:, None, None])
    if window is not None:
        ok &= (q_abs[..., None] - k_pos[None, None]) < window
    s = jnp.where(ok[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, S, H, d).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, h0=None):
    """Sequential SSD recurrence (exact oracle).

    x: (B,S,H,P)  dt: (B,S,H) fp32  A: (H,)  Bm/Cm: (B,S,G,N) with H%G==0.
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t h_t.
    Returns y (B,S,H,P), h_final (B,H,N,P) fp32.
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)   # (B,S,H,N)
    cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
    a = dt.astype(jnp.float32) * A.astype(jnp.float32)     # (B,S,H)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, t):
        decay = jnp.exp(a[:, t])[..., None, None]          # (B,H,1,1)
        upd = jnp.einsum("bhn,bh,bhp->bhnp", bf[:, t], dt[:, t].astype(
            jnp.float32), xf[:, t])
        h = h * decay + upd
        y = jnp.einsum("bhn,bhnp->bhp", cf[:, t], h)
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)             # (B,S,H,P)
    return y, h
