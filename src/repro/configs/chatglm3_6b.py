"""chatglm3-6b [dense] — 2d RoPE (rotary on half the head dims), GQA kv=2.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 [arXiv:2406.12793; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_variant="half2d",
    qkv_bias=True,
    tie_embeddings=False,
    rms_eps=1e-5,
)

REDUCED = ModelConfig(
    name="chatglm3-6b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    rope_variant="half2d",
    qkv_bias=True,
    tie_embeddings=False,
)
