"""Typed metrics registry: counters, gauges, log-bucket histograms.

The serving layers used to keep ad-hoc ``Dict[str, int]`` stats with four
different shapes (``scheduler.stats``, ``router.stats``, ``fleet_stats``,
autoscale samples) and every latency percentile came from numpy over
retained samples in ``benchmarks/serve_bench.py``. This module gives the
fleet one vocabulary:

* ``Counter`` / ``Gauge`` — a named monotonic total / point-in-time value;
* ``Histogram`` — fixed log-spaced buckets (``log_buckets``), so p50/p99/
  p999 are computable in O(buckets) without retaining samples, and two
  replicas' histograms merge by adding bucket counts (the fleet view);
* ``MetricsRegistry`` — get-or-create by name, Prometheus-style text
  exposition (``expose``);
* ``StatsView`` — a ``MutableMapping`` facade over registry metrics that
  preserves the existing ``stats`` dict contract (``stats["x"] += 1``,
  ``dict(stats)``, ``stats.get``) while every mutation lands on a typed
  metric, so ``stats()`` / ``fleet_stats()`` / ``shard_stats()`` keep
  their return shapes and the registry sees every count.

One shared percentile definition lives here too: ``percentile`` is the
nearest-rank estimator used by both the benches (over retained samples)
and ``Histogram.quantile`` (over bucket counts) — a histogram quantile is
the containing bucket's upper bound, so it agrees with the sample
nearest-rank within one bucket's relative error (the bucket growth
factor; see tests/test_obs_metrics.py).
"""
from __future__ import annotations

import bisect
import math
import re
from collections.abc import MutableMapping
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
           "log_buckets", "nearest_rank", "percentile",
           "TICK_BUCKETS", "SECONDS_BUCKETS"]


# ---------------------------------------------------------------- buckets --

def log_buckets(lo: float, hi: float, per_decade: int = 4) -> Tuple[float, ...]:
    """Strictly increasing log-spaced bucket bounds from ``lo`` until the
    first bound >= ``hi``, ``per_decade`` buckets per factor of 10.

    The growth factor ``10 ** (1/per_decade)`` bounds the relative error
    of any quantile read from the histogram: a value lands in the bucket
    whose upper bound is at most ``factor`` times the value.

    Bounds are computed by direct exponentiation (``lo * 10**(i/per_decade)``)
    rather than repeated multiplication: accumulating the step made decade
    bounds drift (``9.999999999999998`` instead of ``10.0``), so an integer
    observation sitting exactly on a nominal bound landed one full bucket
    high and ``Histogram.quantile`` disagreed with ``nearest_rank`` by a
    whole growth factor on boundary-valued data. With exact decade bounds
    (``10**k`` is exact in binary float) the two estimators agree exactly
    whenever every observation equals a bucket bound.
    """
    if lo <= 0:
        raise ValueError(f"log buckets need lo > 0, got {lo}")
    if hi <= lo:
        raise ValueError(f"log buckets need hi > lo, got [{lo}, {hi}]")
    if per_decade < 1:
        raise ValueError("per_decade must be >= 1")
    out = [float(lo)]
    i = 1
    while out[-1] < hi:
        out.append(float(lo) * 10.0 ** (i / per_decade))
        i += 1
    return tuple(out)


# latency-in-ticks histograms (queue wait, TTFT, request latency): the sim
# clock is integer ticks, max_seq_len-scale runs stay inside a few thousand
TICK_BUCKETS = log_buckets(1.0, 4096.0, per_decade=4)
# wall-clock seconds (per-tick step walls, kernel dispatch walls)
SECONDS_BUCKETS = log_buckets(1e-6, 64.0, per_decade=4)


# ------------------------------------------------------------- percentile --

def nearest_rank(values: Iterable[float], q: float) -> float:
    """Nearest-rank percentile: the smallest sample with at least ``q``%
    of the sample at or below it (rank ``ceil(q/100 * N)``, 1-based).

    Unlike ``np.percentile``'s interpolation this always returns an
    observed value, which is what a bucketed histogram can agree with —
    the single percentile definition shared by ``benchmarks/serve_bench``
    and ``Histogram.quantile``.
    """
    vals = sorted(float(v) for v in values)
    if not vals:
        raise ValueError("percentile of an empty sample")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    rank = max(1, math.ceil(q / 100.0 * len(vals)))
    return vals[rank - 1]


percentile = nearest_rank


# ---------------------------------------------------------------- metrics --

class Counter:
    """Monotonic total. ``value`` is directly settable so ``StatsView``
    can preserve the ``stats[k] += n`` idiom."""
    kind = "counter"
    __slots__ = ("name", "help", "unit", "value")

    def __init__(self, name: str, help: str = "", unit: str = ""):
        self.name = name
        self.help = help
        self.unit = unit
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (e.g. ``peak_pages``, live slot count)."""
    kind = "gauge"
    __slots__ = ("name", "help", "unit", "value")

    def __init__(self, name: str, help: str = "", unit: str = ""):
        self.name = name
        self.help = help
        self.unit = unit
        self.value = 0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Fixed-bound histogram: ``counts[i]`` observations in
    ``(bounds[i-1], bounds[i]]`` plus one overflow bucket past the end.

    ``quantile`` is nearest-rank over the cumulative counts and returns
    the containing bucket's *upper bound* (``inf`` for overflow) — an
    upper estimate within one bucket's relative error of the sample
    percentile for values inside the bucket range.
    """
    kind = "histogram"
    __slots__ = ("name", "help", "unit", "bounds", "counts", "sum")

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = "", unit: str = ""):
        bounds = tuple(float(b) for b in buckets)
        if not bounds:
            raise ValueError(f"histogram {name}: needs at least one bucket")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name}: bounds must be strictly increasing, "
                f"got {bounds}")
        self.name = name
        self.help = help
        self.unit = unit
        self.bounds = bounds
        self.counts: List[int] = [0] * (len(bounds) + 1)
        self.sum = 0.0

    @property
    def count(self) -> int:
        return sum(self.counts)

    def observe(self, v) -> None:
        v = float(v)
        self.sum += v
        self.counts[bisect.bisect_left(self.bounds, v)] += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Add ``other``'s counts into this histogram (the per-replica ->
        fleet aggregation); bounds must match exactly."""
        if other.bounds != self.bounds:
            raise ValueError(
                f"cannot merge histogram {other.name} into {self.name}: "
                f"bucket bounds differ")
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.sum += other.sum
        return self

    def quantile(self, q: float) -> float:
        """O(buckets) nearest-rank quantile; 0.0 on an empty histogram,
        ``inf`` when the rank lands in the overflow bucket."""
        n = self.count
        if n == 0:
            return 0.0
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"quantile q must be in [0, 100], got {q}")
        rank = max(1, math.ceil(q / 100.0 * n))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[i] if i < len(self.bounds) else math.inf
        return math.inf                      # pragma: no cover - unreachable


# ----------------------------------------------------------------- registry --

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _expo_val(v) -> str:
    f = float(v)
    if math.isinf(f):
        return "+Inf"
    if f.is_integer():
        return str(int(f))
    return repr(f)


def _expo_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class MetricsRegistry:
    """Named metrics with get-or-create semantics and text exposition.

    One registry per control plane: each scheduler owns one (its replica's
    metrics), the router owns a fleet-level one; ``labels`` (e.g.
    ``{"replica": "2", "role": "decode"}``) are applied to every sample at
    exposition time so the fleet's concatenated output stays unambiguous.
    """

    def __init__(self, namespace: str = "repro",
                 labels: Optional[Dict[str, str]] = None):
        self.namespace = namespace
        self.labels: Dict[str, str] = dict(labels or {})
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, unit: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help=help, unit=unit, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        return self._get(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        return self._get(Gauge, name, help, unit)

    def histogram(self, name: str,
                  buckets: Sequence[float] = TICK_BUCKETS,
                  help: str = "", unit: str = "") -> Histogram:
        return self._get(Histogram, name, help, unit, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def metrics(self) -> List[object]:
        return list(self._metrics.values())

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    # -------------------------------------------------------- exposition --
    def expose(self, extra_labels: Optional[Dict[str, str]] = None) -> str:
        """Prometheus text exposition of every registered metric."""
        labels = {**self.labels, **(extra_labels or {})}
        lines: List[str] = []
        for m in self._metrics.values():
            full = _NAME_RE.sub("_", f"{self.namespace}_{m.name}")
            if m.help:
                lines.append(f"# HELP {full} {m.help}")
            lines.append(f"# TYPE {full} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for bound, c in zip(m.bounds, m.counts):
                    cum += c
                    lab = _expo_labels({**labels, "le": _expo_val(bound)})
                    lines.append(f"{full}_bucket{lab} {cum}")
                lab = _expo_labels({**labels, "le": "+Inf"})
                lines.append(f"{full}_bucket{lab} {m.count}")
                lines.append(f"{full}_sum{_expo_labels(labels)} "
                             f"{_expo_val(m.sum)}")
                lines.append(f"{full}_count{_expo_labels(labels)} {m.count}")
            else:
                lines.append(f"{full}{_expo_labels(labels)} "
                             f"{_expo_val(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------- StatsView --

class StatsView(MutableMapping):
    """The scheduler/router ``stats`` dict, re-plumbed onto the registry.

    Every existing idiom keeps working — ``stats["x"] += 1`` (read +
    write-back through the metric), ``stats["peak"] = max(...)``,
    ``dict(stats)``, ``stats.get(k, 0)``, stat-delta dict comprehensions —
    while each key is backed by a live ``Counter``/``Gauge``, so the typed
    registry (and its exposition) sees the same numbers the legacy dict
    consumers do. The key set is fixed at construction: adding or deleting
    keys raises, which is what kept the four ad-hoc dicts shape-compatible
    by convention and is now enforced.
    """

    def __init__(self, metrics: Dict[str, object]):
        self._m = dict(metrics)

    def __getitem__(self, key):
        return self._m[key].value

    def __setitem__(self, key, value) -> None:
        try:
            self._m[key].value = value
        except KeyError:
            raise KeyError(
                f"stats key {key!r} is not registered (keys are fixed at "
                f"construction: {sorted(self._m)})") from None

    def __delitem__(self, key) -> None:
        raise TypeError("stats keys are fixed; cannot delete")

    def __iter__(self):
        return iter(self._m)

    def __len__(self) -> int:
        return len(self._m)

    def __eq__(self, other) -> bool:
        if isinstance(other, (dict, StatsView)):
            return dict(self) == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def metric(self, key: str):
        """The underlying ``Counter``/``Gauge`` object for ``key``."""
        return self._m[key]

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"
