"""Paper-faithfulness tests: Fig. 1 sequence, security flow, Tables 1-2."""
import pytest

from repro.core.cluster import ClusterManager, RegionOccupiedError
from repro.core.provisioner import ClusterProvisioner
from repro.core.services import PORTS, SERVICE_MATRIX, AmbariServer
from repro.core.simcloud import AccessKeyError, InstanceState, SimCloud


def make_provisioner(deactivate=False):
    cloud = SimCloud(seed=7)
    cloud.register_key("AK", "SK")
    prov = ClusterProvisioner(cloud, region="us-east-1", access_key_id="AK",
                              secret_key="SK",
                              deactivate_key_after_discovery=deactivate)
    return cloud, prov


def test_figure1_sequence():
    """The provisioning event log follows the paper's Fig. 1 exactly."""
    _, prov = make_provisioner()
    cluster = prov.provision(n_slaves=4)
    cluster.log.assert_order(
        "spawn_slave",
        "create_temp_user",
        "install_agent",
        "spawn_master",
        "query_ec2_slaves",
        "assign_hostnames",
        "update_hosts_file",
        "generate_keypair",
        "distribute_keypair_hosts",
        "delete_temp_user",
        "tag_instances",
        "install_ambari_server",
        "start_ambari_server",
    )


def test_slave_steps_precede_master_discovery():
    _, prov = make_provisioner()
    cluster = prov.provision(n_slaves=2)
    log = cluster.log
    assert log.last_index("install_agent") < log.first_index(
        "query_ec2_slaves")


def test_temp_user_window_closes():
    """Security: temp user (password auth) deleted once keys distributed."""
    _, prov = make_provisioner()
    cluster = prov.provision(n_slaves=3)
    assert not any(cluster.security.temp_user_active.values())
    log = cluster.log
    assert log.first_index("distribute_keypair_hosts") < log.first_index(
        "delete_temp_user")


def test_hostnames_and_tags():
    cloud, prov = make_provisioner()
    cluster = prov.provision(n_slaves=3)
    hosts = cluster.directory.hosts_file()
    assert "master" in hosts and "slave-0" in hosts and "slave-2" in hosts
    for node in cluster.directory.slaves():
        inst = cloud.instances[node.instance_id]
        assert inst.tags["instacluster:role"] == node.hostname


def test_key_deactivation_after_discovery():
    cloud, prov = make_provisioner(deactivate=True)
    prov.provision(n_slaves=2)
    assert "AK" not in cloud.active_keys
    with pytest.raises(AccessKeyError):
        cloud.describe_instances(region="us-east-1", access_key_id="AK")


def test_key_deactivation_skipped_for_spot():
    """Paper: deactivation advisable only if NOT using spot (restarts need
    live keys)."""
    cloud, prov = make_provisioner(deactivate=True)
    cluster = prov.provision(n_slaves=2, spot=True)
    assert "AK" in cloud.active_keys
    assert "skip_key_deactivation" in cluster.log.actions()


def test_keypair_regenerated_on_rediscovery():
    _, prov = make_provisioner()
    cluster = prov.provision(n_slaves=2)
    g1 = cluster.security.keypair_generation
    kp1 = cluster.security.cluster_keypair
    prov.rediscover(cluster)
    assert cluster.security.keypair_generation == g1 + 1
    assert cluster.security.cluster_keypair != kp1


def test_restart_remaps_private_ips():
    cloud, prov = make_provisioner()
    cluster = prov.provision(n_slaves=4)
    old_ips = {n.hostname: n.private_ip
               for n in cluster.directory.nodes.values()}
    cloud.stop_instances(cluster.instance_ids, "AK")
    cloud.start_instances(cluster.instance_ids, "AK")
    changed = prov.rediscover(cluster)
    assert changed, "restart must change at least one private IP"
    for hn in changed:
        assert cluster.directory.nodes[hn].private_ip != old_ips[hn]
    # hosts file reflects new IPs
    hosts = cluster.directory.hosts_file()
    for n in cluster.directory.nodes.values():
        assert f"{n.private_ip}\t{n.hostname}" in hosts


# ---------------------------------------------------------------- Table 1 --

def test_table1_every_provisionable_service_installs():
    cloud, prov = make_provisioner()
    cluster = prov.provision(n_slaves=4)
    ambari = AmbariServer(cloud, cluster)
    provisionable = [n for n, (p, _, _) in SERVICE_MATRIX.items()
                     if p is not None]
    ambari.install(provisionable)
    for name in provisionable:
        ambari.start(name)
    assert set(ambari.status()) == set(provisionable)
    assert all(v == "started" for v in ambari.status().values())


def test_table1_ns_services_rejected():
    cloud, prov = make_provisioner()
    cluster = prov.provision(n_slaves=1)
    ambari = AmbariServer(cloud, cluster)
    with pytest.raises(ValueError):
        ambari.install(["impala"])   # n/s in Table 1


# ---------------------------------------------------------------- Table 2 --

def test_table2_ports():
    assert PORTS["spark-driver"] == 7077
    assert PORTS["spark-webui"] == 8888
    assert PORTS["spark-jobserver"] == 8090
    assert PORTS["hue"] == 8808
    assert PORTS["ambari"] == 8080


# ------------------------------------------------------- region limitation --

def test_one_cluster_per_region_limit_and_lift():
    mgr = ClusterManager()
    mgr.build_cluster(n_slaves=2)
    with pytest.raises(RegionOccupiedError):
        mgr.build_cluster(n_slaves=2)
    mgr2 = ClusterManager(allow_multiple_per_region=True)
    mgr2.build_cluster(n_slaves=2)
    mgr2.build_cluster(n_slaves=2)       # beyond-paper: now allowed
    assert len(mgr2.clusters("us-east-1")) == 2


def test_cluster_spec_roundtrip():
    """Paper §4: researchers share (type, count, config) for reproduction."""
    mgr = ClusterManager(allow_multiple_per_region=True)
    a = mgr.build_cluster(n_slaves=3, services=("hdfs", "spark", "hue"))
    b = mgr.build_from_spec(a.spec(), region="eu-west-1")
    assert b.cluster.spec()["n_slaves"] == 3
    assert set(b.ambari.services) == set(a.ambari.services)
