"""Serving-engine ring buffers, schema/sharding properties, rope identities,
checkpoint integrity — coverage beyond the core suites."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.models.schema import ParamSpec, resolve_pspec
from repro.serving import engine as E

KEY = jax.random.PRNGKey(11)


# ------------------------------------------------------- ring-buffer decode --

def test_sliding_window_ring_matches_full_context():
    """Decoding past the window with a ring cache == full forward with the
    same window (gemma2 local layers)."""
    cfg = dataclasses.replace(REDUCED["gemma2-2b"], dtype="float32",
                              sliding_window=8,
                              layer_pattern=("attn_local",))
    params = M.init(cfg, KEY)
    B, S = 1, 24          # 3x window
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    ref_lg, _ = M.prefill(cfg, params, {"tokens": tokens})
    _, cache, cur = E.prefill(cfg, params, {"tokens": tokens[:, :S]}, S + 8)
    lg, _ = E.decode_step(cfg, params, cache, tokens[:, S:S + 1], cur)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_lg),
                               rtol=1e-4, atol=1e-4)


def test_multi_step_decode_matches_incremental_prefill():
    """N decode steps == prefill at each longer prefix (teacher forcing)."""
    cfg = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")
    params = M.init(cfg, KEY)
    B, S, N = 1, 8, 4
    tokens = jax.random.randint(KEY, (B, S + N, ), 0, cfg.vocab_size)
    _, cache, cur = E.prefill(cfg, params, {"tokens": tokens[:, :S]}, S + N)
    for t in range(N):
        lg, cache = E.decode_step(cfg, params, cache, tokens[:, S + t:S + t + 1],
                                  cur)
        cur = cur + 1
        ref, _ = M.prefill(cfg, params, {"tokens": tokens[:, :S + t + 1]})
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_greedy_decode_deterministic():
    cfg = REDUCED["mamba2-1.3b"]
    params = M.init(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)}
    outs = []
    for _ in range(2):
        lg, cache, cur = E.prefill(cfg, params, batch, capacity=32)
        first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
            jnp.int32)[:, None]
        toks, _, _ = E.greedy_decode(cfg, params, cache, first, cur, 6)
        outs.append(np.asarray(toks))
    np.testing.assert_array_equal(outs[0], outs[1])


# ----------------------------------------------------- schema properties --

class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self._shape = tuple(sizes.values())

    @property
    def devices(self):
        import numpy as _np
        return _np.zeros(self._shape)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([(128, 64), (60, 16), (2304, 2048), (7, 13)]),
       st.sampled_from([{"data": 16, "model": 16},
                        {"pod": 2, "data": 16, "model": 16},
                        {"data": 4, "model": 2}]))
def test_resolve_pspec_invariants(shape, sizes):
    mesh = _FakeMesh(sizes)
    rules = {"a": ("model",), "b": ("pod", "data")}
    spec = resolve_pspec(("a", "b"), shape, rules, mesh)
    used = []
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for ax in axes:
            assert ax in sizes          # only real mesh axes
            assert ax not in used       # each mesh axis used at most once
            used.append(ax)
            prod *= sizes[ax]
        assert shape[i] % prod == 0     # always divisible


def test_resolve_pspec_falls_through_on_indivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 60 does not divide by 16 -> experts rule skipped, ff used instead
    spec = resolve_pspec(("experts", "expert_ff"), (60, 1408),
                         {"experts": ("model",), "expert_ff": ("model",)},
                         mesh)
    assert spec[0] is None and spec[1] == "model"


# ------------------------------------------------------------ rope identities --

def test_mrope_equals_standard_rope_for_text():
    """With equal t/h/w position ids, M-RoPE must reduce to standard RoPE."""
    from repro.models.rope import rope_cos_sin
    std_cfg = REDUCED["qwen1.5-110b"]
    vl_cfg = REDUCED["qwen2-vl-72b"]
    assert std_cfg.resolved_head_dim == vl_cfg.resolved_head_dim
    B, S = 2, 16
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    c1, s1 = rope_cos_sin(std_cfg, pos)
    c2, s2 = rope_cos_sin(vl_cfg, jnp.broadcast_to(pos[None], (3, B, S)))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_half2d_rope_leaves_second_half_untouched():
    from repro.models.rope import apply_rope, rope_cos_sin
    cfg = REDUCED["chatglm3-6b"]
    B, S, H, hd = 1, 8, 2, cfg.resolved_head_dim
    x = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    cos, sin = rope_cos_sin(cfg, jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32), (B, S)))
    y = apply_rope(x, cos, sin, hd // 2)
    np.testing.assert_array_equal(np.asarray(y[..., hd // 2:]),
                                  np.asarray(x[..., hd // 2:]))
    assert not np.allclose(np.asarray(y[..., :hd // 2]),
                           np.asarray(x[..., :hd // 2]))


# ------------------------------------------------------ checkpoint integrity --

def test_checkpoint_checksum_verification(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    ck = CheckpointManager(str(tmp_path), async_writes=False)
    ck.save({"w": jnp.arange(8.0)}, 0, blocking=True)
    # corrupt the leaf on disk
    leaf = next((tmp_path / "step_00000000").glob("leaf_*.npy"))
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError):
        ck.restore(0, verify=True)
    # unverified restore still loads (operator's choice)
    out = ck.restore(0, verify=False)
    assert float(out["w"][0]) == 999.0


def test_cache_schema_matches_decode_structure():
    """init_cache trees must be structurally identical to what decode
    returns (scan carries require exact pytree match)."""
    for name in ("gemma2-2b", "jamba-v0.1-52b", "deepseek-v2-236b",
                 "whisper-tiny"):
        cfg = REDUCED[name]
        params = M.init(cfg, KEY)
        B, S = 1, 8
        batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
        if cfg.is_encdec:
            batch["enc_embeds"] = jax.random.normal(
                KEY, (B, cfg.enc_positions, cfg.d_model), jnp.float32)
        _, cache, cur = E.prefill(cfg, params, batch, capacity=S + 4)
        tok = jnp.zeros((B, 1), jnp.int32)
        _, cache2 = E.decode_step(cfg, params, cache, tok, cur)
        assert (jax.tree.structure(cache) == jax.tree.structure(cache2)), name
        a = jax.tree.map(lambda x: (x.shape, str(x.dtype)), cache)
        b = jax.tree.map(lambda x: (x.shape, str(x.dtype)), cache2)
        assert a == b, name
