"""Scaling policies: target tracking and step scaling, with hysteresis.

Both policy kinds map a windowed metric value to a desired capacity and
emit a typed ``ScaleDecision`` only when an actual change should happen.
The guards that keep the loop from flapping live here, not in the
actuator:

* **deadband** (target tracking) — no decision while the metric sits
  within ``tolerance`` of the target;
* **cooldown** — per-direction minimum spacing between decisions, with
  scale-in typically slower than scale-out (AWS-style asymmetry: adding
  capacity is urgent, removing it is housekeeping);
* **bounds** — desired capacity is clamped to ``[min_cap, max_cap]``
  before the decision is emitted (the blueprint's capacity bands).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """A typed resize the actuator should carry out."""
    resource: str            # "slots" | "pages" | "nodes"
    desired: int             # absolute target capacity
    delta: int               # desired - current
    reason: str
    at: float                # decision clock

    @property
    def direction(self) -> str:
        return "out" if self.delta > 0 else "in"


class _CooldownMixin:
    def _cooled_down(self, now: float, direction: str) -> bool:
        last = self._last_action.get(direction)
        wait = (self.cooldown_out if direction == "out"
                else self.cooldown_in)
        return last is None or now - last >= wait

    def _note(self, now: float, direction: str) -> None:
        self._last_action[direction] = now


class TargetTrackingPolicy(_CooldownMixin):
    """Keep ``metric`` near ``target`` by scaling capacity proportionally.

    ``metric`` is read as *per-unit-of-capacity load* (e.g. slot occupancy
    ``(active + queued) / slots``), so the proportional desired capacity is
    ``ceil(current * metric / target)`` — the same control law as AWS
    target tracking. ``tolerance`` is the relative deadband around the
    target inside which no decision fires.
    """

    def __init__(self, *, metric: str, target: float, tolerance: float = 0.1,
                 min_cap: int = 1, max_cap: int = 1 << 30,
                 cooldown_out: float = 0.0, cooldown_in: float = 0.0,
                 resource: str = "slots", quantize=None):
        if target <= 0:
            raise ValueError("target must be positive")
        self.metric = metric
        self.target = target
        self.tolerance = tolerance
        self.min_cap = min_cap
        self.max_cap = max_cap
        self.cooldown_out = cooldown_out
        self.cooldown_in = cooldown_in
        self.resource = resource
        # actuator granularity (e.g. pow2 slot buckets): applied *before*
        # the no-change check, so a desired value that quantizes back to
        # the current capacity is a non-decision — it neither consumes a
        # cooldown nor lands in the event log
        self.quantize = quantize
        self._last_action = {}

    def evaluate(self, now: float, value: float,
                 current: int) -> Optional[ScaleDecision]:
        lo = self.target * (1 - self.tolerance)
        hi = self.target * (1 + self.tolerance)
        if lo <= value <= hi:
            return None                       # inside the deadband
        desired = math.ceil(current * value / self.target)
        if self.quantize is not None:
            desired = self.quantize(desired)
        desired = max(self.min_cap, min(self.max_cap, desired))
        if desired == current:
            return None
        direction = "out" if desired > current else "in"
        if not self._cooled_down(now, direction):
            return None
        self._note(now, direction)
        return ScaleDecision(
            resource=self.resource, desired=desired,
            delta=desired - current, at=now,
            reason=(f"target-tracking {self.metric}={value:.3f} vs "
                    f"target {self.target:.3f}"))


class StepScalingPolicy(_CooldownMixin):
    """Threshold ladder: metric above a step's bound adds that step's delta.

    ``steps_out`` is a sequence of ``(lower_bound, delta)`` pairs sorted
    ascending; the highest bound the metric clears wins (e.g. queue depth
    ``[(1, +1), (4, +2), (16, +4)]``). When the metric falls to
    ``scale_in_below`` or lower, capacity steps down by ``scale_in_step``.
    """

    def __init__(self, *, metric: str,
                 steps_out: Sequence[Tuple[float, int]],
                 scale_in_below: Optional[float] = None,
                 scale_in_step: int = 1,
                 min_cap: int = 1, max_cap: int = 1 << 30,
                 cooldown_out: float = 0.0, cooldown_in: float = 0.0,
                 resource: str = "slots", quantize=None):
        self.metric = metric
        self.steps_out: List[Tuple[float, int]] = sorted(steps_out)
        self.scale_in_below = scale_in_below
        self.scale_in_step = scale_in_step
        self.min_cap = min_cap
        self.max_cap = max_cap
        self.cooldown_out = cooldown_out
        self.cooldown_in = cooldown_in
        self.resource = resource
        self.quantize = quantize              # see TargetTrackingPolicy
        self._last_action = {}

    def evaluate(self, now: float, value: float,
                 current: int) -> Optional[ScaleDecision]:
        delta = 0
        for bound, d in self.steps_out:
            if value >= bound:
                delta = d
        if delta == 0 and self.scale_in_below is not None \
                and value <= self.scale_in_below:
            delta = -self.scale_in_step
        if delta == 0:
            return None
        desired = current + delta
        if self.quantize is not None:
            desired = self.quantize(desired)
        desired = max(self.min_cap, min(self.max_cap, desired))
        if desired == current:
            return None
        direction = "out" if desired > current else "in"
        if not self._cooled_down(now, direction):
            return None
        self._note(now, direction)
        return ScaleDecision(
            resource=self.resource, desired=desired,
            delta=desired - current, at=now,
            reason=f"step-scaling {self.metric}={value:.3f}")
