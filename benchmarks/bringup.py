"""Bring-up time benchmark — the paper's headline table.

Paper claim: a 4-VM cluster hosting the full Table-1 service stack in ~25
minutes with InstaCluster vs "several hours" for an experienced admin by
hand. We reproduce both sides: the InstaCluster path runs the actual
control plane against SimCloud's calibrated latencies; the manual baseline
models the per-node, per-service expert workflow the paper describes
(sequential, error-prone: a configurable retry tax).

Also measures *real wall-clock* of the control plane itself at fleet scale
(provisioning logic for 256 hosts), since that code is what would run on a
real master.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.cluster import ClusterManager
from repro.core.services import SERVICE_MATRIX

# manual-expert latency model (seconds) — paper narrative calibration
MANUAL = {
    "per_node_os_setup": 300.0,      # users, keys, hosts, firewall by hand
    "per_node_connectivity": 120.0,  # verify ssh mesh / hostname resolution
    "per_service_config": 900.0,     # install + configure + debug one service
    "retry_tax": 0.25,               # fraction of steps redone (error-prone)
}

FULL_STACK = tuple(n for n, (p, _, _) in SERVICE_MATRIX.items()
                   if p is not None)


def instacluster_bringup(n_slaves: int = 4,
                         services=FULL_STACK) -> Dict[str, float]:
    mgr = ClusterManager()
    t0 = time.perf_counter()
    ic = mgr.build_cluster(n_slaves=n_slaves, services=services)
    wall = time.perf_counter() - t0
    return {"simulated_minutes": ic.bringup_seconds / 60.0,
            "wall_seconds": wall,
            "n_services": len(services),
            "n_slaves": n_slaves}


def manual_bringup_estimate(n_slaves: int = 4,
                            services=FULL_STACK) -> Dict[str, float]:
    n_nodes = n_slaves + 1
    base = (n_nodes * (MANUAL["per_node_os_setup"]
                       + MANUAL["per_node_connectivity"])
            + len(services) * MANUAL["per_service_config"])
    total = base * (1 + MANUAL["retry_tax"])
    return {"simulated_minutes": total / 60.0, "n_services": len(services),
            "n_slaves": n_slaves}


def control_plane_scaling(ns: List[int] = (4, 64, 256)) -> List[Dict]:
    """Real wall-clock of the provisioning logic at fleet sizes."""
    out = []
    for n in ns:
        mgr = ClusterManager()
        t0 = time.perf_counter()
        ic = mgr.build_cluster(n_slaves=n, services=("hdfs", "spark", "hue"))
        wall = time.perf_counter() - t0
        out.append({"n_slaves": n, "wall_seconds": wall,
                    "sim_minutes": ic.bringup_seconds / 60.0,
                    "chips": ic.cluster.directory.total_chips()})
    return out


def rows() -> List[str]:
    """CSV rows: name,us_per_call,derived."""
    out = []
    ic = instacluster_bringup()
    man = manual_bringup_estimate()
    speedup = man["simulated_minutes"] / ic["simulated_minutes"]
    out.append(f"bringup_instacluster_4vm,{ic['wall_seconds']*1e6:.0f},"
               f"sim_min={ic['simulated_minutes']:.1f}")
    out.append(f"bringup_manual_4vm,,sim_min={man['simulated_minutes']:.1f}")
    out.append(f"bringup_speedup,,x{speedup:.1f}")
    for r in control_plane_scaling():
        out.append(f"controlplane_{r['n_slaves']}slaves,"
                   f"{r['wall_seconds']*1e6:.0f},"
                   f"sim_min={r['sim_minutes']:.1f};chips={r['chips']}")
    return out
