"""Shared-prefix copy-on-write page cache: refcounted allocator regression,
prefix-index semantics, fp32 token identity of shared vs isolated serving
(dense + jamba hybrid), late-diverging COW, hit/miss accounting, admission
charging only the uncached suffix, static-engine bookkeeping parity, and
router prefix-affinity determinism."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import REDUCED
from repro.core.blueprint import serving_page_plan
from repro.models import model as M
from repro.serving import engine as E
from repro.serving import paged_cache as PC
from repro.serving.request import make_request
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler

CFG = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


def _sched(params, *, prefix_cache, cfg=CFG, slots=4, page_size=8,
           max_seq=64, num_pages=None):
    return ContinuousBatchingScheduler(
        cfg, params, max_slots=slots, page_size=page_size,
        max_seq_len=max_seq, num_pages=num_pages, prefix_cache=prefix_cache)


def _serve(sched, trace):
    reqs = [sched.submit(p, g) for p, g in trace]
    sched.run()
    return reqs


# ----------------------------------------------------- allocator regression --

def test_double_free_same_page_in_one_call_raises():
    """Regression: ``free([p, p])`` must raise, not silently drop two
    references — and must leave the allocator untouched when it raises."""
    a = PC.PageAllocator(8)
    p1, p2 = a.alloc(2, owner="r1")
    with pytest.raises(ValueError, match="twice in one free"):
        a.free([p1, p1])
    assert a.num_allocated == 2 and a.ref(p1) == 1   # nothing was mutated
    a.share([p1])                                    # now legitimately ref 2
    with pytest.raises(ValueError, match="twice in one free"):
        a.free([p1, p1])                             # still one call = one ref
    assert a.ref(p1) == 2
    a.free([p1, p2])
    a.free([p1])
    assert a.num_allocated == 0 and a.num_free == 7


def test_share_and_release_lifecycle():
    a = PC.PageAllocator(6)
    pages = a.alloc(3, owner="orig")
    a.share(pages[:2])
    a.free(pages)                      # original owner leaves
    assert a.num_allocated == 2        # shared pages survive
    assert a.ref(pages[0]) == 1 and a.ref(pages[2]) == 0
    with pytest.raises(ValueError):
        a.share([pages[2]])            # cannot share a freed page
    a.free(pages[:2])
    assert a.num_allocated == 0 and a.num_free == 5


def test_shrink_never_reclaims_shared_pages():
    a = PC.PageAllocator(8)
    pages = a.alloc(7)
    a.share(pages)
    a.free(pages)                      # one of two refs gone
    a.request_shrink(2)
    assert not a.shrink_ready()        # live sharers block the shrink
    a.free(pages)                      # last refs released
    assert a.shrink_ready() and a.complete_shrink() == 2


# --------------------------------------------------------- index semantics --

def test_prefix_index_boundary_tail_and_invalidation():
    ps = 8
    alloc = PC.PageAllocator(32)
    idx = PC.PrefixIndex(ps)
    alloc.on_free = idx.invalidate_page
    prompt = np.arange(20, dtype=np.int32)           # 2 full pages + 4 tail
    pages = alloc.alloc(3, owner="r0")
    idx.insert(prompt, pages)

    # full-page boundary match, capped at plen - 1
    hit = idx.lookup(prompt, limit=19)
    assert hit.length == 19 and hit.full_pages == pages[:2]
    assert hit.tail_page == pages[2] and hit.tail_len == 3

    # a prompt diverging inside page 2 shares up to the divergence point
    other = np.concatenate([prompt[:18], [99, 98, 97]]).astype(np.int32)
    hit = idx.lookup(other, limit=len(other) - 1)
    assert hit.length == 18 and hit.tail_len == 2

    # sub-page overlap alone is no match (min one full page)
    assert idx.lookup(np.arange(6, dtype=np.int32)) is None
    # different first page is a clean miss
    assert idx.lookup(np.arange(99, 119, dtype=np.int32)) is None

    # freeing any chain page invalidates the entries referencing it
    alloc.free([pages[1]])
    assert idx.lookup(prompt, limit=19).length == ps  # page-1 entries died
    alloc.free([pages[0], pages[2]])
    assert idx.lookup(prompt, limit=19) is None
    assert len(idx) == 0


# -------------------------------------------------- token identity (dense) --

def test_persona_workload_token_identity_dense(params):
    """Acceptance core: shared-prefix serving emits byte-identical tokens
    while sharing the persona pages (hits for every follower)."""
    rng = np.random.RandomState(0)
    trace = []
    for _ in range(2):                                  # 2 personas x 4 users
        persona = rng.randint(0, CFG.vocab_size, size=24).astype(np.int32)
        for u in range(4):
            user = rng.randint(0, CFG.vocab_size, size=4 + u).astype(np.int32)
            trace.append((np.concatenate([persona, user]), 6))
    off = _serve(_sched(params, prefix_cache=False), trace)
    s_on = _sched(params, prefix_cache=True)
    on = _serve(s_on, trace)
    assert [r.out_tokens for r in on] == [r.out_tokens for r in off]
    assert s_on.stats["prefix_hits"] >= 6               # >= users-1 per persona
    assert s_on.stats["cached_tokens"] >= 6 * 24
    assert s_on.stats["peak_pages"] < _peak(params, trace)
    assert s_on.reserved_pages == 0 and s_on.alloc.num_allocated == 0


def _peak(params, trace):
    s = _sched(params, prefix_cache=False)
    _serve(s, trace)
    return s.stats["peak_pages"]


def test_late_diverging_cow_token_identity(params):
    """Two prompts sharing 18 of 20+ tokens diverge *inside* page 2: the
    follower must COW-fork the page, and both streams' tokens must match
    isolated serving exactly."""
    rng = np.random.RandomState(1)
    base = rng.randint(0, CFG.vocab_size, size=20).astype(np.int32)
    a = np.concatenate([base, rng.randint(0, CFG.vocab_size, size=3)
                        ]).astype(np.int32)
    b = np.concatenate([base[:18], rng.randint(0, CFG.vocab_size, size=6)
                        ]).astype(np.int32)
    trace = [(a, 8), (b, 8)]
    off = _serve(_sched(params, prefix_cache=False, slots=2), trace)
    s_on = _sched(params, prefix_cache=True, slots=2)
    on = _serve(s_on, trace)
    assert [r.out_tokens for r in on] == [r.out_tokens for r in off]
    assert s_on.stats["cow_forks"] >= 1
    assert on[1].cached_tokens == 18


def test_identical_prompt_reuse_caps_at_plen_minus_one(params):
    """An identical prompt reuses everything except its last token (whose
    forward pass must still run to produce the first output logits)."""
    rng = np.random.RandomState(2)
    p = rng.randint(0, CFG.vocab_size, size=21).astype(np.int32)
    trace = [(p, 6), (p.copy(), 9)]
    off = _serve(_sched(params, prefix_cache=False, slots=2), trace)
    s_on = _sched(params, prefix_cache=True, slots=2)
    on = _serve(s_on, trace)
    assert [r.out_tokens for r in on] == [r.out_tokens for r in off]
    assert on[1].cached_tokens == 20


# ------------------------------------------------- token identity (hybrid) --

@pytest.mark.slow
def test_hybrid_jamba_token_identity():
    """Hybrid (jamba) conversation continuation: the exact-entry hit loads
    the SSM state snapshot and steps the suffix sequentially — fp32
    token-identical to isolated serving. Expert capacity is set non-binding
    (capacity_factor = E / top_k): MoE capacity couples tokens through
    their *grouping*, which sharing legitimately changes, so identity is
    only guaranteed when no token can be dropped (same caveat as the
    scheduler's MoE late-join note; MoE archs default to prefix_cache
    off for this reason)."""
    cfg = dataclasses.replace(
        REDUCED["jamba-v0.1-52b"], dtype="float32",
        moe_capacity_factor=float(REDUCED["jamba-v0.1-52b"].n_routed_experts)
        / REDUCED["jamba-v0.1-52b"].moe_top_k)
    p = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    turn1 = rng.randint(0, cfg.vocab_size, size=18).astype(np.int32)
    turn2 = np.concatenate([turn1, rng.randint(0, cfg.vocab_size, size=5)
                            ]).astype(np.int32)
    trace = [(turn1, 8), (turn2, 5)]
    off = _serve(_sched(p, prefix_cache=False, cfg=cfg, slots=2), trace)
    s_on = _sched(p, prefix_cache=True, cfg=cfg, slots=2)
    on = _serve(s_on, trace)
    assert [r.out_tokens for r in on] == [r.out_tokens for r in off]
    assert s_on.stats["prefix_hits"] == 1
    assert on[1].cached_tokens == 18
    # the hit landed mid-page, so the continuation COW-forked the tail page
    assert s_on.stats["cow_forks"] == 1


def test_moe_arch_defaults_to_no_prefix_cache(params):
    cfg = dataclasses.replace(REDUCED["jamba-v0.1-52b"], dtype="float32")
    p = M.init(cfg, jax.random.PRNGKey(0))
    assert _sched(p, prefix_cache=None, cfg=cfg).prefix_cache is False
    assert _sched(params, prefix_cache=None).prefix_cache is True


# ---------------------------------------------------------------- accounting --

def test_hit_miss_accounting_and_ledger(params):
    rng = np.random.RandomState(4)
    persona = rng.randint(0, CFG.vocab_size, size=16).astype(np.int32)
    trace = [(np.concatenate([persona, rng.randint(0, CFG.vocab_size,
                                                   size=3 + u)]).astype(
                  np.int32), 4) for u in range(3)]
    s = _sched(params, prefix_cache=True, slots=3)
    reqs = _serve(s, trace)
    assert s.stats["prefix_misses"] == 1 and s.stats["prefix_hits"] == 2
    assert reqs[0].cached_tokens == 0
    assert all(r.cached_tokens == 16 for r in reqs[1:])
    assert s.stats["cached_tokens"] == 32
    # ledger drains to zero: shared pages freed exactly once each
    assert s.reserved_pages == 0 and s.pages_in_use == 0
    assert s.alloc.num_allocated == 0
    assert s.alloc.num_free == s.alloc.num_pages - 1
    assert len(s.index) == 0                  # all entries invalidated


def test_admission_charges_only_uncached_suffix(params):
    """With a pool too small for two worst-case reservations, sharing makes
    the second request admissible concurrently — the reservation charges
    only its uncached suffix."""
    rng = np.random.RandomState(5)
    p = rng.randint(0, CFG.vocab_size, size=16).astype(np.int32)
    trace = [(p, 8), (p.copy(), 8)]           # worst case 3 pages each @ps=8
    # 5 allocatable pages: 3 + 3 reservations cannot coexist without sharing
    s_off = _sched(params, prefix_cache=False, slots=2, num_pages=6)
    off = _serve(s_off, trace)
    assert off[1].admit_step > off[0].admit_step      # serialised
    s_on = _sched(params, prefix_cache=True, slots=2, num_pages=6)
    on = _serve(s_on, trace)
    assert on[1].admit_step == on[0].admit_step       # concurrent via sharing
    assert [r.out_tokens for r in on] == [r.out_tokens for r in off]


def test_static_engine_bookkeeping_parity(params):
    """The static path fills the same hit/miss bookkeeping (all misses), so
    paged==static identity checks run on shared-prefix workloads. Prompts
    share one length so the static group pads nothing (token-exact)."""
    rng = np.random.RandomState(6)
    persona = rng.randint(0, CFG.vocab_size, size=16).astype(np.int32)
    trace = [(np.concatenate([persona, rng.randint(0, CFG.vocab_size,
                                                   size=6)]).astype(
                  np.int32), 5) for _ in range(3)]
    static = [make_request(i, p, g) for i, (p, g) in enumerate(trace)]
    E.serve_requests(CFG, params, static, batch_width=3)
    assert all(r.cached_tokens == 0 for r in static)
    s = _sched(params, prefix_cache=True, slots=3)
    paged = _serve(s, trace)
    assert s.stats["prefix_hits"] == 2
    assert [r.out_tokens for r in paged] == [r.out_tokens for r in static]


# ------------------------------------------------------------------ router --

def test_router_prefix_affinity_beats_least_pages(params):
    """Affinity sends a follower to the replica caching its persona even
    when that replica holds more outstanding pages."""
    rng = np.random.RandomState(7)
    persona = rng.randint(0, CFG.vocab_size, size=24).astype(np.int32)
    router = ServingRouter(CFG, params, replicas=2, max_slots=4,
                           page_size=8, max_seq_len=64,
                           route_policy="prefix-affinity")
    lead = router.submit(np.concatenate([persona, [1, 2]]).astype(np.int32),
                         12)
    router.step()
    assert lead.replica == 0                  # all-miss -> id tie-break
    # load replica 0 further; replica 1 stays empty (fewer pages)
    filler = router.submit(rng.randint(0, CFG.vocab_size, size=8), 12)
    router.replicas[0].accept(filler)
    follower = router.submit(
        np.concatenate([persona, [3, 4, 5]]).astype(np.int32), 6)
    router.step()
    assert follower.replica == 0              # affinity overrides least-pages
    unrelated = router.submit(rng.randint(0, CFG.vocab_size, size=9), 6)
    router.step()
    assert unrelated.replica == 1             # no match -> least pages
    router.run()
    assert router.fleet_stats()["prefix_hits"] >= 1


def test_router_prefix_affinity_deterministic(params):
    """Same trace, same fleet ops -> same placements and tokens, twice."""
    def go():
        rng = np.random.RandomState(8)
        persona = rng.randint(0, CFG.vocab_size, size=16).astype(np.int32)
        router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                               page_size=8, max_seq_len=64,
                               route_policy="prefix-affinity")
        reqs = []
        for i in range(6):
            user = rng.randint(0, CFG.vocab_size, size=2 + i % 3)
            reqs.append(router.submit(
                np.concatenate([persona, user]).astype(np.int32), 5,
                arrival_step=i // 2))
        router.run()
        return [(r.rid, r.replica) for r in reqs], [r.out_tokens
                                                    for r in reqs]
    a, ta = go()
    b, tb = go()
    assert a == b and ta == tb


def test_failover_reprefill_reuses_surviving_prefix(params):
    """After a replica failure, the re-prefilled continuations land on the
    survivor with prefix affinity; the second continuation reuses the
    persona pages the first one just rebuilt (a prefix hit on re-prefill),
    and tokens stay byte-identical to the single-scheduler run."""
    rng = np.random.RandomState(9)
    persona = rng.randint(0, CFG.vocab_size, size=16).astype(np.int32)
    trace = [(np.concatenate([persona, rng.randint(0, CFG.vocab_size,
                                                   size=2 + i)]).astype(
                  np.int32), 10) for i in range(2)]
    ref = _sched(params, prefix_cache=True, slots=2)
    want = [r.out_tokens for r in _serve(ref, trace)]

    router = ServingRouter(CFG, params, replicas=2, max_slots=2,
                           page_size=8, max_seq_len=64,
                           route_policy="prefix-affinity")
    reqs = [router.submit(*trace[0])]
    router.step(max_fuse=1)                   # leader admitted + indexed
    reqs.append(router.submit(*trace[1],
                              arrival_step=router.step_idx))
    for _ in range(2):
        router.step(max_fuse=1)
    # affinity pulled the follower onto the leader's replica (a hit there)
    assert reqs[1].replica == reqs[0].replica == 0
    assert router.replicas[0].num_unfinished > 0
    router.fail_replica(0)
    router.run(max_fuse=1)
    assert [r.out_tokens for r in reqs] == want
    stats = router.fleet_stats()
    # follower's hit on replica 0 died with it (retired stats keep it);
    # after failover the first continuation re-seeds the persona on the
    # survivor and the second re-prefill hits it
    assert stats["prefix_hits"] >= 2
    assert stats["reroutes"] == 2


# --------------------------------------------------------------- blueprint --

def test_blueprint_shared_prefix_plan():
    plan = serving_page_plan(REDUCED["qwen3-32b"], SHAPES["decode_32k"],
                             shared_prefix_len=1024, users_per_prefix=8)
    sp = plan["shared_prefix"]
    assert sp["prefix_pages"] == 64           # 1024 / page_size 16
    assert sp["pages_per_seq_effective"] < plan["pages_per_seq"]
    assert sp["max_concurrent_seqs"] > plan["max_concurrent_seqs"]
    assert 0 < sp["page_savings_frac"] < 1
    flat = serving_page_plan(REDUCED["qwen3-32b"], SHAPES["decode_32k"],
                             shared_prefix_len=1024, users_per_prefix=1)
    assert flat["shared_prefix"]["page_savings_frac"] == 0
    with pytest.raises(ValueError):
        serving_page_plan(REDUCED["qwen3-32b"], SHAPES["decode_32k"],
                          shared_prefix_len=64, users_per_prefix=0)
