"""Elastic autoscaling control plane.

Closes the loop between the serving engine and the cluster control plane:

* ``metrics``    — telemetry bus aggregating per-tick scheduler + heartbeat
                   signals into windowed series on the SimCloud clock;
* ``policy``     — target-tracking and step-scaling policies with
                   hysteresis/cooldown, emitting typed ``ScaleDecision``s;
* ``controller`` — the actuator: live slot/page-pool resize on the paged
                   scheduler, node add/remove through ``ClusterLifecycle``,
                   spot-preemption replacement from the warm-spare pool;
* ``fleet``      — the replica axis: a ``FleetController`` over the serving
                   fabric router adds/removes whole replicas (drain-based
                   scale-in, node acquisition per replica) on fleet-wide
                   queue depth, composing with per-replica slot/page
                   controllers.

See docs/autoscaling.md for the control-loop walk-through.
"""
from repro.autoscale.controller import AutoscaleController, CapacityBands
from repro.autoscale.fleet import FleetController, default_fleet_policy
from repro.autoscale.metrics import TelemetryBus, sample_scheduler
from repro.autoscale.policy import (ScaleDecision, StepScalingPolicy,
                                    TargetTrackingPolicy)

__all__ = [
    "AutoscaleController", "CapacityBands", "FleetController",
    "TelemetryBus", "default_fleet_policy", "sample_scheduler",
    "ScaleDecision", "StepScalingPolicy", "TargetTrackingPolicy",
]
