"""Heartbeat monitoring — the Ambari server<->agent loop, hardened.

Ambari's server detects dead agents by missed heartbeats; at pod scale the
same loop must also catch *stragglers* (hosts that are alive but slow — the
tail that stalls a synchronous train step). The monitor keeps per-host
heartbeat times and step-duration EWMAs and classifies hosts as
ALIVE / SUSPECT / DEAD / STRAGGLER.
"""
from __future__ import annotations

import dataclasses
import enum
import statistics
from typing import Callable, Dict, List, Optional


class HostState(enum.Enum):
    ALIVE = "alive"
    SUSPECT = "suspect"
    DEAD = "dead"
    STRAGGLER = "straggler"


@dataclasses.dataclass
class HostHealth:
    hostname: str
    last_beat: float
    step_ewma: Optional[float] = None
    state: HostState = HostState.ALIVE
    missed: int = 0


class HeartbeatMonitor:
    def __init__(self, *, interval: float = 10.0, suspect_after: float = 2.5,
                 dead_after: float = 6.0, straggler_factor: float = 1.8,
                 ewma_alpha: float = 0.3):
        self.interval = interval
        self.suspect_after = suspect_after       # x interval
        self.dead_after = dead_after             # x interval
        self.straggler_factor = straggler_factor
        self.alpha = ewma_alpha
        self.hosts: Dict[str, HostHealth] = {}
        self._on_dead: List[Callable[[str], None]] = []
        self._on_straggler: List[Callable[[str], None]] = []

    def register(self, hostname: str, now: float = 0.0) -> None:
        self.hosts[hostname] = HostHealth(hostname, last_beat=now)

    def deregister(self, hostname: str) -> None:
        self.hosts.pop(hostname, None)

    def on_dead(self, fn: Callable[[str], None]) -> None:
        self._on_dead.append(fn)

    def on_straggler(self, fn: Callable[[str], None]) -> None:
        self._on_straggler.append(fn)

    # ----------------------------------------------------------- ingestion --
    def beat(self, hostname: str, now: float,
             step_time: Optional[float] = None) -> None:
        h = self.hosts[hostname]
        h.last_beat = now
        h.missed = 0
        if step_time is not None:
            h.step_ewma = (step_time if h.step_ewma is None
                           else self.alpha * step_time
                           + (1 - self.alpha) * h.step_ewma)
        if h.state in (HostState.SUSPECT, HostState.STRAGGLER):
            h.state = HostState.ALIVE

    # ---------------------------------------------------------- evaluation --
    def check(self, now: float) -> Dict[str, HostState]:
        ewmas = [h.step_ewma for h in self.hosts.values()
                 if h.step_ewma is not None]
        med = statistics.median(ewmas) if ewmas else None
        for h in self.hosts.values():
            if h.state == HostState.DEAD:
                continue
            silence = now - h.last_beat
            if silence > self.dead_after * self.interval:
                h.state = HostState.DEAD
                for fn in self._on_dead:
                    fn(h.hostname)
            elif silence > self.suspect_after * self.interval:
                h.state = HostState.SUSPECT
            elif (med is not None and h.step_ewma is not None and med > 0
                  and h.step_ewma > self.straggler_factor * med):
                if h.state != HostState.STRAGGLER:
                    h.state = HostState.STRAGGLER
                    for fn in self._on_straggler:
                        fn(h.hostname)
            else:
                h.state = HostState.ALIVE
        return {h.hostname: h.state for h in self.hosts.values()}

    def alive(self) -> List[str]:
        return [h.hostname for h in self.hosts.values()
                if h.state in (HostState.ALIVE, HostState.STRAGGLER)]
