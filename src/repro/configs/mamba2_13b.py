"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 vocab=50280 ssm_state=128 [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=16,            # unused (attention-free)
    n_kv_heads=16,
    d_ff=0,                # mixer-only blocks
    vocab_size=50280,
    attn_impl="none",
    rope_variant="none",
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
    rms_eps=1e-5,
)

REDUCED = ModelConfig(
    name="mamba2-1.3b-reduced",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    attn_impl="none",
    rope_variant="none",
    layer_pattern=("ssm",),
    ssm_state=16,
    ssm_headdim=16,
    ssm_expand=2,
)
