"""PageAllocator live-resize + refcount invariants (hypothesis stateful).

The allocator is the serving engine's memory-safety keystone: admission
reservations, live grow, drain-before-shrink, and now prefix sharing all
assume that at every point in *any* operation sequence the page-id space
partitions cleanly into {free} ∪ {allocated (ref > 0)} ∪
{retired-by-pending-shrink} with the sink page in none of them. These
properties drive random interleavings of alloc / share / free / COW-fork /
grow / request_shrink / complete_shrink and check, after every step:

* the partition (free + allocated + retired == pool size − sink);
* a page with live sharers (ref > 0) is never on the free list and is
  never reclaimed by a shrink;
* a COW fork conserves ``num_free + num_allocated`` (the fork allocates
  one page and drops one reference — pool accounting must not leak);
* duplicate page ids in one ``free`` call always raise, mutating nothing.

The state-machine analogue of the hand-written sequences in
tests/test_autoscale.py and tests/test_prefix_cache.py.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

from repro.serving.paged_cache import SINK_PAGE, PageAllocator


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.alloc = PageAllocator(8)
        self.refs = {}                     # page -> refcount (shadow model)
        self.next_owner = 0

    # ------------------------------------------------------------- rules --
    @rule(n=st.integers(min_value=1, max_value=6))
    def alloc_pages(self, n):
        if self.alloc.can_alloc(n):
            pages = self.alloc.alloc(n, owner=self.next_owner)
            assert len(set(pages)) == n, "duplicate page in one alloc"
            assert SINK_PAGE not in pages, "sink page handed out"
            for p in pages:
                assert p not in self.refs, f"page {p} double-allocated"
                self.refs[p] = 1
            self.next_owner += 1
        else:
            with pytest.raises(MemoryError):
                self.alloc.alloc(n)

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def share_pages(self, data):
        pages = data.draw(st.lists(st.sampled_from(sorted(self.refs)),
                                   min_size=1, unique=True), label="share")
        self.alloc.share(pages)
        for p in pages:
            self.refs[p] += 1

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def free_pages(self, data):
        pages = data.draw(st.lists(st.sampled_from(sorted(self.refs)),
                                   min_size=1, unique=True), label="free")
        self.alloc.free(pages)
        for p in pages:
            self.refs[p] -= 1
            if not self.refs[p]:
                del self.refs[p]

    @precondition(lambda self: self.refs)
    @rule(data=st.data())
    def duplicate_free_raises(self, data):
        p = data.draw(st.sampled_from(sorted(self.refs)), label="dup")
        before = (self.alloc.num_free, self.alloc.num_allocated,
                  self.alloc.ref(p))
        with pytest.raises(ValueError):
            self.alloc.free([p, p])
        after = (self.alloc.num_free, self.alloc.num_allocated,
                 self.alloc.ref(p))
        assert before == after, "raising free() must not mutate"

    @precondition(lambda self: any(r >= 2 for r in self.refs.values()))
    @rule(data=st.data())
    def cow_fork(self, data):
        """Fork a shared page: alloc the copy, drop one ref on the source.
        ``num_free + num_allocated`` must be conserved."""
        if not self.alloc.can_alloc(1):
            return
        src = data.draw(st.sampled_from(
            sorted(p for p, r in self.refs.items() if r >= 2)), label="src")
        total = self.alloc.num_free + self.alloc.num_allocated
        dst = self.alloc.alloc(1, owner=self.next_owner)[0]
        self.next_owner += 1
        self.refs[dst] = 1
        self.alloc.free([src])
        self.refs[src] -= 1
        assert self.alloc.num_free + self.alloc.num_allocated == total, \
            "COW fork leaked pool capacity"

    @rule(k=st.integers(min_value=0, max_value=8))
    def grow(self, k):
        self.alloc.grow(self.alloc.num_pages + k)
        assert not self.alloc.shrink_pending   # grow cancels pending shrinks

    @rule(data=st.data())
    def request_shrink(self, data):
        target = data.draw(st.integers(min_value=2,
                                       max_value=self.alloc.num_pages),
                           label="target")
        self.alloc.request_shrink(target)
        assert self.alloc.effective_pages == min(self.alloc.num_pages, target)

    @precondition(lambda self: self.alloc.shrink_ready())
    @rule()
    def complete_shrink(self):
        new = self.alloc.complete_shrink()
        assert new == self.alloc.num_pages
        assert not self.alloc.shrink_pending
        assert all(p < new for p in self.refs), \
            "shrink reclaimed a page with live sharers"

    # -------------------------------------------------------- invariants --
    @invariant()
    def partition_covers_pool(self):
        a = self.alloc
        free = set(a._free)
        allocated = set(a._ref)
        every = set(range(1, a.num_pages))
        retired = every - free - allocated
        # free + used + retired == pool size (sink excluded from all three)
        assert len(free) + len(allocated) + len(retired) == a.num_pages - 1
        assert len(a._free) == len(free), "duplicate ids on the free list"
        assert not (free & allocated), "page both free and referenced"
        assert SINK_PAGE not in free and SINK_PAGE not in allocated
        # retired pages exist only under a pending shrink, above its target
        if retired:
            assert a.shrink_pending
            assert all(p >= a._shrink_target for p in retired)
        # free pages below a pending shrink target only
        if a.shrink_pending:
            assert all(p < a._shrink_target for p in free)

    @invariant()
    def shadow_model_agrees(self):
        assert dict(self.alloc._ref) == self.refs
        assert self.alloc.num_allocated == len(self.refs)
        assert all(r > 0 for r in self.refs.values())
        assert self.alloc.capacity >= 0

    @invariant()
    def shrink_blocked_by_sharers(self):
        if self.alloc.shrink_ready():
            assert all(p < self.alloc._shrink_target for p in self.refs)


TestAllocatorProps = AllocatorMachine.TestCase
TestAllocatorProps.settings = settings(max_examples=60,
                                       stateful_step_count=40,
                                       deadline=None)
