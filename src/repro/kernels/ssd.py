"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk term.

Per (batch, head, chunk) tile the kernel computes, entirely in VMEM:
    scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j        (j <= i)
    y_diag      = scores @ X                                     (Q, P)
    state       = sum_j B_j * exp(cum_Q - cum_j) * dt_j * X_j    (N, P)
i.e. the quadratic-in-chunk matmuls that hit the MXU. The cheap inter-chunk
recurrence and the C_i*h_prev correction run as jnp in the wrapper
(``repro.kernels.ops.ssd``), mirroring ``repro.models.ssm.ssd_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, b_ref, c_ref, cum_ref, dt_ref, y_ref, st_ref, *,
                chunk: int):
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0].astype(jnp.float32)         # (Q, N)
    cum = cum_ref[0].astype(jnp.float32)      # (1, Q) row vector
    dt = dt_ref[0].astype(jnp.float32)        # (1, Q)
    cum_i = cum.reshape(chunk, 1)
    cum_j = cum.reshape(1, chunk)
    dt_j = dt.reshape(1, chunk)

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum_i - cum_j)
    w = jnp.where(ii >= jj, cb * decay * dt_j, 0.0)
    y_ref[0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    total = cum[0, chunk - 1]
    wb = bm * (jnp.exp(total - cum.reshape(chunk, 1))
               * dt.reshape(chunk, 1))                            # (Q,N)
    st_ref[0] = jax.lax.dot_general(
        wb, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (N,P)


def ssd_intra_chunk(x: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray,
                    cum: jnp.ndarray, dt: jnp.ndarray, *,
                    interpret: bool = False):
    """x: (B,nc,Q,H,P)  Bm/Cm: (B,nc,Q,H,N) (pre-broadcast to heads)
    cum/dt: (B,nc,Q,H) float32.

    Returns y_diag (B,nc,Q,H,P) and chunk states (B,nc,H,N,P) fp32.
    """
    B, nc, Q, H, P = x.shape
    N = Bm.shape[-1]
    xf = jnp.moveaxis(x, 3, 2).reshape(B * nc * H, Q, P)
    bf = jnp.moveaxis(Bm, 3, 2).reshape(B * nc * H, Q, N)
    cf = jnp.moveaxis(Cm, 3, 2).reshape(B * nc * H, Q, N)
    cumf = jnp.moveaxis(cum, 3, 2).reshape(B * nc * H, 1, Q)
    dtf = jnp.moveaxis(dt, 3, 2).reshape(B * nc * H, 1, Q)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y, st = pl.pallas_call(
        kernel,
        grid=(B * nc * H,),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, N, P), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc * H, Q, P), x.dtype),
            jax.ShapeDtypeStruct((B * nc * H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xf, bf, cf, cumf, dtf)
    y = jnp.moveaxis(y.reshape(B, nc, H, Q, P), 2, 3)
    st = st.reshape(B, nc, H, N, P)
    return y, st
