"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

Weak-type-correct, shardable specs for train / prefill / decode steps, plus
abstract train state (params + Adam m/v + step) with NamedShardings derived
from the same param schema used for real initialisation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.blueprint import Plan
from repro.models import model as M
from repro.models.schema import abstract_params, resolve_pspec


def _sds(shape, dtype, mesh, axes, rules):
    pspec = resolve_pspec(tuple(axes), tuple(shape), rules, mesh)
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype),
                                sharding=NamedSharding(mesh, pspec))


def _merged_rules(plan: Plan) -> Dict[str, Tuple[str, ...]]:
    return {**plan.param_rules, **plan.act_rules}


def abstract_train_state(cfg: ModelConfig, mesh, plan: Plan) -> Dict[str, Any]:
    params = abstract_params(M.schema(cfg), mesh, plan.param_rules)
    return {
        "params": params,
        "m": abstract_params(M.schema(cfg), mesh, plan.param_rules),
        "v": abstract_params(M.schema(cfg), mesh, plan.param_rules),
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh,
                                                            PartitionSpec())),
    }


def abstract_params_only(cfg: ModelConfig, mesh, plan: Plan):
    p = abstract_params(M.schema(cfg), mesh, plan.param_rules)
    if getattr(plan, "serve_param_dtype", "float32") == "bfloat16":
        p = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16,
                                           sharding=s.sharding)
            if s.dtype == jnp.dtype(jnp.float32) else s, p)
    return p


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, plan: Plan,
                *, with_labels: bool) -> Dict[str, Any]:
    rules = _merged_rules(plan)
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((B, S), jnp.int32, mesh, ("batch", None), rules)}
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32, mesh, ("batch", None), rules)
    if cfg.rope_variant == "mrope":
        out["positions"] = _sds((3, B, S), jnp.int32, mesh,
                                (None, "batch", None), rules)
    if cfg.is_encdec:
        out["enc_embeds"] = _sds((B, cfg.enc_positions, cfg.d_model),
                                 jnp.float32, mesh, ("batch", None, None),
                                 rules)
    return out


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   plan: Plan) -> Any:
    rules = _merged_rules(plan)
    sch = M.cache_schema(cfg, shape.global_batch, shape.seq_len)
    return abstract_params(sch, mesh, rules)


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, plan: Plan):
    """-> (params, cache, tokens, cur_len) SDS tuple for serve_step."""
    rules = _merged_rules(plan)
    B = shape.global_batch
    params = abstract_params_only(cfg, mesh, plan)
    cache = abstract_cache(cfg, shape, mesh, plan)
    tokens = _sds((B, 1), jnp.int32, mesh, ("batch", None), rules)
    cur_len = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh,
                                                          PartitionSpec()))
    return params, cache, tokens, cur_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, plan: Plan):
    """All inputs for the step the shape's kind lowers.

    train   -> {"state": ..., "batch": ...}
    prefill -> {"params": ..., "batch": ...}
    decode  -> {"params": ..., "cache": ..., "tokens": ..., "cur_len": ...}
    """
    if shape.kind == "train":
        return {"state": abstract_train_state(cfg, mesh, plan),
                "batch": batch_specs(cfg, shape, mesh, plan, with_labels=True)}
    if shape.kind == "prefill":
        return {"params": abstract_params_only(cfg, mesh, plan),
                "batch": batch_specs(cfg, shape, mesh, plan,
                                     with_labels=False)}
    params, cache, tokens, cur_len = decode_specs(cfg, shape, mesh, plan)
    return {"params": params, "cache": cache, "tokens": tokens,
            "cur_len": cur_len}
