"""Real-SPMD integration tests: run checks in a subprocess with 8 fake CPU
devices (keeps the main pytest process single-device)."""
import pathlib
import subprocess
import sys

import pytest

SCRIPT = pathlib.Path(__file__).parent / "spmd_scripts" / "run_spmd_checks.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def run_check(name: str, timeout: int = 900) -> str:
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
           "JAX_PLATFORMS": "cpu", "HOME": "/tmp"}
    proc = subprocess.run([sys.executable, str(SCRIPT), name],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (proc.stdout[-3000:], proc.stderr[-3000:])
    assert f"PASS {name}" in proc.stdout, proc.stdout[-3000:]
    return proc.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    run_check("sharded_train_step_matches_single_device")


@pytest.mark.slow
def test_elastic_reshard_resume():
    run_check("elastic_reshard_resume")


@pytest.mark.slow
def test_compressed_psum_error_bound():
    run_check("compressed_psum")


@pytest.mark.slow
def test_decode_cache_stays_sharded():
    run_check("decode_cache_stays_sharded")


@pytest.mark.slow
def test_gpipe_matches_sequential():
    run_check("gpipe_matches_sequential")


@pytest.mark.slow
def test_shard_group_paged_decode_shard_map():
    run_check("shard_group_paged_decode")


@pytest.mark.slow
def test_chunked_prefill_composes_with_tp2():
    run_check("chunked_prefill_tp2")
