"""Pallas TPU paged flash-decode kernel (block-table KV gather).

The paged variant of ``repro.kernels.decode_attention``: instead of a
contiguous per-sequence ring buffer, K/V live in a shared page pool of
shape (num_pages, page_size, KVH, d) and each sequence owns a list of
pages recorded in a *block table* (B, pages_per_seq). The block table and
the per-sequence lengths are passed as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps can resolve
``pages[block_table[b, i]]`` before the kernel body runs — the page gather
happens in the DMA engine, never materialising a contiguous copy in HBM.

Grid: (B, KVH, pages_per_seq). Each step attends one page and emits a
partial (max, sum, weighted-V) triple; the log-sum-exp combine over the
page axis runs as plain jnp in ``repro.kernels.ops.paged_decode_attention``
— identical structure to the dense flash-decode split-KV combine.

Pages wholly past a sequence's length produce masked partials with
``m = -1e30``; the combine weights them by ``exp(m - m_glob) == 0`` so they
never contribute. Page 0 is the serving layer's sink page (see
``repro.serving.paged_cache``) and may be referenced by idle slots — it is
masked the same way.

Supports the int8-quantised cache (§Perf ``cache_quant``): quantised pools
carry per-(position, kv-head) fp32 scale pages and the dequantise happens
in-kernel on the VMEM block.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _pd_kernel(bt_ref, lens_ref, q_ref, k_ref, v_ref, *refs,
               scale: float, softcap: Optional[float],
               window: Optional[int], page_size: int, quant: bool):
    if quant:                                   # int8 pools + fp32 scales
        ks_ref, vs_ref, m_ref, l_ref, o_ref = refs
    else:
        m_ref, l_ref, o_ref = refs
    b = pl.program_id(0)
    pi = pl.program_id(2)                       # page slot within the sequence
    q = q_ref[0, 0].astype(jnp.float32)         # (G, d)
    k = k_ref[0, :, 0].astype(jnp.float32)      # (ps, d)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = lens_ref[b]                         # tokens 0..valid-1 are live
    k_pos = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    ok = k_pos < valid
    if window is not None:
        ok &= k_pos >= valid - window
    s = jnp.where(ok, s, _NEG)                  # (G, ps)
    m = s.max(axis=-1)                          # (G,)
    p = jnp.exp(s - m[:, None])
    lse = p.sum(axis=-1)
    v = v_ref[0, :, 0].astype(jnp.float32)      # (ps, d)
    if quant:
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = lse
    o_ref[0, 0, 0] = pv


def paged_decode_partials(q: jnp.ndarray, k_pages: jnp.ndarray,
                          v_pages: jnp.ndarray, block_table: jnp.ndarray,
                          seq_lens: jnp.ndarray, *,
                          k_scale_pages: Optional[jnp.ndarray] = None,
                          v_scale_pages: Optional[jnp.ndarray] = None,
                          softcap: Optional[float] = None,
                          window: Optional[int] = None,
                          scale: Optional[float] = None,
                          interpret: bool = False):
    """q: (B, H, d); pools: (P, page_size, KVH, d); block_table: (B, n_pg)
    int32; seq_lens: (B,) int32 — number of live tokens per sequence.

    Returns partials (m, l, o) with a page axis for the LSE combine:
    m/l (B, KVH, n_pg, G), o (B, KVH, n_pg, G, d).
    """
    B, H, d = q.shape
    _, page_size, KVH, _ = k_pages.shape
    n_pg = block_table.shape[1]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qr = q.reshape(B, KVH, G, d)
    quant = k_scale_pages is not None

    kernel = functools.partial(_pd_kernel, scale=scale, softcap=softcap,
                               window=window, page_size=page_size,
                               quant=quant)
    page_spec = pl.BlockSpec((1, page_size, 1, d),
                             lambda b, h, i, bt, lens: (bt[b, i], 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, d), lambda b, h, i, bt, lens: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    args = [qr, k_pages, v_pages]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, page_size, 1), lambda b, h, i, bt, lens: (bt[b, i], 0, h))
        in_specs += [scale_spec, scale_spec]
        args += [k_scale_pages, v_scale_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, n_pg),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, G), lambda b, h, i, bt, lens: (b, h, i, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, i, bt, lens: (b, h, i, 0)),
            pl.BlockSpec((1, 1, 1, G, d),
                         lambda b, h, i, bt, lens: (b, h, i, 0, 0)),
        ],
    )
    m, lse, o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KVH, n_pg, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KVH, n_pg, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KVH, n_pg, G, d), jnp.float32),
        ],
        interpret=interpret,
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32), *args)
    return m, lse, o
