"""Typed metrics registry: bucket math, nearest-rank percentiles,
histogram quantiles and merges, the registry's get-or-create + exposition
contract, and the StatsView facade that keeps the legacy ``stats`` dict
idioms working on top of typed metrics (docs/observability.md)."""
import math

import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               StatsView, log_buckets, nearest_rank,
                               percentile)


# ---------------------------------------------------------------- buckets --

def test_log_buckets_monotone_and_cover():
    b = log_buckets(1.0, 4096.0, per_decade=4)
    assert all(b2 > b1 for b1, b2 in zip(b, b[1:]))
    assert b[0] == 1.0 and b[-1] >= 4096.0
    # growth factor is exactly 10^(1/per_decade)
    step = 10.0 ** 0.25
    for b1, b2 in zip(b, b[1:]):
        assert b2 / b1 == pytest.approx(step)


@pytest.mark.parametrize("lo,hi,per", [(0.0, 1.0, 4), (-1.0, 1.0, 4),
                                       (2.0, 1.0, 4), (1.0, 2.0, 0)])
def test_log_buckets_rejects_bad_args(lo, hi, per):
    with pytest.raises(ValueError):
        log_buckets(lo, hi, per_decade=per)


# ------------------------------------------------------------- percentile --

def test_nearest_rank_basics():
    vals = list(range(1, 11))                 # 1..10
    assert nearest_rank(vals, 50) == 5        # rank ceil(5) = 5
    assert nearest_rank(vals, 0) == 1         # rank clamps to 1
    assert nearest_rank(vals, 100) == 10
    assert nearest_rank([7.0], 99) == 7.0
    assert percentile is nearest_rank         # one shared definition


def test_nearest_rank_rejects_empty_and_out_of_range():
    with pytest.raises(ValueError):
        nearest_rank([], 50)
    with pytest.raises(ValueError):
        nearest_rank([1.0], 101)
    with pytest.raises(ValueError):
        nearest_rank([1.0], -1)


# --------------------------------------------------------------- histogram --

def test_histogram_bucket_semantics():
    h = Histogram("h", (1.0, 10.0, 100.0))
    # a value equal to a bound lands in that bound's bucket: (lo, bound]
    h.observe(0.5)
    h.observe(1.0)
    h.observe(10.0)
    h.observe(10.5)
    h.observe(1000.0)                         # overflow
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5
    assert h.sum == pytest.approx(0.5 + 1.0 + 10.0 + 10.5 + 1000.0)


def test_histogram_quantile_edges():
    h = Histogram("h", (1.0, 10.0))
    assert h.quantile(99) == 0.0              # empty histogram
    h.observe(0.5)
    assert h.quantile(50) == 1.0              # containing bucket upper bound
    h2 = Histogram("h2", (1.0,))
    h2.observe(5.0)
    assert h2.quantile(99) == math.inf        # overflow has no upper bound
    with pytest.raises(ValueError):
        h.quantile(101)


def test_histogram_quantile_agrees_with_nearest_rank_within_a_bucket():
    """The contract the benches rely on: a histogram quantile is an upper
    estimate of the sample nearest-rank within one bucket growth factor."""
    import random
    rng = random.Random(0)
    bounds = log_buckets(1.0, 4096.0, per_decade=4)
    h = Histogram("lat", bounds)
    samples = [rng.uniform(1.0, 3000.0) for _ in range(500)]
    for v in samples:
        h.observe(v)
    step = 10.0 ** 0.25
    for q in (50, 90, 99, 99.9):
        exact = nearest_rank(samples, q)
        approx = h.quantile(q)
        assert exact <= approx <= exact * step, (q, exact, approx)


def test_log_buckets_decade_bounds_exact_for_boundary_values():
    """Bugfix regression: cumulative ``*= step`` accumulation drifted the
    decade bounds (10.0 became 9.999...), so a sample worth exactly one
    decade fell into the bucket ABOVE its bound and ``quantile`` read a
    full bucket higher than ``nearest_rank`` on boundary-valued data.
    Direct exponentiation makes every decade bound exact."""
    b = log_buckets(1.0, 10000.0, per_decade=4)
    for decade in (10.0, 100.0, 1000.0, 10000.0):
        assert decade in b, f"decade bound {decade} not exact in {b}"
    # boundary-valued samples: bucket upper bounds themselves. A value
    # equal to a bound belongs to that bound's bucket ((lo, bound]), so
    # the histogram quantile must agree with nearest-rank EXACTLY — no
    # within-one-bucket tolerance for data sitting on the bounds.
    h = Histogram("boundary", b)
    samples = [1.0, 10.0, 10.0, 100.0, 1000.0, 10000.0]
    for v in samples:
        h.observe(v)
    for q in (1, 25, 50, 75, 90, 99, 100):
        assert h.quantile(q) == nearest_rank(samples, q), q


def test_histogram_merge_adds_counts_and_rejects_mismatched_bounds():
    a = Histogram("a", (1.0, 2.0))
    b = Histogram("b", (1.0, 2.0))
    a.observe(0.5)
    b.observe(1.5)
    b.observe(9.0)
    out = a.merge(b)
    assert out is a
    assert a.counts == [1, 1, 1] and a.count == 3
    assert a.sum == pytest.approx(11.0)
    with pytest.raises(ValueError):
        a.merge(Histogram("c", (1.0, 3.0)))


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError):
        Histogram("h", ())
    with pytest.raises(ValueError):
        Histogram("h", (1.0, 1.0))


# ---------------------------------------------------------------- registry --

def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("tokens_out", help="tokens")
    assert reg.counter("tokens_out") is c     # same object, help kept
    assert reg.get("tokens_out") is c and "tokens_out" in reg
    assert reg.get("nope") is None and "nope" not in reg
    reg.gauge("peak")
    reg.histogram("lat", (1.0, 2.0))
    assert {m.name for m in reg.metrics()} == {"tokens_out", "peak", "lat"}
    with pytest.raises(TypeError):
        reg.gauge("tokens_out")               # registered as a counter
    with pytest.raises(TypeError):
        reg.counter("lat")


def test_registry_exposition_format():
    reg = MetricsRegistry(labels={"replica": "2", "role": "decode"})
    reg.counter("tokens_out", help="total tokens").inc(7)
    reg.gauge("peak_pages").set(3)
    h = reg.histogram("latency_ticks", (1.0, 10.0), unit="ticks")
    h.observe(0.5)
    h.observe(5.0)
    h.observe(99.0)
    text = reg.expose()
    assert "# HELP repro_tokens_out total tokens" in text
    assert "# TYPE repro_tokens_out counter" in text
    assert 'repro_tokens_out{replica="2",role="decode"} 7' in text
    assert "# TYPE repro_peak_pages gauge" in text
    assert "# TYPE repro_latency_ticks histogram" in text
    # cumulative buckets, +Inf, sum and count
    assert 'le="1"' in text and 'le="10"' in text and 'le="+Inf"' in text
    assert text.index('le="1"') < text.index('le="10"')
    assert 'repro_latency_ticks_count{replica="2",role="decode"} 3' in text
    assert "repro_latency_ticks_sum" in text
    # extra labels merge in at exposition time
    assert 'plane="fleet"' in reg.expose(extra_labels={"plane": "fleet"})


# ---------------------------------------------------------------- StatsView --

def _view():
    reg = MetricsRegistry()
    return StatsView({"tokens_out": reg.counter("tokens_out"),
                      "peak_pages": reg.gauge("peak_pages")}), reg


def test_stats_view_preserves_dict_idioms():
    stats, reg = _view()
    stats["tokens_out"] += 5                  # read-modify-write
    stats["tokens_out"] += 2
    stats["peak_pages"] = max(stats["peak_pages"], 9)
    assert stats["tokens_out"] == 7
    assert dict(stats) == {"tokens_out": 7, "peak_pages": 9}
    assert stats == {"tokens_out": 7, "peak_pages": 9}   # __eq__ vs dict
    assert stats.get("missing", 0) == 0
    assert len(stats) == 2 and set(stats) == {"tokens_out", "peak_pages"}
    # a stats-delta comprehension (the bench idiom) still works
    before = dict(stats)
    stats["tokens_out"] += 3
    assert {k: stats[k] - before[k] for k in before} == {"tokens_out": 3,
                                                         "peak_pages": 0}
    # and the registry saw every mutation
    assert reg.get("tokens_out").value == 10


def test_stats_view_key_set_is_fixed():
    stats, _ = _view()
    with pytest.raises(KeyError):
        stats["new_key"] = 1
    with pytest.raises(TypeError):
        del stats["tokens_out"]
    assert isinstance(stats.metric("tokens_out"), Counter)
    assert isinstance(stats.metric("peak_pages"), Gauge)
