"""Mamba-2 SSD block (state-space duality), chunked for the MXU.

The chunked formulation replaces Mamba-1's sequential selective scan with
per-chunk matmuls (intra-chunk quadratic term + inter-chunk state
recurrence) — the TPU-native adaptation recorded in DESIGN.md. The pure-jnp
chunked path here is the reference/dry-run implementation; the Pallas kernel
in ``repro.kernels.ssd`` computes the intra-chunk term.

Recurrence (per head h, state dim N, head dim P):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T      (N x P)
    y_t = C_t^T h_t + D_h * x_t
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import ParamSpec


def ssm_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di = cfg.d_model, cfg.ssm_d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_ch = di + 2 * g * n
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * g * n + h), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_ch,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "norm": ParamSpec((di,), (None,), init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, g, n, h = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                   cfg.ssm_nheads)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, xBC, dt


def _causal_conv(cfg: ModelConfig, p, xBC: jnp.ndarray,
                 init_state: jnp.ndarray = None):
    """Depthwise causal conv1d + SiLU. xBC: (B, S, C)."""
    K = cfg.ssm_conv
    if init_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = init_state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)
    w = p["conv_w"].astype(xBC.dtype)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    out = jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))
    return out, full[:, -(K - 1):]    # (conv output, tail state)


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                eps: float) -> jnp.ndarray:
    from repro.models.layers import rmsnorm
    return rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   scale, eps)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                h0: jnp.ndarray = None):
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H)  A: (H,)  Bm/Cm: (B,S,G,N) with H % G == 0.
    Returns y: (B,S,H,P) and final state (B,H,N,P).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    rep = H // G

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, chunk, G, N)
    Cc = Cm.reshape(B, nc, chunk, G, N)

    a = dtc * A.astype(jnp.float32)                    # (B,nc,Q,H), negative
    cum = jnp.cumsum(a, axis=2)                        # within-chunk cumsum
    total = cum[:, :, -1]                              # (B,nc,H)

    # ---- intra-chunk (quadratic in chunk) --------------------------------
    # scores[b,c,i,j,h] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j  (j <= i)
    cb = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc,
                    preferred_element_type=jnp.float32)
    cb = jnp.repeat(cb, rep, axis=-1)                  # (B,nc,Q,Q,H)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    w = jnp.where(causal, cb * decay * dtc[:, :, None, :, :], 0.0)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # ---- chunk states -----------------------------------------------------
    # S_c[h,n,p] = sum_j B_j[n] * exp(total - cum_j) * dt_j * x_j[p]
    dec_end = jnp.exp(total[:, :, None, :] - cum)      # (B,nc,Q,H)
    b_rep = jnp.repeat(Bc, rep, axis=3)                # (B,nc,Q,H,N)
    bx = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp",
                    b_rep.astype(jnp.float32), dec_end * dtc,
                    xc.astype(jnp.float32), preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence over nc ------------------------------------
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def body(h_prev, xs):
        s_c, tot_c = xs                                # (B,H,N,P), (B,H)
        h_new = h_prev * jnp.exp(tot_c)[..., None, None] + s_c
        return h_new, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        body, h0.astype(jnp.float32),
        (jnp.moveaxis(bx, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # (B,nc,H,N,P)

    # ---- inter-chunk contribution: C_i * exp(cum_i) * h_prev ----------------
    c_rep = jnp.repeat(Cc, rep, axis=3)                # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcihn,bchnp->bcihp",
                       c_rep.astype(jnp.float32)
                       * jnp.exp(cum)[..., None],
                       h_prevs, preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(B, S, H, P).astype(x.dtype)
    return y, h_final


def ssm_train(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray,
              *_args, **_kw) -> jnp.ndarray:
    y, _ = _ssm_forward(cfg, p, x)
    return y


def ssm_prefill(cfg: ModelConfig, p, x, *_args, **_kw):
    y, cache = _ssm_forward(cfg, p, x, want_cache=True)
    return y, cache


def _ssm_forward(cfg: ModelConfig, p, x, want_cache: bool = False):
    B, S, D = x.shape
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    g, n, di = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_d_inner
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC, conv_tail = _causal_conv(cfg, p, xBC)
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + g * n].reshape(B, S, g, n)
    Cm = xBC[..., di + g * n:].reshape(B, S, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
    y = y + xs * p["D"].astype(dt_)[None, None, :, None]
    y = _gated_norm(y.reshape(B, S, di), z, p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(dt_)
    if want_cache:
        return out, {"h": h_final.astype(jnp.float32), "conv": conv_tail}
    return out, None


def ssm_decode(cfg: ModelConfig, p, x, cache: Dict[str, jnp.ndarray],
               *_args, **_kw):
    """x: (B,1,D); cache: h (B,H,N,P) fp32, conv (B,K-1,conv_ch)."""
    B = x.shape[0]
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    g, n, di = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_d_inner
    K = cfg.ssm_conv
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xBC_new, dt_raw = _split_proj(cfg, zxbcdt)       # (B,1,*)
    window = jnp.concatenate([cache["conv"].astype(dt_), xBC_new], axis=1)
    w = p["conv_w"].astype(dt_)
    conv_out = jax.nn.silu((window * w[None]).sum(axis=1, keepdims=True)
                           + p["conv_b"].astype(dt_))
    xs = conv_out[..., :di].reshape(B, H, P)
    Bm = conv_out[..., di:di + g * n].reshape(B, g, n)
    Cm = conv_out[..., di + g * n:].reshape(B, g, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                    # (B,H)
    rep = H // g
    Bh = jnp.repeat(Bm, rep, axis=1)                           # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    h = (cache["h"] * decay[..., None, None]
         + jnp.einsum("bhn,bh,bhp->bhnp", Bh.astype(jnp.float32), dt,
                      xs.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)
    y = y.astype(dt_) + xs * p["D"].astype(dt_)[None, :, None]
    y = _gated_norm(y.reshape(B, 1, di), z, p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(dt_)
    return out, {"h": h, "conv": window[:, 1:]}


def ssm_cache_spec(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    H, P, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "h": ((batch, H, n, P), ("batch", "ssm_heads", None, None), "float32"),
        "conv": ((batch, cfg.ssm_conv - 1, conv_ch),
                 ("batch", None, "ssm_inner"), cfg.dtype),
    }
