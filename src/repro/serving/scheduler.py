"""Continuous-batching serving scheduler over the paged KV cache.

The dense engine (``repro.serving.engine``) decodes one fixed batch until
its *longest* member finishes — occupancy decays as short requests drain,
and a new request waits for the whole batch. This scheduler keeps a fixed
set of decode *slots* and runs one jit-compiled paged decode step per tick:

* **join-on-arrival** — a waiting request is prefilled and inserted into
  any free slot between decode steps (no reshape, no recompile: the step
  function's shapes are fixed at ``(max_slots, 1)``);
* **evict-on-finish** — a finished request frees its pages and its slot the
  same tick, so the next arrival takes over immediately;
* **prefill/decode interleave** — admission runs between decode ticks;
  prefill is batch-1, bucketed to a small set of padded lengths so mixed
  prompt lengths share compilations (right padding is causally invisible).

Greedy sampling, like the dense engine. Admission uses worst-case page
reservation (``ceil((prompt + max_new) / page_size)`` pages), so a request
that is admitted can never hit a mid-flight pool OOM. Page-pool sizing for
a target arch/shape comes from ``repro.core.blueprint.serving_page_plan``,
and the provisioning layer exposes it as the "serve" service
(``repro.core.services.AmbariServer.provision_serving``).

Works for decoder-only archs without MLA attention; SSM/hybrid and MoE
archs are supported with exact-length prefill (an SSM state folds padding
in; MoE routing lets padding compete for expert capacity). One caveat for
MoE at multi-slot: the decode router groups all slots' tokens under one
capacity bound (exactly like the dense engine's batch), so concurrent
requests can influence each other's routing when capacity binds — the
late-join byte-determinism guarantee is for dense/SSM archs. See
docs/serving.md for the API walk-through and tuning knobs.

The request dataclass and its lifecycle live in ``repro.serving.request``
(shared with the static engine and the fabric router); this module is the
single-scheduler core only. One scheduler drives one page pool — a fleet
of them behind ``repro.serving.router.ServingRouter`` is the replicated
serving fabric, with each scheduler wrapped as a
``repro.serving.replica.ServingReplica`` placed on a cluster node.
"""
from __future__ import annotations

import collections
import functools
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.transformer import lm_forward
from repro.serving import paged_cache as PC
from repro.serving.request import Request, make_request

DEFAULT_BUCKETS = (8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512)

__all__ = ["ContinuousBatchingScheduler", "DEFAULT_BUCKETS", "Request",
           "supports_paged"]


def supports_paged(cfg: ModelConfig) -> bool:
    return not cfg.is_encdec and cfg.attn_impl != "mla"


class ContinuousBatchingScheduler:
    """Admission + continuous batching loop over ``max_slots`` decode slots.

    Parameters mirror ``serving_page_plan``'s output: ``page_size`` tokens
    per page, ``num_pages`` in the shared pool (page 0 is the sink),
    ``max_seq_len`` bounds prompt+generation and fixes the block-table
    width.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *, max_slots: int = 4,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 max_seq_len: int = 512,
                 prefill_buckets: Sequence[int] = DEFAULT_BUCKETS):
        if not supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.name}: paged serving covers decoder-only non-MLA "
                "archs; use repro.serving.engine for this one")
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.n_pg = PC.pages_for_len(max_seq_len, page_size)
        if num_pages is None:
            num_pages = max_slots * self.n_pg + 1        # + sink
        # SSM state folds every processed token in, and MoE routing makes
        # tokens compete for expert capacity — bucket padding would change
        # real tokens' results for either, so such archs prefill exact-length
        # (one compile per distinct prompt length).
        self.exact_prefill = cfg.n_routed_experts > 0 or any(
            cfg.block_kind(i) == "ssm" for i in range(cfg.n_layers))
        self.buckets = tuple(sorted(b for b in prefill_buckets
                                    if b <= max_seq_len))

        self.cache = PC.init_paged_cache(cfg, num_pages, page_size, max_slots)
        self.alloc = PC.PageAllocator(num_pages)
        self.block_table = np.full((max_slots, self.n_pg), PC.SINK_PAGE,
                                   np.int32)
        self.seq_lens = np.zeros((max_slots,), np.int32)
        self.last_tokens = np.zeros((max_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.waiting: Deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self._admit_done: List[Request] = []
        self.step_idx = 0
        self.reserved_pages = 0
        # live resize (repro.autoscale): slots above target_slots are
        # draining — no new admissions; the arrays shrink once they empty
        self.target_slots = max_slots
        # a controller may promise future pool growth up to this many pages
        # so submit() validates against the band ceiling, not today's pool
        self.capacity_hint: Optional[int] = None
        self.stats: Dict[str, int] = {"decode_steps": 0, "tokens_out": 0,
                                      "prefills": 0, "peak_pages": 0,
                                      "admit_blocked": 0, "resizes": 0}

        # donate the cache: pools are sized to fill HBM, so the step must
        # update them in place rather than double-buffer (cf. trainer.py)
        self._decode_fn = jax.jit(functools.partial(self._decode_multi, cfg),
                                  static_argnames=("k",), donate_argnums=(1,))
        self._prefill_fns: Dict[int, Any] = {}
        self._insert_fns: Dict[int, Any] = {}
        self._rid = 0

    # ------------------------------------------------------------ jit fns --
    @staticmethod
    def _decode_multi(cfg, params, cache, tokens, seq_lens, block_table, *,
                      k: int):
        """``k`` fused greedy decode ticks in one lax.scan (one dispatch).

        The host loop picks ``k`` so that no request finishes and no arrival
        becomes admissible mid-scan — fusion is a pure dispatch-overhead
        optimisation, token-for-token identical to k=1 stepping.
        Returns (tokens (k, B), new_cache).
        """
        def body(carry, _):
            toks, lens, cc = carry
            lg, cc = M.paged_decode_step(cfg, params, cc, toks, lens,
                                         block_table)
            nxt = jnp.argmax(lg[:, -1, :cfg.vocab_size],
                             axis=-1).astype(jnp.int32)
            return (nxt[:, None], lens + 1, cc), nxt

        (_, _, new_cache), outs = jax.lax.scan(
            body, (tokens, seq_lens, cache), None, length=k)
        return outs, new_cache

    def _prefill_fn(self, n: int):
        """Batch-1 prefill at padded length ``n``; logits taken at the live
        prompt's last position (right padding is causally invisible)."""
        if n not in self._prefill_fns:
            cfg = self.cfg

            def fn(params, tokens, plen):
                positions = None
                if cfg.rope_variant == "mrope":
                    pos = jnp.broadcast_to(
                        jnp.arange(n, dtype=jnp.int32)[None], (1, n))
                    positions = jnp.broadcast_to(pos[None], (3, 1, n))
                hidden, _, pre = lm_forward(cfg, params, tokens,
                                            positions=positions,
                                            mode="prefill")
                h_last = jax.lax.dynamic_slice_in_dim(hidden, plen - 1, 1,
                                                      axis=1)
                lg = M.final_logits(cfg, params, h_last)
                tok = jnp.argmax(lg[0, -1, :cfg.vocab_size]).astype(jnp.int32)
                return tok, pre

            self._prefill_fns[n] = jax.jit(fn)
        return self._prefill_fns[n]

    def _insert_fn(self, n: int):
        if n not in self._insert_fns:
            cfg, ps = self.cfg, self.page_size

            def fn(cache, pre, block_row, slot, plen):
                return PC.write_prefill(cfg, cache, pre, block_row, slot,
                                        plen, n, ps)

            self._insert_fns[n] = jax.jit(fn, donate_argnums=(0,))
        return self._insert_fns[n]

    # ---------------------------------------------------------- submission --
    def submit(self, prompt, max_new_tokens: int,
               arrival_step: int = 0) -> Request:
        req = make_request(self._rid, prompt, max_new_tokens, arrival_step)
        self._rid += 1
        return self.submit_request(req)

    def submit_request(self, req: Request) -> Request:
        """Enqueue a pre-built request (the fabric router's entry point: the
        router owns rid assignment, so the same object travels through
        whichever replica scheduler ends up decoding it)."""
        total = req.plen + req.max_new_tokens
        if total > self.max_seq_len:
            raise ValueError(f"request needs {total} positions > "
                             f"max_seq_len {self.max_seq_len}")
        worst = PC.pages_for_len(total, self.page_size)
        cap = self.alloc.capacity
        if self.capacity_hint is not None:
            cap = max(cap, self.capacity_hint - 1)
        if worst > cap:
            raise ValueError(
                f"request reserves {worst} pages but the pool only holds "
                f"{cap} — it could never be admitted")
        self.waiting.append(req)
        return req

    # ----------------------------------------------------------- admission --
    def _free_slots(self) -> List[int]:
        # slots at or above target_slots are draining (pending shrink)
        return [i for i, r in enumerate(self.slot_req[:self.target_slots])
                if r is None]

    def _try_admit(self) -> None:
        while self.waiting and self.waiting[0].arrival_step <= self.step_idx:
            free = self._free_slots()   # re-list: _admit may finish a slot
            if not free:
                self.stats["admit_blocked"] += 1
                break
            req = self.waiting[0]
            need = PC.pages_for_len(req.plen + req.max_new_tokens,
                                    self.page_size)
            if self.alloc.num_free - (self.reserved_pages
                                      - self.pages_in_use) < need:
                self.stats["admit_blocked"] += 1
                break                       # reservation would overcommit
            self.waiting.popleft()
            self._admit(req, free[0], need)

    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self.slot_pages)

    def _bucket(self, plen: int) -> int:
        if self.exact_prefill:
            return plen
        for b in self.buckets:
            if plen <= b:
                return b
        return self.max_seq_len

    def _admit(self, req: Request, slot: int, reserve: int) -> None:
        plen = req.plen
        n = self._bucket(plen)
        tokens = np.zeros((1, n), np.int32)
        tokens[0, :plen] = req.prompt
        first, pre = self._prefill_fn(n)(self.params, jnp.asarray(tokens),
                                         jnp.asarray(plen, jnp.int32))
        pages = self.alloc.alloc(PC.pages_for_len(plen + 1, self.page_size),
                                 owner=req.rid)
        self.reserved_pages += reserve
        row = np.full((self.n_pg,), PC.SINK_PAGE, np.int32)
        row[:len(pages)] = pages
        self.cache = self._insert_fn(n)(self.cache, pre, jnp.asarray(row),
                                        jnp.asarray(slot, jnp.int32),
                                        jnp.asarray(plen, jnp.int32))
        self.block_table[slot] = row
        self.seq_lens[slot] = plen
        self.last_tokens[slot, 0] = int(first)
        self.slot_req[slot] = req
        self.slot_pages[slot] = pages
        req.admit_step = self.step_idx
        req.out_tokens.append(int(first))
        self.stats["prefills"] += 1
        self.stats["tokens_out"] += 1
        if req.done:                        # max_new_tokens == 1
            self._finish(slot)
            self._admit_done.append(req)

    # -------------------------------------------------------------- finish --
    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.finish_step = self.step_idx
        self.alloc.free(self.slot_pages[slot])
        self.reserved_pages -= PC.pages_for_len(
            req.plen + req.max_new_tokens, self.page_size)
        self.slot_pages[slot] = []
        self.slot_req[slot] = None
        self.block_table[slot] = PC.SINK_PAGE
        self.seq_lens[slot] = 0
        self.last_tokens[slot, 0] = 0
        self.finished.append(req)

    def _grow_pages(self, k: int = 1) -> None:
        """Ensure each active slot owns the pages its next ``k`` tokens land
        in (admission reserved them, so allocation cannot fail here)."""
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            needed = (int(self.seq_lens[slot]) + k - 1) // self.page_size + 1
            while len(self.slot_pages[slot]) < needed:
                new = self.alloc.alloc(1, owner=req.rid)[0]
                self.block_table[slot, len(self.slot_pages[slot])] = new
                self.slot_pages[slot].append(new)

    def _fuse_k(self, max_fuse: int) -> int:
        """Largest tick count that changes nothing mid-scan: bounded by the
        earliest finish among active requests and the next future arrival."""
        k = min(r.max_new_tokens - len(r.out_tokens)
                for r in self.slot_req if r is not None)
        future = [r.arrival_step - self.step_idx for r in self.waiting
                  if r.arrival_step > self.step_idx]
        if future:
            k = min(k, min(future))
        return max(1, min(k, max_fuse))

    # -------------------------------------------------------------- resize --
    def resize(self, *, max_slots: Optional[int] = None,
               num_pages: Optional[int] = None) -> None:
        """Live capacity change (the autoscaler's actuation point).

        Growth is immediate: slot-state rows / page pools are zero-padded,
        which leaves every live sequence's pages and tokens untouched.
        Shrink is drain-before-shrink: slots >= the new target stop
        admitting and the arrays slice down once those slots empty; pages
        >= the new pool size are retired from the free list now and the
        pools slice once their last owner finishes. A page shrink is
        clamped so the pool always covers every outstanding admission
        reservation — an admitted request can never hit a mid-flight OOM,
        resize or not. Each distinct (slots, pages) shape costs one jit
        re-trace, so callers should bucket targets (see
        ``repro.autoscale.controller``).
        """
        if max_slots is not None:
            if max_slots < 1:
                raise ValueError("max_slots must be >= 1")
            if max_slots > self.max_slots:
                self._grow_slots(max_slots)
            self.target_slots = max_slots
        if num_pages is not None:
            # reservation-aware floor (+1 for the sink page)
            num_pages = max(num_pages, self.reserved_pages + 1, 2)
            if num_pages > self.alloc.num_pages:
                self.cache = PC.resize_cache_pages(self.cache, num_pages)
                self.alloc.grow(num_pages)
            else:
                self.alloc.request_shrink(num_pages)
        self.stats["resizes"] += 1
        self._settle_resize()

    def _grow_slots(self, new: int) -> None:
        pad = new - self.max_slots
        self.block_table = np.vstack(
            [self.block_table,
             np.full((pad, self.n_pg), PC.SINK_PAGE, np.int32)])
        self.seq_lens = np.concatenate(
            [self.seq_lens, np.zeros((pad,), np.int32)])
        self.last_tokens = np.vstack(
            [self.last_tokens, np.zeros((pad, 1), np.int32)])
        self.slot_req.extend([None] * pad)
        self.slot_pages.extend([] for _ in range(pad))
        self.cache = PC.resize_cache_slots(self.cache, new)
        self.max_slots = new

    def _settle_resize(self) -> None:
        """Complete any drained shrink (called between decode ticks)."""
        n = self.target_slots
        if n < self.max_slots and all(r is None for r in self.slot_req[n:]):
            self.block_table = self.block_table[:n]
            self.seq_lens = self.seq_lens[:n]
            self.last_tokens = self.last_tokens[:n]
            del self.slot_req[n:]
            del self.slot_pages[n:]
            self.cache = PC.resize_cache_slots(self.cache, n)
            self.max_slots = n
        if self.alloc.shrink_ready():
            self.cache = PC.resize_cache_pages(self.cache,
                                               self.alloc.complete_shrink())

    # ---------------------------------------------------------------- step --
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    @property
    def pending(self) -> int:
        return len(self.waiting)

    @property
    def pending_due(self) -> int:
        """Waiting requests whose arrival time has passed — the real queue
        depth (benchmarks submit whole traces upfront with future
        ``arrival_step``s; those must not read as present load)."""
        return sum(r.arrival_step <= self.step_idx for r in self.waiting)

    def step(self, max_fuse: int = 16) -> List[Request]:
        """Admit what fits, run up to ``max_fuse`` fused decode ticks, evict
        finished requests.

        Fusing runs several ticks in one jit dispatch (a lax.scan) but only
        when nothing could change mid-scan — no active request finishes and
        no waiting arrival becomes due — so the schedule (and every token)
        is identical to single-stepping. Returns the requests that finished.
        A tick with no active slots (arrival gap) only advances the clock.
        """
        self._settle_resize()
        self._try_admit()
        done_now: List[Request] = self._admit_done
        self._admit_done = []
        if not self.num_active:
            arrivals = [r.arrival_step for r in self.waiting]
            if arrivals and min(arrivals) > self.step_idx:
                # idle gap: skip toward the next arrival instead of spinning
                # ticks — capped at max_fuse so a control loop driving this
                # scheduler still samples (and can scale in) inside the gap
                self.step_idx = min(min(arrivals), self.step_idx + max_fuse)
            else:
                self.step_idx += 1
            return done_now
        k = self._fuse_k(max_fuse)
        k = 1 << (k.bit_length() - 1)       # pow2 buckets bound compiles
        self._grow_pages(k)
        self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                       self.pages_in_use)
        outs, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(self.last_tokens),
            jnp.asarray(self.seq_lens), jnp.asarray(self.block_table), k=k)
        outs = np.asarray(outs)             # (k, max_slots)
        self.stats["decode_steps"] += k
        self.step_idx += k                  # before _finish: finish_step must
        for slot, req in enumerate(self.slot_req):  # not depend on max_fuse
            if req is None:
                continue
            req.out_tokens.extend(int(t) for t in outs[:, slot])
            self.stats["tokens_out"] += k
            self.last_tokens[slot, 0] = int(outs[-1, slot])
            self.seq_lens[slot] += k
            if req.done:
                done_now.append(req)
                self._finish(slot)
        return done_now

    def run(self, max_steps: int = 100_000,
            max_fuse: int = 32) -> List[Request]:
        """Drive ``step`` until every submitted request has finished."""
        while (self.waiting or self.num_active) and max_steps:
            self.step(max_fuse=max_fuse)
            max_steps -= 1
        if self.waiting or self.num_active:
            raise RuntimeError(
                f"run() exhausted max_steps with {len(self.waiting)} waiting "
                f"and {self.num_active} active requests")
        return self.finished
