"""Pipeline parallelism: GPipe-style stage executor on a mesh axis.

Completes the parallelism menu (DP/FSDP/TP/EP/SP + PP). The scan-over-layers
layout makes PP natural: the stacked layer dim is sharded over a ``stage``
mesh axis, each stage runs its local layers, and activations hop stages via
``lax.ppermute`` inside ``jax.shard_map``. The schedule is the classic GPipe
fill/steady/drain loop over microbatches (bubble fraction
(S-1)/(S-1+M)); compute and the permute collective overlap across
iterations under XLA's async scheduling on TPU.

Used for depth-dominated models when a single stage's layers + optimizer
shard exceed HBM even under FSDP; validated bit-close against sequential
execution in tests/spmd_scripts (8-device subprocess).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.collectives import shard_map_compat


def gpipe_forward(stack_params: Any, x: jnp.ndarray, *,
                  body: Callable[[Any, jnp.ndarray], jnp.ndarray],
                  mesh, axis: str = "stage", n_micro: int):
    """Run ``body`` over a layer stack sharded on ``axis``.

    stack_params: pytree with leading layer dim L on every leaf, sharded on
        ``axis`` (L % n_stages == 0 — each stage owns L/n_stages layers).
    x: (B, ...) activations, replicated; B % n_micro == 0.
    body(layer_params, h) -> h applies ONE layer.

    Returns f(x) with layers applied in order, identical to the sequential
    loop (up to dtype round-off).
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro

    def stage_program(p_local, x_rep):
        sid = jax.lax.axis_index(axis)
        micro = x_rep.reshape((n_micro, mb) + x_rep.shape[1:])

        def run_local(h):
            def step(c, pl):
                return body(pl, c), None
            out, _ = jax.lax.scan(step, h, p_local)
            return out

        ticks = n_micro + n_stages - 1
        carry = jnp.zeros_like(micro[0])
        acc = jnp.zeros_like(micro)
        for t in range(ticks):
            inject = micro[min(t, n_micro - 1)]
            h_in = jnp.where(sid == 0, inject, carry)
            h_out = run_local(h_in)
            # last stage banks finished microbatch (t - n_stages + 1)
            m = t - (n_stages - 1)
            if m >= 0:
                bank = jnp.where(sid == n_stages - 1, h_out,
                                 jnp.zeros_like(h_out))
                acc = acc.at[m].set(bank)
            carry = jax.lax.ppermute(
                h_out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
        # only the last stage holds real outputs; share with everyone
        acc = jax.lax.psum(acc, axis) / 1.0
        return acc.reshape(x_rep.shape)

    fn = shard_map_compat(stage_program, mesh=mesh,
                          in_specs=(P(axis), P()), out_specs=P())
    return fn(stack_params, x)


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages - 1 + n_micro)
