"""Host-RAM KV page tier: swap-out/swap-in byte identity (fp32, int8,
fp8 pools), the recompute-vs-transfer cost model, session retention +
preemption byte identity through the scheduler (dense and SSM archs),
priority-class and tenant-quota admission, prefix-index LRU cap and
whole-chain swap atomicity, tier teardown, and the blueprint plan's
host-budget axis. See docs/serving.md ("Memory tiers & preemption")."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, REDUCED
from repro.core.blueprint import serving_page_plan
from repro.models import model as M
from repro.serving import paged_cache as PC
from repro.serving.scheduler import ContinuousBatchingScheduler

CFG = dataclasses.replace(REDUCED["qwen3-32b"], dtype="float32")


@pytest.fixture(scope="module")
def params():
    return M.init(CFG, jax.random.PRNGKey(0))


# ------------------------------------------------------- swap primitives --

def _randomized(cache, seed):
    """Same pytree, every leaf filled with seeded noise in its own dtype —
    arbitrary pool contents for the byte-preservation checks."""
    rng = np.random.RandomState(seed)

    def fill(leaf):
        dt = np.dtype(leaf.dtype)
        if dt.kind in "iu":
            arr = rng.randint(-120, 120, size=leaf.shape).astype(dt)
        else:
            arr = rng.standard_normal(leaf.shape).astype(dt)
        return jnp.asarray(arr)

    return jax.tree_util.tree_map(fill, cache)


def _page_rows(cache, page, tp=1):
    """Every attention leaf's row for one physical page, keyed by path —
    the unit of content the swap ops must move verbatim."""
    rows = {}

    def walk(node, stacked, path):
        if PC._is_attn(node):
            ax = PC.page_axis(stacked, tp)
            lead = (slice(None),) * ax
            for k in PC.PAGE_LEAVES:
                if k in node:
                    rows[path + k] = np.asarray(
                        jax.device_get(node[k][lead + (page,)]))
            return
        if PC._is_ssm(node):
            return
        for k in node:
            walk(node[k], stacked or k == "stack", path + k + "/")

    walk(cache, False, "")
    return rows


def _bytes(a):
    return np.ascontiguousarray(a).view(np.uint8).tobytes()


@pytest.mark.parametrize("quant", [False, "int8", "fp8"])
def test_swap_round_trip_byte_identity(quant):
    """swap_out -> swap_in restores every pool leaf's page row bit-exactly
    (quantised pools and their scale pages included), into *different*
    device pages, and leaves the host tier empty."""
    cfg = dataclasses.replace(CFG, cache_quant=quant)
    cache = _randomized(PC.init_paged_cache(cfg, 8, 4, 2), seed=3)
    tier = PC.HostPageTier(6)
    src, dst = [2, 5, 3], [7, 1, 4]
    want = {p: _page_rows(cache, p) for p in src}
    host = PC.swap_out_pages(cache, tier, src)
    assert len(host) == 3
    assert tier.pages_used == 3 and tier.bytes_used > 0
    cache = PC.swap_in_pages(cache, tier, host, dst)
    assert tier.pages_used == 0 and tier.bytes_used == 0
    for s, d in zip(src, dst):
        got = _page_rows(cache, d)
        assert set(got) == set(want[s])
        for path in want[s]:
            assert _bytes(got[path]) == _bytes(want[s][path]), (quant, path)


def test_host_tier_residency_bit():
    assert not PC.is_host_page(5)
    h = PC.as_host_page(5)
    assert PC.is_host_page(h) and PC.host_page_id(h) == 5
    assert PC.as_host_page(h) == h


# -------------------------------------------------------------- cost model --

def test_swap_resume_cost_monotone_and_deterministic():
    t1, r1 = PC.swap_resume_cost(CFG, 64, 8, 8)
    t2, r2 = PC.swap_resume_cost(CFG, 128, 16, 8)
    assert t2 > t1 and r2 > r1
    assert (t1, r1) == PC.swap_resume_cost(CFG, 64, 8, 8)


def test_swap_crossover_reduced_vs_full_dims():
    """At REDUCED dims recompute undercuts PCIe at any length (crossover
    None); at full-model dims transfer wins from the crossover on — and
    the cost model agrees with its own crossover."""
    assert PC.swap_crossover_tokens(CFG, 8) is None
    full = ARCHS["qwen3-32b"]
    x = PC.swap_crossover_tokens(full, 16)
    assert x is not None and x >= 1
    t, r = PC.swap_resume_cost(full, x, PC.pages_for_len(x, 16), 16)
    assert t <= r


# ------------------------------------------------- scheduler session flow --

def _drive_sessions(sched, bases, turns, gen, seed):
    """Multi-turn sessions: each turn resubmits transcript + fresh user
    tokens after the previous turn fully drained (the idle gap)."""
    rng = np.random.RandomState(seed)
    prompts = [np.asarray(b, np.int32) for b in bases]
    hist = [[] for _ in bases]
    for _ in range(turns):
        reqs = [sched.submit(p, gen) for p in prompts]
        sched.run()
        for i, r in enumerate(reqs):
            hist[i].append(list(r.out_tokens))
            ext = rng.randint(0, sched.cfg.vocab_size, size=4
                              ).astype(np.int32)
            prompts[i] = np.concatenate(
                [prompts[i], np.asarray(r.out_tokens, np.int32), ext])
    return hist


def _session_bases(rng, vocab, lens):
    return [rng.randint(0, vocab, size=n).astype(np.int32) for n in lens]


@pytest.mark.parametrize("quant", [False, "int8"])
def test_session_byte_identity_under_pressure(params, quant):
    """Tier-on vs tier-off on the same tight pool: byte-identical tokens
    while the cost model demonstrably takes both resume paths (long
    chains swap to host, short ones re-prefill)."""
    cfg = dataclasses.replace(CFG, cache_quant=quant)
    kw = dict(max_slots=2, page_size=8, max_seq_len=128, num_pages=28,
              prefix_cache=True)
    off = ContinuousBatchingScheduler(cfg, params, **kw)
    on = ContinuousBatchingScheduler(cfg, params, host_pages=64,
                                     swap_crossover=40, **kw)
    bases = _session_bases(np.random.RandomState(0), CFG.vocab_size,
                           (12, 60, 20, 90))
    h_off = _drive_sessions(off, bases, 2, 4, seed=7)
    h_on = _drive_sessions(on, bases, 2, 4, seed=7)
    assert h_on == h_off
    assert on.stats["swap_outs"] > 0
    assert on.stats["swap_ins"] > 0, "no chain ever swapped back in"
    assert on.stats["swap_reprefills"] > 0, "no chain was ever re-prefilled"
    assert on.alloc.num_pages == off.alloc.num_pages == 28
    assert off.stats["swap_outs"] == off.stats["swap_ins"] == 0


def test_session_resume_saves_prefill_work(params):
    """The tier's dividend: turn-2 admissions prefix-hit the retained
    chains, so cached tokens flow and resume latency is recorded."""
    sched = ContinuousBatchingScheduler(
        CFG, params, max_slots=2, page_size=8, max_seq_len=128,
        num_pages=28, prefix_cache=True, host_pages=64, swap_crossover=40)
    bases = _session_bases(np.random.RandomState(0), CFG.vocab_size,
                           (12, 60, 20, 90))
    _drive_sessions(sched, bases, 2, 4, seed=7)
    assert sched.stats["prefix_hits"] > 0
    assert sched.stats["cached_tokens"] > 0
    if sched.stats["swap_ins"]:
        assert sched.h_resume.count == sched.stats["swap_ins"]
        assert sched.h_resume.quantile(99) < 64


def test_ssm_session_byte_identity(params):
    """Hybrid/SSM retention resumes from an exact-entry state snapshot;
    tokens must match the tier-off run exactly."""
    cfg = dataclasses.replace(REDUCED["mamba2-1.3b"], dtype="float32")
    p = M.init(cfg, jax.random.PRNGKey(0))
    kw = dict(max_slots=2, page_size=8, max_seq_len=96, num_pages=20,
              prefix_cache=True)
    off = ContinuousBatchingScheduler(cfg, p, **kw)
    on = ContinuousBatchingScheduler(cfg, p, host_pages=48,
                                     swap_crossover=32, **kw)
    bases = _session_bases(np.random.RandomState(1), cfg.vocab_size,
                           (10, 44, 52))
    h_off = _drive_sessions(off, bases, 2, 4, seed=9)
    h_on = _drive_sessions(on, bases, 2, 4, seed=9)
    assert h_on == h_off
    assert on.stats["swap_outs"] + on.stats["swap_reprefills"] > 0


def test_drop_tier_state_clean(params):
    """Replica failure forgets both tiers: allocator back to baseline,
    host rows gone, gauges zeroed — nothing leaks."""
    sched = ContinuousBatchingScheduler(
        CFG, params, max_slots=2, page_size=8, max_seq_len=128,
        num_pages=28, prefix_cache=True, host_pages=64, swap_crossover=40)
    base_alloc = sched.alloc.num_allocated
    bases = _session_bases(np.random.RandomState(0), CFG.vocab_size,
                           (12, 60, 20, 90))
    _drive_sessions(sched, bases, 2, 4, seed=7)
    assert (sched.stats["retained_pages"] > 0
            or sched.stats["host_pages_used"] > 0)
    sched.drop_tier_state()
    assert sched.alloc.num_allocated == base_alloc
    assert sched.host_tier.pages_used == 0
    assert sched.host_tier.bytes_used == 0
    assert sched.stats["retained_pages"] == 0
    assert sched.stats["host_pages_used"] == 0


# --------------------------------------------------- priority and quotas --

def test_priority_admission_order(params):
    """Under slot contention the higher class goes first; equal classes
    keep exact FCFS (the pre-tier admission order)."""
    rng = np.random.RandomState(1)

    def prompt():
        return rng.randint(0, CFG.vocab_size, size=8).astype(np.int32)

    sched = ContinuousBatchingScheduler(CFG, params, max_slots=1,
                                        page_size=8, max_seq_len=64)
    lo = sched.submit(prompt(), 4, priority=0)
    hi = sched.submit(prompt(), 4, priority=3)
    sched.run()
    assert hi.finish_step < lo.finish_step

    a = sched.submit(prompt(), 4)
    b = sched.submit(prompt(), 4)
    sched.run()
    assert a.finish_step <= b.finish_step


def test_tenant_quota_blocks_then_drains(params):
    """A tenant at its page quota queues (quota_blocked counts it) but
    drains as its own reservations release; other tenants are unaffected."""
    sched = ContinuousBatchingScheduler(
        CFG, params, max_slots=4, page_size=8, max_seq_len=64,
        tenant_quotas={"free": 3})
    rng = np.random.RandomState(2)
    free = [sched.submit(rng.randint(0, CFG.vocab_size, size=16
                                     ).astype(np.int32), 4, tenant="free")
            for _ in range(3)]
    pro = sched.submit(rng.randint(0, CFG.vocab_size, size=16
                                   ).astype(np.int32), 4, tenant="pro")
    sched.run()
    assert all(len(r.out_tokens) == 4 for r in free + [pro])
    assert sched.stats["quota_blocked"] > 0
    assert sched._tenant_reserved.get("free", 0) == 0
    # the unquota'd tenant was never held behind the free tier's queue
    assert pro.finish_step <= max(r.finish_step for r in free)


def test_submit_rejects_bad_priority(params):
    sched = ContinuousBatchingScheduler(CFG, params, max_slots=1,
                                        page_size=8, max_seq_len=64)
    with pytest.raises(ValueError):
        sched.submit(np.zeros(4, np.int32), 2, priority=-1)


# -------------------------------------------------------- index residency --

def test_prefix_index_exact_lru_cap():
    idx = PC.PrefixIndex(4, max_exact=2)
    alloc = PC.PageAllocator(32)
    dropped = []
    idx.on_evict = dropped.append
    chains = []
    for i in range(4):
        prompt = (np.arange(8) + 100 * i).astype(np.int32)
        idx.insert(prompt, alloc.alloc(2), state=("s", i))
        chains.append(prompt)
    assert idx.evictions == 2 and len(dropped) == 2
    assert idx.lookup(chains[0], need_state=True) is None
    hit = idx.lookup(chains[3], need_state=True)
    assert hit is not None and hit.state == ("s", 3)


def test_swap_chain_remaps_whole_chains_only():
    """Entries move only when their entire chain is in the mapping — the
    index never holds a half-swapped chain."""
    idx = PC.PrefixIndex(4)
    a = np.arange(8, dtype=np.int32)
    b = np.arange(100, 112, dtype=np.int32)
    idx.insert(a, [1, 2])
    idx.insert(b, [5, 6, 7])
    H = PC.as_host_page
    assert idx.swap_chain({1: H(1), 2: H(2)}) == 2   # both boundaries of a
    assert idx.lookup(a).full_pages == [H(1), H(2)]
    assert idx.lookup(b).full_pages == [5, 6, 7]     # untouched
    # partial mapping: only the 1-page chain moves, longer ones stay put
    assert idx.swap_chain({5: H(5)}) == 1
    assert idx.lookup(b).full_pages == [5, 6, 7]
    # and back in, to fresh device ids
    assert idx.swap_chain({H(1): 11, H(2): 12}) == 2
    assert idx.lookup(a).full_pages == [11, 12]


# -------------------------------------------------------------- blueprint --

def test_serving_page_plan_host_axis():
    cfg = ARCHS["qwen3-32b"]
    shape = SHAPES["decode_32k"]
    mesh = {"model": 8, "data": 4}
    base = serving_page_plan(cfg, shape, mesh)
    assert "host_tier" not in base
    plan = serving_page_plan(cfg, shape, mesh, host_ram=64 << 30)
    ht = plan["host_tier"]
    tok = PC.page_bytes_per_token(cfg)
    assert ht["host_ram_bytes"] == 64 << 30
    assert ht["host_pages"] == (64 << 30) // (tok * plan["page_size"])
    assert ht["max_open_sessions"] >= plan["max_concurrent_seqs"]
    assert (ht["max_open_sessions"] - plan["max_concurrent_seqs"]
            == ht["host_pages"] // plan["pages_per_seq"])
    with pytest.raises(ValueError):
        serving_page_plan(cfg, shape, mesh, host_ram=0)
