"""The paper's eight demonstration use cases (Appendix A), as tests."""
import pytest

from repro.core.cluster import ClusterManager
from repro.core.interaction import InteractionError
from repro.core.simcloud import InstanceState

TEXT = b"""the quick brown fox jumps over the lazy dog
the dog barks and the fox runs
"""


@pytest.fixture()
def platform():
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=6,
                           services=("hdfs", "yarn", "zookeeper", "spark",
                                     "hue"))
    return mgr, ic


def test_use_case_1_provision_and_install(platform):
    """6-node cluster with the selected services installed + started."""
    _, ic = platform
    assert len(ic.cluster.slaves) == 6
    st = ic.ambari.status()
    assert st["spark"] == "started" and st["hdfs"] == "started"
    assert ic.bringup_seconds < 30 * 60       # "minutes, not hours"


def test_use_case_2_stop_cluster(platform):
    mgr, ic = platform
    ic.lifecycle.stop(ic.cluster)
    states = {mgr.cloud.instances[i].state for i in ic.cluster.instance_ids}
    assert states == {InstanceState.STOPPED}
    assert mgr.cloud.hourly_cost(ic.cluster.instance_ids) == 0.0


def test_use_case_3_start_cluster_slaves_first(platform):
    mgr, ic = platform
    ic.lifecycle.stop(ic.cluster)
    ic.lifecycle.start(ic.cluster)
    log = ic.log
    assert log.first_index("start_slaves") < log.first_index("start_master")
    # master re-discovers new private IPs (paper's restart story)
    assert log.first_index("start_master") < log.last_index(
        "remap_private_ips")
    states = {mgr.cloud.instances[i].state for i in ic.cluster.instance_ids}
    assert states == {InstanceState.RUNNING}


def test_use_case_4_extend_by_three(platform):
    _, ic = platform
    before = len(ic.cluster.directory.slaves())
    nodes = ic.lifecycle.extend(ic.cluster, 3)
    assert [n.hostname for n in nodes] == [f"slave-{before + i}"
                                           for i in range(3)]
    assert len(ic.cluster.directory.slaves()) == before + 3


def test_use_case_5_browse_storage(platform):
    _, ic = platform
    ic.hue.upload_file("/data/corpus.txt", TEXT)
    listing = ic.hue.browse_storage("/data")
    assert listing == [{"path": "/data/corpus.txt", "bytes": len(TEXT)}]


def test_use_case_6_submit_job(platform):
    _, ic = platform
    job = ic.hue.submit_job("spark", lambda: sum(range(10)))
    assert job.status == "succeeded" and job.result == 45


def test_use_case_7_upload_to_hdfs(platform):
    _, ic = platform
    info = ic.hue.upload_file("/data/corpus.txt", TEXT)
    assert info["bytes"] == len(TEXT)
    assert len(info["placement"]) >= 1


def test_use_case_8_wordcount(platform):
    _, ic = platform
    ic.hue.upload_file("/data/corpus.txt", TEXT)
    counts = ic.hue.run_wordcount("/data/corpus.txt")
    assert counts["the"] == 4
    assert counts["fox"] == 2
    assert counts["dog"] == 2
    assert counts["barks"] == 1


def test_interaction_requires_running_services():
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=2, services=("hdfs", "hue"))
    with pytest.raises(InteractionError):
        ic.hue.submit_job("spark", lambda: 1)   # spark not installed
