"""Speculative decoding in the paged scheduler.

The contract under test is byte identity: greedy draft-and-verify emits
exactly the tokens spec-off decoding would, for every composition the
scheduler supports — dense and SSM archs, the Pallas prefill-kernel verify
path, tensor parallelism, chunked prefill, the prefix cache, the draft
model, and the fleet router. Around that core: the host-side acceptance
rule and n-gram speculator as units, construction-time rejections (MoE,
vocab mismatch, spec_draft without spec_k), cap semantics (a verify tick
can never overrun the token budget or the admission page reservation),
and the two bugfix regressions that rode this PR — the idle fast-forward
firing past a PREFILLING/parked backlog, and a donor replica failing
mid-handoff double-freeing the migrated stream's pages.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import model as M
from repro.serving.replica import ServingReplica
from repro.serving.request import RequestState, make_request
from repro.serving.router import ServingRouter
from repro.serving.scheduler import ContinuousBatchingScheduler, spec_accept

_PARAMS = {}


def _params(arch):
    if arch not in _PARAMS:
        cfg = dataclasses.replace(REDUCED[arch], dtype="float32")
        _PARAMS[arch] = (cfg, M.init(cfg, jax.random.PRNGKey(0)))
    return _PARAMS[arch]


def _trace(cfg, seed, n=4, p_lo=3, p_hi=22, g_lo=2, g_hi=7):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size,
                         size=int(rng.randint(p_lo, p_hi + 1))
                         ).astype(np.int32),
             int(rng.randint(g_lo, g_hi + 1))) for _ in range(n)]


def _serve(cfg, params, trace, arrivals=None, **kw):
    s = ContinuousBatchingScheduler(cfg, params, max_slots=3, page_size=8,
                                    max_seq_len=64, **kw)
    reqs = [s.submit(p, g, arrival_step=arrivals[i] if arrivals else i // 2)
            for i, (p, g) in enumerate(trace)]
    s.run()
    return s, [list(r.out_tokens) for r in reqs]


# ------------------------------------------------------------ host units --

def test_spec_accept_unit():
    assert spec_accept([], []) == 0
    assert spec_accept([5, 6, 7], [5, 6, 7]) == 3
    assert spec_accept([5, 6, 7], [5, 6, 9]) == 2
    assert spec_accept([5, 6, 7], [9, 6, 7]) == 0
    # acceptance stops at the first mismatch even if later tokens agree
    assert spec_accept([1, 2, 3], [1, 9, 3]) == 1


def test_ngram_draft_unit():
    draft = ContinuousBatchingScheduler._ngram_draft
    # the final 3-gram (4,5,6) occurred earlier, followed by 7,8
    req = make_request(0, [1, 4, 5, 6, 7, 8, 2, 4, 5, 6], 4)
    np.testing.assert_array_equal(draft(None, req, 2), [7, 8])
    # cap truncates the proposal
    np.testing.assert_array_equal(draft(None, req, 1), [7])
    # generated tokens extend the lookup context
    req2 = make_request(1, [4, 5, 9, 9], 8)
    req2.out_tokens = [4, 5]
    d = draft(None, req2, 3)
    assert d.size and int(d[0]) == 9          # 2-gram (4,5) -> 9 follows
    # no earlier occurrence of any suffix m-gram: no proposal
    req3 = make_request(2, [1, 2, 3, 4], 4)
    assert draft(None, req3, 4).size == 0


# ----------------------------------------------- construction rejections --

def test_spec_construction_rejections():
    cfg, params = _params("qwen3-32b")
    with pytest.raises(ValueError, match="spec_k must be in"):
        ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, spec_k=0)
    with pytest.raises(ValueError, match="spec_draft needs spec_k"):
        ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, spec_draft=(cfg, params))
    moe = dataclasses.replace(REDUCED["qwen2-moe-a2.7b"], dtype="float32")
    with pytest.raises(ValueError, match="MoE"):
        ContinuousBatchingScheduler(moe, None, max_slots=2, page_size=8,
                                    max_seq_len=64, spec_k=4)
    other = dataclasses.replace(REDUCED["gemma2-2b"], vocab_size=256)
    with pytest.raises(ValueError, match="share the tokenizer"):
        ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, spec_k=4,
                                    spec_draft=(other, None))
    # the incremental draft cache rolls back by length masking, which SSM
    # recurrent state (and MoE capacity grouping) cannot honour
    ssm = dataclasses.replace(REDUCED["mamba2-1.3b"],
                              vocab_size=cfg.vocab_size)
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, spec_k=4,
                                    spec_draft=(ssm, None))


# ----------------------------------------------------------- byte identity --

def test_spec_token_identity_dense():
    """Acceptance core: spec-on emits spec-off's exact tokens (dense arch),
    for a trivial and a deep draft budget, with clean ledgers and
    consistent speculation stats."""
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=0)
    _, base = _serve(cfg, params, trace)
    for k in (1, 4):
        s, toks = _serve(cfg, params, trace, spec_k=k)
        assert toks == base, f"spec_k={k} changed tokens"
        assert s.alloc.num_allocated == 0 and s.reserved_pages == 0
        assert s.stats["spec_ticks"] > 0
        assert s.stats["spec_accepted"] <= s.stats["spec_drafted"]
        assert 0.0 <= s.stats["spec_accept_rate"] <= 1.0
        # every decode-side token was emitted by a verify tick: the
        # accepted+1 histogram's mass is total output minus the per-stream
        # prefill token
        assert s.h_spec_accept.sum == sum(g for _, g in trace) - len(trace)
        assert s.h_spec_accept.count >= s.stats["spec_ticks"]


def test_spec_token_identity_ssm():
    """SSM archs verify through the sequential scan with in-dispatch state
    rollback (PC.select_ssm_steps) — a partial reject must leave the
    recurrence exactly where spec-off decoding would have."""
    cfg, params = _params("mamba2-1.3b")
    trace = _trace(cfg, seed=1, n=3)
    _, base = _serve(cfg, params, trace)
    s, toks = _serve(cfg, params, trace, spec_k=3)
    assert toks == base
    assert s.alloc.num_allocated == 0 and s.reserved_pages == 0
    assert s.stats["spec_ticks"] > 0


def test_spec_token_identity_prefill_kernel():
    """The grouped verify dispatch rides the Pallas write+attend pair when
    prefill_kernel is baked in — same bytes as the XLA path."""
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=2, n=3)
    _, base = _serve(cfg, params, trace)
    _, toks = _serve(cfg, params, trace, spec_k=3, prefill_kernel=True)
    assert toks == base


def test_spec_token_identity_tp2():
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=3, n=3)
    _, base = _serve(cfg, params, trace)
    _, toks = _serve(cfg, params, trace, spec_k=3, tp=2)
    assert toks == base


def test_spec_composes_with_chunked_prefill_and_prefix_cache():
    cfg, params = _params("qwen3-32b")
    rng = np.random.RandomState(4)
    persona = rng.randint(0, cfg.vocab_size, size=18).astype(np.int32)
    trace = [(np.concatenate([persona,
                              rng.randint(0, cfg.vocab_size, size=3 + u)
                              ]).astype(np.int32), 5) for u in range(3)]
    # followers arrive after the leader's last chunk lands (a chunked
    # admission indexes its pages only once the whole prompt is in)
    arrivals = [0, 8, 10]
    base_s, base = _serve(cfg, params, trace, arrivals, prefill_budget=4,
                          prefix_cache=True)
    s, toks = _serve(cfg, params, trace, arrivals, prefill_budget=4,
                     prefix_cache=True, spec_k=4)
    assert toks == base
    assert s.stats["prefix_hits"] == base_s.stats["prefix_hits"] >= 1
    assert s.alloc.num_allocated == 0 and s.reserved_pages == 0


def test_spec_draft_model_identity():
    """Draft-model speculation (here self-drafting: the target arch
    drafting for itself through the incremental paged draft cache, the
    strongest possible draft) emits identical bytes — acceptance verifies
    every draft token against the target regardless of where the draft
    came from."""
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=5, n=3)
    _, base = _serve(cfg, params, trace)
    s, toks = _serve(cfg, params, trace, spec_k=3,
                     spec_draft=(cfg, params))
    assert toks == base
    assert s.stats["spec_drafted"] > 0


def test_spec_draft_cache_tracks_context():
    """The incremental draft cache stays coherent with the committed
    stream across accept/reject rollbacks: a self-draft whose cache
    tracked the context accepts nearly everything (it predicts exactly
    what the target then emits, modulo dispatch-shape float noise), while
    a desynced cache would draft from garbage K/V and accept ~nothing."""
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=7, n=4, g_lo=10, g_hi=14)
    s, toks = _serve(cfg, params, trace, spec_k=3,
                     spec_draft=(cfg, params))
    _, base = _serve(cfg, params, trace)
    assert toks == base
    assert s.stats["spec_accept_rate"] >= 0.75


def test_spec_fleet_identity():
    cfg, params = _params("qwen3-32b")
    trace = _trace(cfg, seed=6, n=5)
    _, base = _serve(cfg, params, trace)
    r = ServingRouter(cfg, params, replicas=2, max_slots=3, page_size=8,
                      max_seq_len=64, prefix_cache=False, spec_k=4)
    reqs = [r.submit(p, g, arrival_step=i // 2)
            for i, (p, g) in enumerate(trace)]
    r.run()
    assert [list(q.out_tokens) for q in reqs] == base
    fleet = r.fleet_stats()
    assert fleet["spec_ticks"] > 0
    assert fleet["spec_accept_rate"] == pytest.approx(
        fleet["spec_accepted"] / max(fleet["spec_drafted"], 1), abs=1e-4)


# ------------------------------------------------------------ cap semantics --

def test_spec_cap_never_overruns_budget_or_reservation():
    """A verify tick emits accepted+1 tokens; the draft cap (remaining-1)
    must make that overshoot-proof: exact token budgets, and page growth
    that never exceeds the admission's worst-case reservation."""
    cfg, params = _params("qwen3-32b")
    # repetitive prompts make n-gram drafting fire hard at a deep budget
    prompt = np.asarray([3, 7, 3, 7, 3, 7, 3, 7, 3, 7], np.int32)
    trace = [(prompt, 1), (prompt, 2), (prompt, 9)]
    _, base = _serve(cfg, params, trace)
    s, toks = _serve(cfg, params, trace, spec_k=8)
    assert toks == base
    for (_, g), t in zip(trace, toks):
        assert len(t) == g, "verify tick overran the token budget"
    assert s.alloc.num_allocated == 0 and s.reserved_pages == 0
    # peak page use stayed within the sum of worst-case reservations
    worst = sum(-(-(len(p) + g) // s.page_size) for p, g in trace)
    assert s.stats["peak_pages"] <= worst


def test_speculating_state_is_observability_only():
    cfg, params = _params("qwen3-32b")
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, spec_k=4)
    prompt = np.asarray([3, 7, 3, 7, 3, 7], np.int32)
    req = s.submit(prompt, 6)
    seen = set()
    while not req.done:
        s.step()
        seen.add(req.state)
    assert req.state is RequestState.FINISHED
    assert RequestState.SPECULATING in seen   # drafts were in flight
    assert not req.speculating                # cleared at finish
    assert req.spec_accepted <= req.spec_drafted


# ----------------------------------------------- bugfix #1: fast-forward --

def test_idle_fast_forward_skips_gap_capped_at_max_fuse():
    cfg, params = _params("qwen3-32b")
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64)
    s.submit(np.arange(4, dtype=np.int32), 2, arrival_step=50)
    s.step(max_fuse=16)
    assert s.step_idx == 16                  # toward the arrival, capped
    s.step(max_fuse=64)
    assert s.step_idx == 50                  # lands exactly on it


def test_fast_forward_never_fires_past_prefilling_backlog():
    """Bugfix regression: a chunked-prefill backlog has no decoding slots,
    but the scheduler is NOT idle — the clock must advance one tick per
    step (queue-wait/TTFT accounting depends on it), never jump toward a
    future arrival."""
    cfg, params = _params("qwen3-32b")
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, prefill_budget=3)
    s.submit(np.arange(12, dtype=np.int32), 2, arrival_step=0)
    s.submit(np.arange(4, dtype=np.int32), 2, arrival_step=100)
    s.step(max_fuse=16)                      # admits; first chunk lands
    t = s.step_idx
    assert t == 1
    while any(r is not None and r.prefill_pos is not None
              for r in s.slot_req):
        s.step(max_fuse=16)
        assert s.step_idx == t + 1, \
            "fast-forward fired with a PREFILLING backlog"
        t = s.step_idx


def test_fast_forward_never_fires_past_parked_handoff_slot():
    """Same rule for a prefill-role replica's parked slots: a stream
    awaiting page handoff keeps the scheduler busy."""
    cfg, params = _params("qwen3-32b")
    s = ContinuousBatchingScheduler(cfg, params, max_slots=2, page_size=8,
                                    max_seq_len=64, role="prefill")
    s.submit(np.arange(6, dtype=np.int32), 4, arrival_step=0)
    for _ in range(8):
        if s.handoff_ready():
            break
        s.step(max_fuse=16)
    assert s.handoff_ready(), "prefill-role slot should park after prompt"
    s.submit(np.arange(4, dtype=np.int32), 2, arrival_step=100)
    t = s.step_idx
    s.step(max_fuse=16)
    assert s.step_idx == t + 1, "fast-forward fired over a parked slot"


# -------------------------------------------- bugfix #3: fail mid-handoff --

def _disagg_pair(cfg, params):
    pre = ServingReplica.build(cfg, params, 0, max_slots=2, page_size=8,
                               max_seq_len=64, role="prefill",
                               prefix_cache=False)
    dec = ServingReplica.build(cfg, params, 1, max_slots=2, page_size=8,
                               max_seq_len=64, role="decode",
                               prefix_cache=False)
    return pre, dec


def test_fail_after_adopt_does_not_requeue_or_double_free():
    """The donor dies between the page copy and the surrender. Ownership
    transferred at the copy point, so the dead donor must free its orphaned
    source pages but NOT hand the stream back for re-prefill (it would
    decode twice), and the guarded surrender must not double-free."""
    cfg, params = _params("qwen3-32b")
    trace = [(np.arange(6, dtype=np.int32), 4)]
    _, base = _serve(cfg, params, trace)
    pre, dec = _disagg_pair(cfg, params)
    req = make_request(0, trace[0][0], trace[0][1])
    pre.accept(req)
    while not pre.handoff_ready():
        pre.step()
    donor_slot = pre.handoff_ready()[0]
    # scheduler-level adopt = the page copy; ownership moves here (the
    # fix: adopt stamps req.replica, not the later surrender)
    dec.sched.adopt(req, pre.sched, donor_slot)
    assert req.replica == dec.replica_id
    lost = pre.fail()                        # donor dies mid-handoff
    assert req not in lost, "adopted-away stream requeued (would decode 2x)"
    assert pre.sched.alloc.num_allocated == 0, "donor leaked source pages"
    assert pre.sched.stats["migrations_out"] == 1
    # the replica-level surrender guard sees the cleared slot and skips —
    # a second free of already-freed pages would raise in the allocator
    assert pre.sched.slot_req[donor_slot] is not req
    while not req.done:
        dec.step()
    assert list(req.out_tokens) == base[0], "handoff changed tokens"
    assert dec.sched.alloc.num_allocated == 0
    assert dec.sched.reserved_pages == 0


def test_clean_handoff_surrender_still_fires():
    """Control for the guard: in the normal order (donor alive) the
    replica-level adopt must still surrender the donor slot."""
    cfg, params = _params("qwen3-32b")
    pre, dec = _disagg_pair(cfg, params)
    req = make_request(0, np.arange(6, dtype=np.int32), 3)
    pre.accept(req)
    while not pre.handoff_ready():
        pre.step()
    donor_slot = pre.handoff_ready()[0]
    dec.adopt(req, pre, donor_slot)
    assert pre.sched.slot_req[donor_slot] is None
    assert pre.sched.alloc.num_allocated == 0
    assert pre.sched.stats["migrations_out"] == 1
    while not req.done:
        dec.step()
    assert dec.sched.alloc.num_allocated == 0


# ----------------------------------------- accept/rollback ledger machine --

# guarded import (not module-level importorskip: the identity tests above
# must run with or without hypothesis)
try:
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
except ImportError:                           # pragma: no cover
    st = None

_V = 17                                       # toy vocab


def _oracle(ctx):
    """Deterministic greedy target model: next token from the context."""
    return (sum(int(t) * (i + 1) for i, t in enumerate(ctx)) * 31
            + len(ctx)) % _V


if st is not None:
    class SpecLedgerMachine(RuleBasedStateMachine):
        """Host-side model of one slot's draft-and-verify ledger.

        Drives ``spec_accept`` with arbitrary draft sequences against a
        deterministic oracle target and checks, after every verify tick, the
        three properties ``_spec_step`` relies on:

        * byte identity — emitted tokens are exactly the oracle's greedy
          continuation, whatever the drafts were;
        * budget safety — capping drafts at ``remaining - 1`` means emitting
          ``accepted + 1`` tokens can never overrun ``max_new_tokens``;
        * reservation safety — pages grown for positions ``L..L+cap`` never
          exceed the admission's worst-case reservation.
        """

        PS = 4                                    # page size

        @initialize(prompt=st.lists(st.integers(0, _V - 1), min_size=1,
                                    max_size=8),
                    max_new=st.integers(1, 12))
        def begin(self, prompt, max_new):
            self.prompt = list(prompt)
            self.max_new = max_new
            # prefill emits the first token (the scheduler's admission does)
            self.out = [_oracle(self.prompt)]
            self.seq_len = len(prompt) + 1
            self.pages = -(-self.seq_len // self.PS)
            self.reservation = -(-(len(prompt) + max_new) // self.PS)

        @rule(data=st.data(), k=st.integers(1, 8))
        def verify_tick(self, data, k):
            if len(self.out) >= self.max_new:
                return
            cap = min(k, self.max_new - len(self.out) - 1)
            drafts = data.draw(st.lists(st.integers(0, _V - 1), max_size=cap)
                               if cap > 0 else st.just([]), label="drafts")
            # page growth for positions seq_len .. seq_len+cap (the verify
            # rows' write positions), exactly _spec_step's formula
            needed = (self.seq_len + len(drafts)) // self.PS + 1
            self.pages = max(self.pages, needed)
            ctx = self.prompt + self.out
            targets = [_oracle(ctx + drafts[:i])
                       for i in range(len(drafts) + 1)]
            j = spec_accept(drafts, targets)
            emitted = targets[:j + 1]
            self.out.extend(emitted)
            self.seq_len += j + 1

        @invariant()
        def emits_greedy_bytes(self):
            ctx = list(self.prompt)
            for i, tok in enumerate(self.out):
                assert tok == _oracle(ctx), \
                    f"output diverged from greedy at position {i}"
                ctx.append(tok)

        @invariant()
        def never_overruns(self):
            assert len(self.out) <= self.max_new, "token budget overrun"
            assert self.seq_len == len(self.prompt) + len(self.out)
            assert self.pages <= self.reservation, \
                "verify page growth exceeded the admission reservation"


    TestSpecLedgerProps = SpecLedgerMachine.TestCase
    TestSpecLedgerProps.settings = settings(max_examples=60,
                                            stateful_step_count=30,
                                            deadline=None)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_spec_ledger_props():
        """Stateful accept/rollback ledger properties need hypothesis."""
