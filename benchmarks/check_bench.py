"""Assert benchmark hard gates in CI.

Usage:  python benchmarks/check_bench.py REPORT.json [REPORT.json ...]

Every benchmark ``--out`` report shares one schema (written by
``serve_bench.write_report`` and friends): top-level ``bench`` names the
mode and ``gates`` maps hard-gate names to booleans. This gate loads each
report, asserts every gate is true, and exits non-zero naming the
failures — the single CI post-step that replaced the per-bench heredocs.

A report with an empty ``gates`` dict passes (that bench has no hard
gates — its numbers are advisory); a report *missing* the schema keys
fails, so a bench that silently stops writing gates cannot green CI.
"""
from __future__ import annotations

import json
import sys


def check(path: str) -> list:
    """Failure messages for one report file (empty list == pass)."""
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable report ({e})"]
    if not isinstance(data, dict) or "bench" not in data \
            or not isinstance(data.get("gates"), dict):
        return [f"{path}: not a shared-schema bench report "
                "(missing 'bench'/'gates' keys)"]
    return [f"{path} [{data['bench']}]: gate '{name}' failed"
            for name, ok in data["gates"].items() if ok is not True]


def main(argv: list) -> int:
    if not argv:
        print("usage: check_bench.py REPORT.json [REPORT.json ...]",
              file=sys.stderr)
        return 2
    failures = []
    for path in argv:
        bad = check(path)
        failures += bad
        if not bad:
            with open(path) as fh:
                n = len(json.load(fh)["gates"])
            print(f"OK: {path} ({n} gate{'s' if n != 1 else ''})")
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
