"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Batched prefill + greedy decode on the arch's reduced config (CPU); the
full-config serve paths (decode_32k / long_500k) are lowered and analysed
by the dry-run.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_reduced
from repro.models import model as M
from repro.serving import engine as E


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.rope_variant == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_positions, cfg.d_model), jnp.float32)

    t0 = time.time()
    lg, cache, cur = E.prefill(cfg, params, batch,
                               capacity=S + args.gen + 8)
    lg.block_until_ready()
    t_pre = time.time() - t0
    first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
        jnp.int32)[:, None]
    t0 = time.time()
    toks, cache, cur = E.greedy_decode(cfg, params, cache, first, cur,
                                       args.gen)
    toks.block_until_ready()
    t_dec = time.time() - t0
    print(json.dumps({
        "arch": cfg.name,
        "prefill_tok_per_s": round(B * S / t_pre, 1),
        "decode_tok_per_s": round(B * args.gen / t_dec, 1),
        "generated": [[int(t) for t in row[:8]] for row in toks],
    }))


if __name__ == "__main__":
    main()
