"""Parallel context: activation-sharding constraints usable from model code.

Model code names *logical* activation axes; the active ``ParallelCtx`` (set
by the train/serve step builders) maps them to mesh axes. With no context
(single-device smoke tests) constraints are no-ops, so model code never
needs to know whether it is distributed.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.schema import resolve_pspec

# default logical activation-axis rules (planner may override per blueprint)
ACT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "act_seq": ("data",),        # sequence sharding (long-context decode)
    "heads_act": ("model",),
    "ff_act": ("model",),
    "experts_act": ("model",),
    "vocab_act": ("model",),
    "cache_seq": ("model",),     # decode-cache sequence dim
    "kv_heads": ("model",),
}

_STATE = threading.local()


@dataclass
class ParallelCtx:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=lambda: dict(ACT_RULES))


def current() -> Optional[ParallelCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_parallel(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = current()
    _STATE.ctx = ParallelCtx(mesh, {**ACT_RULES, **(rules or {})})
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply with_sharding_constraint mapping logical axes via the context."""
    ctx = current()
    if ctx is None:
        return x
    pspec = resolve_pspec(tuple(axes), tuple(x.shape), ctx.rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, pspec))
