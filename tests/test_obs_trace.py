"""Lifecycle tracer: span/instant collection semantics, the Chrome
trace-event export shape, the fail-loud JSONL round trip, the EventLog
bridge, and the launcher's non-autoscale ``--events-out`` path (the trace
is the event stream when no autoscale control loop owns one)."""
import json
import sys

import pytest

from repro.core.events import EventLog
from repro.obs.trace import TICK_US, Tracer


def _sample_tracer():
    tr = Tracer()
    tr.set_process_name(0, "replica-0 (mixed)")
    tr.set_tick(0)
    tr.begin("queued", 7, replica=0)
    tr.set_tick(2)
    tr.end("queued", 7)
    tr.span("prefill", 7, 2, 3, replica=0, tokens=12, pages=2)
    tr.begin("decode", 7, replica=0)
    tr.instant("routed", rid=7, t=0, replica=None, spillover=False)
    tr.set_tick(9)
    tr.end("decode", 7, tokens=6)
    tr.instant("autoscale", t=4, direction="scale_out", resource="slots")
    return tr


# ----------------------------------------------------------- collection --

def test_begin_end_pairing_and_no_op_rules():
    tr = Tracer()
    tr.begin("queued", 1, t=0, replica=0, first=True)
    tr.begin("queued", 1, t=5, replica=2)      # already open: first wins
    tr.end("queued", 1, t=3)
    tr.end("queued", 1, t=9)                   # unmatched: no-op
    tr.end("decode", 42)                       # never opened: no-op
    assert len(tr.spans) == 1
    s = tr.spans[0]
    assert (s.t0, s.t1, s.replica) == (0.0, 3.0, 0)
    assert s.attrs == {"first": True}


def test_next_index_numbers_per_request_and_name():
    tr = Tracer()
    assert [tr.next_index(1, "prefill_chunk") for _ in range(3)] == [0, 1, 2]
    assert tr.next_index(2, "prefill_chunk") == 0
    assert tr.next_index(1, "other") == 0


def test_finish_open_flushes_with_marker():
    tr = Tracer()
    tr.begin("decode", 3, t=5, replica=1)
    tr.set_tick(8)
    assert tr.finish_open() == 1
    assert tr.finish_open() == 0               # idempotent
    s = tr.spans[-1]
    assert s.t1 == 8.0 and s.attrs["open"] is True


# -------------------------------------------------------------- chrome --

def test_chrome_export_shape():
    tr = _sample_tracer()
    doc = tr.to_chrome()
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    # pid 0 is the fleet lane; replica 0's lane is pid 1
    metas = {e["pid"]: e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert metas[0] == "fleet" and metas[1] == "replica-0 (mixed)"
    pre = next(e for e in evs if e["ph"] == "X" and e["name"] == "prefill")
    assert pre["pid"] == 1 and pre["tid"] == 7
    assert pre["ts"] == 2 * TICK_US and pre["dur"] == 1 * TICK_US
    assert pre["args"]["tokens"] == 12 and pre["args"]["replica"] == 0
    routed = next(e for e in evs if e["ph"] == "i" and e["name"] == "routed")
    assert routed["pid"] == 0 and routed["s"] == "t"   # rid-scoped instant
    auto = next(e for e in evs if e["name"] == "autoscale")
    assert auto["s"] == "g"                            # global instant
    json.dumps(doc)                                    # serializable


def test_write_chrome_counts_events(tmp_path):
    tr = _sample_tracer()
    path = tmp_path / "trace.json"
    n = tr.write_chrome(str(path))
    doc = json.loads(path.read_text())
    assert n == len(doc["traceEvents"])
    assert n == 2 + 3 + 2                     # metas + spans + instants


# ---------------------------------------------------------------- jsonl --

def test_jsonl_round_trip(tmp_path):
    tr = _sample_tracer()
    path = tmp_path / "trace.jsonl"
    n = tr.write_jsonl(str(path))
    assert n == len(tr.spans) + len(tr.instants)
    back = Tracer.from_jsonl(str(path))
    assert back.process_names == tr.process_names
    assert [s.to_dict() for s in back.spans] == \
        [s.to_dict() for s in tr.spans]
    assert [i.to_dict() for i in back.instants] == \
        [i.to_dict() for i in tr.instants]


@pytest.mark.parametrize("line,match", [
    ("{not json", "line 2 is not valid JSON"),
    ("[1, 2]", "line 2 holds a JSON list"),
    ('{"kind": "mystery", "name": "x"}', "unknown trace record kind"),
    ('{"kind": "span", "name": "x", "rid": 1}', "missing field"),
    ('{"kind": "instant", "name": "x", "t": 1, "attrs": 3}',
     "non-object 'attrs'"),
])
def test_from_jsonl_fails_loud_with_line_numbers(tmp_path, line, match):
    path = tmp_path / "bad.jsonl"
    good = '{"kind": "instant", "name": "ok", "t": 0}'
    path.write_text(good + "\n" + line + "\n")
    with pytest.raises(ValueError, match=match):
        Tracer.from_jsonl(str(path))


# ------------------------------------------------------------- EventLog --

def test_to_event_log_orders_and_names_actors(tmp_path):
    tr = _sample_tracer()
    log = tr.to_event_log()
    assert isinstance(log, EventLog)
    ts = [e.t for e in log.events]
    assert ts == sorted(ts)
    # ties at t=0 keep insertion order (spans before instants), so the
    # queued span leads the routed instant on the shared timeline
    log.assert_order("queued", "routed", "prefill", "decode")
    pre = next(e for e in log.events if e.action == "prefill")
    assert pre.actor == "replica-0" and pre.detail["dur"] == 1.0
    routed = next(e for e in log.events if e.action == "routed")
    assert routed.actor == "fleet" and routed.detail["rid"] == 7
    # and the EventLog round trip still holds for the bridged log
    path = tmp_path / "events.jsonl"
    log.write_jsonl(str(path))
    assert len(EventLog.from_jsonl(str(path)).events) == len(log.events)


# ------------------------------------------------- launcher integration --

def test_serve_events_out_without_autoscale(tmp_path, monkeypatch, capsys):
    """Regression (S2): ``--events-out`` used to be an argparse error
    without ``--autoscale``; now the lifecycle trace is the event stream."""
    from repro.launch import serve
    out = tmp_path / "events.jsonl"
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "qwen3-32b", "--engine", "paged",
        "--requests", "3", "--prompt-len", "8", "--gen", "4",
        "--batch", "2", "--events-out", str(out)])
    serve.main()
    report = json.loads(capsys.readouterr().out)
    assert report["events_written"] > 0
    log = EventLog.from_jsonl(str(out))
    assert len(log.events) == report["events_written"]
    log.assert_order("queued", "prefill", "decode", "finish")
