"""whisper-tiny [audio] — enc-dec transformer backbone; conv frontend is a
STUB (input_specs supplies precomputed frame embeddings).

4+4L d_model=384 6H d_ff=1536 vocab=51865 [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    n_enc_layers=4,
    enc_positions=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp_act="gelu",
    mlp_gated=False,
    rope_variant="none",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="whisper-tiny-reduced",
    family="audio",
    n_layers=2,
    n_enc_layers=2,
    enc_positions=32,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mlp_act="gelu",
    mlp_gated=False,
    rope_variant="none",
)
