"""Pallas TPU chunked flash-prefill kernels (direct-to-page KV writes).

Chunked prefill lands one prompt chunk per sequence per tick: the chunk's
K/V must end up in the paged pool (``repro.serving.paged_cache``) and the
chunk's queries must attend everything written so far — the already-paged
prefix *and* the chunk itself — under the causal (and optionally sliding-
window) mask. The XLA path does this as quantise → scatter → full-pool
gather → dense masked softmax; these kernels compute the same function
without ever materialising the gathered cache or a contiguous K/V
intermediate:

``paged_prefill_write``
    Scatters the chunk's K/V **directly into the pool pages** through the
    scalar-prefetched block table, aliasing the pools in-place
    (``input_output_aliases``). Grid (B, pages_per_seq): each step owns
    one page — every page block is visited exactly once, so the aliased
    read-modify-write never races itself. Rows of the page whose absolute
    position falls inside ``[start, start+chunk_len)`` take the chunk
    values (routed via a one-hot position matmul — a gather phrased for
    the MXU); all other rows keep the page's previous contents. Pool
    quantisation (int8 / fp8) happens in-kernel, emitting the per-
    (position, kv-head) scale planes bit-identically to
    ``repro.models.attention.quantize_kv``.

    A tighter grid over only the chunk's own pages (start//ps ..
    (start+len)//ps) would skip the untouched page slots, but with a
    clamped index map two grid steps can resolve to the same page and the
    later step's input fetch is not ordered against the earlier step's
    aliased write. Correct-by-construction wins here; the full-table sweep
    is the documented cost (pages_per_seq is small at serving block sizes)
    and the range-restricted grid is TPU future work.

``paged_prefill_attend``
    Flash attention (online-softmax, same scratch discipline as
    ``flash_attention``) where **all** K/V — prefix and chunk — stream
    from the pool pages via the block table, after the write kernel has
    landed the chunk. Grid (B, KVH, q_blocks, pages_per_seq) with the page
    axis innermost and sequential; running max / sum / accumulator live in
    VMEM scratch. Masking is absolute-position causal
    (``k_pos <= start + q_row`` and ``k_pos < start + chunk_len``) plus
    the optional sliding window; quantised pools dequantise in-kernel from
    the scale planes. Pages wholly outside a q block's visible range are
    skipped (causal skip, window skip, past-the-end skip).

Run order matters: attend reads the chunk's K/V *from the pages*, so the
write kernel must run first. That ordering is also what makes the
quantised paths bit-identical to the XLA write-then-gather reference —
chunk tokens go through the same quantise→dequantise roundtrip on both.

Target is TPU; correctness on this CPU-only container is established in
interpret mode against ``repro.kernels.ref.paged_prefill_attention_ref``
(see tests/test_paged_prefill.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30
_FP8_MAX = 448.0  # float8_e4m3 largest finite value


# ---------------------------------------------------------------------------
# write kernel: chunk K/V -> pool pages (aliased, in-kernel quantisation)
# ---------------------------------------------------------------------------

def _pw_kernel(bt_ref, start_ref, lens_ref, k_new_ref, v_new_ref,
               k_in_ref, v_in_ref, *refs, page_size: int, chunk: int,
               quant: Optional[str]):
    if quant:
        ks_in_ref, vs_in_ref, k_out_ref, v_out_ref, ks_out_ref, \
            vs_out_ref = refs
    else:
        ks_in_ref = vs_in_ref = ks_out_ref = vs_out_ref = None
        k_out_ref, v_out_ref = refs
    b = pl.program_id(0)
    i = pl.program_id(1)
    st = start_ref[b]
    ln = lens_ref[b]

    # absolute position of each page row -> chunk-relative index + liveness
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (page_size, 1), 0) \
        + i * page_size
    j = k_pos - st                                        # (ps, 1)
    sel = jnp.logical_and(j >= 0, j < ln)                 # (ps, 1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    onehot = jnp.where(jnp.logical_and(j == idx, sel), 1.0, 0.0)  # (ps, chunk)

    def scatter_one(new_ref, in_ref, out_ref, sc_in_ref, sc_out_ref):
        KVH, d = new_ref.shape[2], new_ref.shape[3]
        flat = new_ref[0].astype(jnp.float32).reshape(chunk, KVH * d)
        g = jax.lax.dot_general(onehot, flat, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        g = g.reshape(page_size, KVH, d)                  # chunk rows routed
        old = in_ref[0]
        # reciprocal multiply matches attention.quantize_kv bit-for-bit
        # (jit strength-reduces x/const to it; the kernel writes it out)
        if quant == "fp8":
            a = jnp.max(jnp.abs(g), axis=-1)              # (ps, KVH)
            scale = jnp.maximum(a * jnp.float32(1.0 / _FP8_MAX), 1e-12)
            qv = (g / scale[..., None]).astype(jnp.float8_e4m3fn)
        elif quant:
            a = jnp.max(jnp.abs(g), axis=-1)
            scale = jnp.maximum(a * jnp.float32(1.0 / 127.0), 1e-12)
            qv = jnp.clip(jnp.round(g / scale[..., None]),
                          -127, 127).astype(jnp.int8)
        else:
            scale = None
            qv = g.astype(old.dtype)
        live = sel[:, :1][..., None]                      # (ps, 1, 1)
        out_ref[0] = jnp.where(live, qv, old)
        if quant:
            sc_out_ref[0] = jnp.where(sel[:, :1], scale, sc_in_ref[0])

    scatter_one(k_new_ref, k_in_ref, k_out_ref, ks_in_ref, ks_out_ref)
    scatter_one(v_new_ref, v_in_ref, v_out_ref, vs_in_ref, vs_out_ref)


def paged_prefill_write(k_new: jnp.ndarray, v_new: jnp.ndarray,
                        k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                        block_table: jnp.ndarray, start: jnp.ndarray,
                        chunk_lens: jnp.ndarray, *,
                        k_scale_pages: Optional[jnp.ndarray] = None,
                        v_scale_pages: Optional[jnp.ndarray] = None,
                        quant: Optional[str] = None,
                        interpret: bool = False):
    """Scatter a ragged batch of prompt chunks into their pool pages.

    k_new/v_new: (B, S, KVH, d) — rows past ``chunk_lens[b]`` are padding
    and are not written. Pools: (P, page_size, KVH, d); block_table:
    (B, n_pg); start/chunk_lens: (B,) int32 — chunk token ``t`` lands at
    absolute position ``start[b] + t``. ``quant`` in (None, "int8",
    "fp8") must match the pool dtype; quantised calls also take/return the
    fp32 scale planes (P, page_size, KVH).

    Returns the updated pools dict (k_pages, v_pages[, k_scale_pages,
    v_scale_pages]). Inputs are donated via ``input_output_aliases``.
    """
    B, S, KVH, d = k_new.shape
    P, page_size = k_pages.shape[0], k_pages.shape[1]
    n_pg = block_table.shape[1]
    if quant not in (None, "int8", "fp8"):
        raise ValueError(f"quant must be None, 'int8' or 'fp8': {quant!r}")
    if (quant is not None) != (k_scale_pages is not None):
        raise ValueError("scale planes required iff quant is set")

    kernel = functools.partial(_pw_kernel, page_size=page_size, chunk=S,
                               quant=quant)
    chunk_spec = pl.BlockSpec((1, S, KVH, d),
                              lambda b, i, bt, st, ln: (b, 0, 0, 0))
    page_spec = pl.BlockSpec((1, page_size, KVH, d),
                             lambda b, i, bt, st, ln: (bt[b, i], 0, 0, 0))
    scale_spec = pl.BlockSpec((1, page_size, KVH),
                              lambda b, i, bt, st, ln: (bt[b, i], 0, 0))

    in_specs = [chunk_spec, chunk_spec, page_spec, page_spec]
    args = [k_new, v_new, k_pages, v_pages]
    out_specs = [page_spec, page_spec]
    out_shape = [jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
                 jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype)]
    # alias indices count the scalar-prefetch operands (bt, start, lens)
    aliases = {5: 0, 6: 1}
    if quant:
        in_specs += [scale_spec, scale_spec]
        args += [k_scale_pages, v_scale_pages]
        out_specs += [scale_spec, scale_spec]
        out_shape += [jax.ShapeDtypeStruct(k_scale_pages.shape, jnp.float32),
                      jax.ShapeDtypeStruct(v_scale_pages.shape, jnp.float32)]
        aliases.update({7: 2, 8: 3})

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, n_pg),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(block_table.astype(jnp.int32), start.astype(jnp.int32),
      chunk_lens.astype(jnp.int32), *args)
    pool = {"k_pages": outs[0], "v_pages": outs[1]}
    if quant:
        pool["k_scale_pages"] = outs[2]
        pool["v_scale_pages"] = outs[3]
    return pool


# ---------------------------------------------------------------------------
# attend kernel: chunk queries vs paged prefix+chunk K/V (online softmax)
# ---------------------------------------------------------------------------

def _pa_kernel(bt_ref, start_ref, lens_ref, q_ref, k_ref, v_ref, *refs,
               scale: float, softcap: Optional[float],
               window: Optional[int], page_size: int, block_q: int,
               quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        ks_ref = vs_ref = None
        o_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    qi = pl.program_id(2)
    pi = pl.program_id(3)
    n_pg = pl.num_programs(3)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    st = start_ref[b]
    ln = lens_ref[b]
    total = st + ln                           # tokens 0..total-1 are live
    G, d = q_ref.shape[3], q_ref.shape[4]

    # absolute query position per flattened (q_row, group) scratch row
    row = jax.lax.broadcasted_iota(
        jnp.int32, (block_q, G), 0).reshape(block_q * G, 1)
    q_abs = st + qi * block_q + row                       # (bq*G, 1)
    k_pos = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)

    # skip pages no row of this q block can see: past the live length,
    # above the causal diagonal, or wholly below the sliding window
    needed = pi * page_size < total
    needed = jnp.logical_and(
        needed, pi * page_size <= st + qi * block_q + block_q - 1)
    if window is not None:
        needed = jnp.logical_and(
            needed,
            (st + qi * block_q) - (pi * page_size + page_size - 1) < window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(block_q * G, d)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (ps, d)
        if quant:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        ok = jnp.logical_and(k_pos <= q_abs, k_pos < total)
        if window is not None:
            ok = jnp.logical_and(ok, q_abs - k_pos < window)
        s = jnp.where(ok, s, _NEG)                        # (bq*G, ps)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * alpha + p.sum(axis=-1)
        m_scr[...] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32)            # (ps, d)
        if quant:
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(pi == n_pg - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        out = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        o_ref[0, :, 0] = out.reshape(block_q, G, d)


def paged_prefill_attend(q: jnp.ndarray, k_pages: jnp.ndarray,
                         v_pages: jnp.ndarray, block_table: jnp.ndarray,
                         start: jnp.ndarray, chunk_lens: jnp.ndarray, *,
                         k_scale_pages: Optional[jnp.ndarray] = None,
                         v_scale_pages: Optional[jnp.ndarray] = None,
                         softcap: Optional[float] = None,
                         window: Optional[int] = None,
                         scale: Optional[float] = None,
                         block_q: Optional[int] = None,
                         interpret: bool = False) -> jnp.ndarray:
    """Chunk queries attend the paged prefix+chunk K/V. Call *after*
    ``paged_prefill_write`` — the chunk's own K/V stream from the pages.

    q: (B, S, H, d) — query ``t`` of sequence ``b`` sits at absolute
    position ``start[b] + t``; rows past ``chunk_lens[b]`` are padding
    (their output is unspecified — callers slice live rows). Pools:
    (P, page_size, KVH, d); block_table: (B, n_pg). Returns (B, S, H, d).
    """
    B, S, H, d = q.shape
    page_size, KVH = k_pages.shape[1], k_pages.shape[2]
    n_pg = block_table.shape[1]
    G = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q or 128, S)
    quant = k_scale_pages is not None

    pq = (-S) % block_q
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    qr = qp.reshape(B, S + pq, KVH, G, d)
    n_qb = (S + pq) // block_q

    kernel = functools.partial(_pa_kernel, scale=scale, softcap=softcap,
                               window=window, page_size=page_size,
                               block_q=block_q, quant=quant)
    q_spec = pl.BlockSpec((1, block_q, 1, G, d),
                          lambda b, h, qi, i, bt, st, ln: (b, qi, h, 0, 0))
    page_spec = pl.BlockSpec((1, page_size, 1, d),
                             lambda b, h, qi, i, bt, st, ln:
                             (bt[b, i], 0, h, 0))
    in_specs = [q_spec, page_spec, page_spec]
    args = [qr, k_pages, v_pages]
    if quant:
        scale_spec = pl.BlockSpec((1, page_size, 1),
                                  lambda b, h, qi, i, bt, st, ln:
                                  (bt[b, i], 0, h))
        in_specs += [scale_spec, scale_spec]
        args += [k_scale_pages, v_scale_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KVH, n_qb, n_pg),
        in_specs=in_specs,
        out_specs=q_spec,
        scratch_shapes=[
            pltpu.VMEM((block_q * G,), jnp.float32),
            pltpu.VMEM((block_q * G,), jnp.float32),
            pltpu.VMEM((block_q * G, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S + pq, KVH, G, d), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), start.astype(jnp.int32),
      chunk_lens.astype(jnp.int32), *args)
    return out.reshape(B, S + pq, H, d)[:, :S]
