"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Two engines, selectable with ``--engine``:

* ``static`` — the original fixed-batch prefill + greedy decode
  (``repro.serving.engine``): one batch, one ring-buffer cache, every
  stream padded to the same capacity and decoded until the longest one
  finishes.
* ``paged`` — the continuous-batching scheduler over the paged KV cache
  (``repro.serving.scheduler``): requests join on arrival, evict on
  finish, and K/V live in a shared page pool sized by the blueprint
  planner (``repro.core.blueprint.serving_page_plan``). ``--requests``
  builds a mixed-length workload with staggered arrivals to show the
  occupancy win; see ``benchmarks/serve_bench.py`` for the head-to-head.
  With ``--autoscale`` the engine starts at one decode slot and the
  elastic control plane (``repro.autoscale``) grows/shrinks slots and
  page pool with load; ``--events-out run.jsonl`` exports the scale
  decisions for replay (``EventLog.from_jsonl``). Without ``--autoscale``
  the same flag exports the request-lifecycle trace as an event log
  instead (``repro.obs.trace.Tracer.to_event_log``).

Observability (paged/fleet only, see docs/observability.md):
``--trace-out trace.json`` records per-request lifecycle spans (queued /
prefill chunks / parked / migration / decode) as Chrome trace-event JSON
for Perfetto; ``--metrics-out metrics.prom`` dumps the typed metric
registries in Prometheus text exposition; ``--profile`` wall-times every
kernel dispatch and reports modeled roofline fractions. All three are
read-only: emitted tokens are byte-identical with them on or off.

``--replicas k`` (paged only) serves through the replicated fabric
instead: a ``ServingRouter`` front-end spreads the workload over k
scheduler replicas (``--router`` picks the routing policy), and
``--autoscale`` then runs the *fleet* control plane
(``repro.autoscale.FleetController``): start at one replica, add/drain
whole replicas with fleet queue depth.

``--chunked-prefill N`` (paged only) lands each prompt in chunks of at
most N tokens per tick, interleaved with decode ticks, instead of one
monolithic prefill call — tokens stay byte-identical at fp32.
``--disagg k`` (fleet only) splits the fabric into k prefill-role
replicas and ``replicas - k`` decode-role replicas with verbatim KV-page
handoff between them; composes with ``--chunked-prefill`` and
``--autoscale`` (the fleet controller then scales the two roles on
separate signals).

``--tp k`` (paged only) serves every scheduler/replica as a k-way
tensor-parallel *shard group*: page pools and attention heads (and MoE
experts) split k ways while tokens stay byte-identical to ``--tp 1``
(docs/sharding.md). Composes with ``--replicas``: a fleet of shard
groups.

``--seed`` drives both parameter init and workload generation, so
run-to-run variation studies are one flag.

Both paths run the arch's reduced config on CPU; the full-config serve
cells (decode_32k / long_500k) are lowered and analysed by the dry-run.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_reduced
from repro.models import model as M
from repro.obs.metrics import percentile
from repro.obs.trace import Tracer
from repro.serving import engine as E
from repro.serving import paged_cache as PC
from repro.serving.scheduler import ContinuousBatchingScheduler, supports_paged


def _finish_obs(out: dict, args, tracer, profiler, expose_fn,
                ctl=None) -> None:
    """Common export tail for the paged/fleet runners: flush the tracer,
    write the requested artifacts, and fold counts into the result dict."""
    if tracer is not None:
        tracer.finish_open()
    if args.trace_out:
        out["trace_events"] = tracer.write_chrome(args.trace_out)
    if args.events_out:
        # the autoscale control loop owns the event log when present;
        # otherwise the lifecycle trace is the run's event stream
        if ctl is not None:
            out["events_written"] = ctl.log.write_jsonl(args.events_out)
        else:
            out["events_written"] = tracer.to_event_log().write_jsonl(
                args.events_out)
    if args.metrics_out:
        text = expose_fn()
        with open(args.metrics_out, "w") as fh:
            fh.write(text)
        out["metrics_written"] = text.count("# TYPE")
    if profiler is not None:
        out["profile"] = profiler.summary()


def run_static(cfg, params, args) -> dict:
    key = jax.random.PRNGKey(args.seed)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.rope_variant == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_positions, cfg.d_model), jnp.float32)

    t0 = time.time()
    lg, cache, cur = E.prefill(cfg, params, batch,
                               capacity=S + args.gen + 8)
    lg.block_until_ready()
    t_pre = time.time() - t0
    first = jnp.argmax(lg[:, -1, :cfg.vocab_size], -1).astype(
        jnp.int32)[:, None]
    t0 = time.time()
    toks, cache, cur = E.greedy_decode(cfg, params, cache, first, cur,
                                       args.gen)
    toks.block_until_ready()
    t_dec = time.time() - t0
    return {
        "engine": "static",
        "arch": cfg.name,
        "prefill_tok_per_s": round(B * S / t_pre, 1),
        "decode_tok_per_s": round(B * args.gen / t_dec, 1),
        "generated": [[int(t) for t in row[:8]] for row in toks],
    }


def persona_workload(vocab_size, rng, personas, users, persona_len,
                     user_lo, user_hi, gen_lo, gen_hi):
    """Canonical persona trace: ``personas`` shared system prompts of
    ``persona_len`` tokens, each carried by ``users`` requests that differ
    only in a short user suffix — the fleet-chat shape where re-prefilling
    the persona dominates both prefill FLOPs and page-pool footprint.
    Requests are grouped by persona (one persona's users arrive as a
    burst), so a persona's pages stay live across its users' admissions.
    Shared by the launcher's ``--shared-prefix`` mode and
    ``benchmarks/serve_bench.py`` so both tools measure the same traffic.
    """
    out = []
    for _ in range(personas):
        persona = rng.randint(0, vocab_size, size=persona_len)
        for _ in range(users):
            ulen = int(rng.randint(user_lo, user_hi + 1))
            user = rng.randint(0, vocab_size, size=ulen)
            gen = int(rng.randint(gen_lo, gen_hi + 1))
            out.append((np.concatenate([persona, user]).astype(np.int32),
                        gen))
    return out


def make_workload(cfg, rng, args):
    """(prompt, gen) pairs submitted at ``i // 2`` arrivals.

    Default: independent mixed-length prompts. ``--shared-prefix`` builds
    the persona trace instead (``persona_workload``) so concurrent streams
    share their dominant prefix and admission can skip its prefill.
    """
    if args.shared_prefix:
        user_hi = max(args.user_len, 2)
        out = persona_workload(cfg.vocab_size, rng, args.personas,
                               args.users_per_persona, args.persona_len,
                               max(user_hi // 2, 1), user_hi,
                               max(args.gen // 2, 1), args.gen)
        # an explicit --requests caps the trace (personas x users otherwise)
        if args.requests is not None:
            out = out[:args.requests]
        return out
    out = []
    for _ in range(args.requests if args.requests is not None else 8):
        plen = int(rng.randint(max(args.prompt_len // 2, 1),
                               args.prompt_len + 1))
        gen = int(rng.randint(max(args.gen // 2, 1), args.gen + 1))
        out.append((rng.randint(0, cfg.vocab_size, size=plen), gen))
    return out


def _max_seq(args) -> int:
    if args.shared_prefix:
        return args.persona_len + max(args.user_len, 2) + args.gen + 8
    return args.prompt_len + args.gen + 8


def _prefix_stats(stats: dict) -> dict:
    out = {"prefix_hits": stats.get("prefix_hits", 0),
           "cached_tokens": stats.get("cached_tokens", 0),
           "cow_forks": stats.get("cow_forks", 0)}
    if stats.get("prefills"):
        out["prefix_hit_rate"] = round(out["prefix_hits"]
                                       / stats["prefills"], 3)
    return out


def _spec_kw(args) -> dict:
    """Speculative-decoding knobs for the scheduler/router constructors.

    The draft model is a reduced config initialised from its own seed —
    it shares only the tokenizer (vocab) with the target; the scheduler
    validates that at construction.
    """
    if getattr(args, "spec", None) is None:
        return {}
    draft = None
    if args.spec_draft:
        dcfg = get_reduced(args.spec_draft)
        draft = (dcfg, M.init(dcfg, jax.random.PRNGKey(args.seed + 1)))
    return {"spec_k": args.spec, "spec_draft": draft}


def _parse_tenants(spec):
    """``"free=32,pro=128"`` -> ``{"free": 32, "pro": 128}`` page quotas."""
    if not spec:
        return None
    out = {}
    for part in spec.split(","):
        name, _, pages = part.partition("=")
        name, pages = name.strip(), pages.strip()
        if not name or not pages.isdigit():
            raise ValueError(
                f"--tenants expects name=pages[,name=pages...], got {spec!r}")
        out[name] = int(pages)
    return out


def _tier_kw(args) -> dict:
    """Host-RAM KV tier knobs for the scheduler/router constructors."""
    if getattr(args, "host_pages", None) is None:
        return {}
    return {"host_pages": args.host_pages,
            "tenant_quotas": _parse_tenants(getattr(args, "tenants", None)),
            "swap_crossover": getattr(args, "swap_crossover", None)}


def _submit_kw(args, i: int) -> dict:
    """Per-request tenant/priority tags: requests round-robin over the
    declared tenants (first tenant = priority 2, the rest priority 1) so a
    --tenants run exercises both quota classes without a trace format."""
    quotas = _parse_tenants(getattr(args, "tenants", None))
    if not quotas:
        return {}
    names = sorted(quotas)
    name = names[i % len(names)]
    return {"tenant": name, "priority": 2 if name == names[0] else 1}


def _tier_stats(out: dict, args, stats) -> None:
    if getattr(args, "host_pages", None) is None:
        return
    out["host_pages"] = args.host_pages
    for k in ("swap_outs", "swap_ins", "swap_out_pages", "swap_in_pages",
              "swap_reprefills", "host_evictions", "quota_blocked",
              "index_evictions"):
        out[k] = stats[k]


def _spec_stats(out: dict, args, stats) -> None:
    if not getattr(args, "spec", None):
        return
    out["spec_k"] = args.spec
    if args.spec_draft:
        out["spec_draft"] = args.spec_draft
    for k in ("spec_ticks", "spec_drafted", "spec_accepted",
              "spec_accept_rate"):
        out[k] = stats[k]


def run_fleet(cfg, params, args) -> dict:
    """Replicated fabric: k scheduler replicas behind one router."""
    from repro.serving.router import ServingRouter
    if not supports_paged(cfg):
        raise SystemExit(f"{cfg.name}: use --engine static (MLA/enc-dec)")
    rng = np.random.RandomState(args.seed)
    max_seq = _max_seq(args)
    # a disaggregated fleet needs one live replica per role, so the
    # autoscale floor is (disagg prefill + 1 decode) instead of 1
    start = args.replicas if not args.autoscale \
        else (args.disagg + 1 if args.disagg else 1)
    router = ServingRouter(cfg, params, replicas=start,
                           max_slots=args.batch, page_size=args.page_size,
                           max_seq_len=max_seq, route_policy=args.router,
                           prefix_cache=args.prefix_cache, tp=args.tp,
                           prefill_budget=args.chunked_prefill,
                           disagg=args.disagg, **_spec_kw(args),
                           **_tier_kw(args))
    tracer = None
    if args.trace_out or (args.events_out and not args.autoscale):
        tracer = Tracer()
        router.set_tracer(tracer)
    profiler = router.enable_profiling() if args.profile else None
    ctl = None
    if args.autoscale:
        from repro.autoscale import FleetController
        ctl = FleetController(router, min_replicas=start,
                              max_replicas=args.replicas, eval_interval=2)
    for i, (prompt, gen) in enumerate(make_workload(cfg, rng, args)):
        router.submit(prompt, gen, arrival_step=i // 2, **_submit_kw(args, i))

    t0 = time.time()
    done = ctl.run() if ctl else router.run()
    wall = time.time() - t0
    fleet = router.fleet_stats()
    lat = [float(r.finish_step - r.arrival_step) for r in done]
    out = {
        "engine": "fleet",
        "arch": cfg.name,
        "replicas": args.replicas,
        "tp": args.tp,
        "router": args.router,
        "disagg": args.disagg,
        "requests": len(done),
        "tokens_out": fleet["tokens_out"],
        "tok_per_s": round(fleet["tokens_out"] / wall, 1),
        "fleet_ticks": fleet["fleet_ticks"],
        "p50_latency_ticks": percentile(lat, 50),
        "p99_latency_ticks": percentile(lat, 99),
        "spillovers": fleet["spillovers"],
        "reroutes": fleet["reroutes"],
        "generated": [r.out_tokens[:8] for r in done[:4]],
    }
    if args.chunked_prefill:
        out["chunked_prefill"] = args.chunked_prefill
        out["prefill_chunk_tokens"] = fleet.get("prefill_chunk_tokens", 0)
    if args.disagg:
        out["migrations"] = router.stats.get("migrations", 0)
    _spec_stats(out, args, fleet)
    _tier_stats(out, args, fleet)
    out.update(_prefix_stats(fleet))
    if fleet.get("reserved_page_imbalance") is not None:
        out["reserved_page_imbalance"] = fleet["reserved_page_imbalance"]
    if ctl is not None:
        out["autoscale"] = ctl.summary()
    _finish_obs(out, args, tracer, profiler, router.expose, ctl=ctl)
    return out


def run_paged(cfg, params, args) -> dict:
    if not supports_paged(cfg):
        raise SystemExit(f"{cfg.name}: use --engine static (MLA/enc-dec)")
    rng = np.random.RandomState(args.seed)
    max_seq = _max_seq(args)
    n_pg = PC.pages_for_len(max_seq, args.page_size)
    start_slots = 1 if args.autoscale else args.batch
    sched = ContinuousBatchingScheduler(
        cfg, params, max_slots=start_slots, page_size=args.page_size,
        num_pages=start_slots * n_pg + 1 if args.autoscale else None,
        max_seq_len=max_seq, prefix_cache=args.prefix_cache, tp=args.tp,
        prefill_budget=args.chunked_prefill, **_spec_kw(args),
        **_tier_kw(args))
    tracer = None
    if args.trace_out or (args.events_out and not args.autoscale):
        tracer = Tracer()
        sched.set_tracer(tracer)
    profiler = sched.enable_profiling() if args.profile else None
    ctl = None
    if args.autoscale:
        from repro.autoscale import AutoscaleController, CapacityBands
        bands = CapacityBands(min_slots=1, max_slots=args.batch,
                              min_pages=n_pg + 1,
                              max_pages=args.batch * n_pg + 1)
        ctl = AutoscaleController(sched, bands, eval_interval=2)
    for i, (prompt, gen) in enumerate(make_workload(cfg, rng, args)):
        sched.submit(prompt, gen, arrival_step=i // 2, **_submit_kw(args, i))

    t0 = time.time()
    done = ctl.run() if ctl else sched.run()
    wall = time.time() - t0
    toks = sched.stats["tokens_out"]
    out = {
        "engine": "paged",
        "arch": cfg.name,
        "tp": args.tp,
        "requests": len(done),
        "decode_steps": sched.stats["decode_steps"],
        "tokens_out": toks,
        "tok_per_s": round(toks / wall, 1),
        # under --autoscale the allocated width varies, so occupancy is
        # decode tokens over *paid* slot-ticks, not a fixed --batch width
        "mean_occupancy": round(
            (toks - sched.stats["prefills"])
            / max(ctl.slot_ticks if ctl is not None
                  else sched.stats["decode_steps"] * args.batch, 1), 3),
        "peak_pages": sched.stats["peak_pages"],
        "generated": [r.out_tokens[:8] for r in done[:4]],
    }
    if args.tp > 1:
        out["shards"] = sched.shard_stats()
    if args.chunked_prefill:
        out["chunked_prefill"] = args.chunked_prefill
        out["prefill_chunk_tokens"] = sched.stats["prefill_chunk_tokens"]
    _spec_stats(out, args, sched.stats)
    _tier_stats(out, args, sched.stats)
    out.update(_prefix_stats(sched.stats))
    if ctl is not None:
        out["autoscale"] = ctl.summary()
    _finish_obs(out, args, tracer, profiler, sched.registry.expose, ctl=ctl)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--engine", default="static",
                    choices=("static", "paged"))
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch / paged decode slots")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=None,
                    help="paged engine: workload size (default 8; with "
                    "--shared-prefix the default is personas x "
                    "users-per-persona and an explicit value caps it)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=1,
                    help="paged engine: serve through the replicated "
                    "fabric with this many scheduler replicas (with "
                    "--autoscale this is the fleet ceiling)")
    ap.add_argument("--tp", type=int, default=1,
                    help="paged engine: tensor-parallel shard group width "
                    "— each scheduler/replica spans this many shards "
                    "(page pools and attention heads split tp ways; "
                    "tokens are byte-identical to --tp 1, see "
                    "docs/sharding.md)")
    ap.add_argument("--router", default="least-pages",
                    choices=("least-pages", "round-robin",
                             "prefix-affinity"),
                    help="fabric routing policy (--replicas > 1); "
                    "prefix-affinity sends a request to the replica whose "
                    "page pool caches its longest prompt prefix")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="paged engine: serve a persona workload "
                    "(--personas system prompts x --users-per-persona "
                    "suffixes) so the copy-on-write prefix cache shares "
                    "each persona's pages and skips its prefill")
    ap.add_argument("--personas", type=int, default=4,
                    help="--shared-prefix: distinct shared system prompts")
    ap.add_argument("--users-per-persona", type=int, default=8,
                    help="--shared-prefix: concurrent users per persona")
    ap.add_argument("--persona-len", type=int, default=64,
                    help="--shared-prefix: tokens per persona prompt")
    ap.add_argument("--user-len", type=int, default=16,
                    help="--shared-prefix: max tokens per user suffix")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=None,
                    help="disable shared-prefix admission (the no-sharing "
                    "baseline; default: on except MoE archs)")
    ap.add_argument("--chunked-prefill", type=int, default=None,
                    metavar="N",
                    help="paged engine: land each prompt in chunks of at "
                    "most N tokens per tick, interleaved with decode "
                    "ticks (tokens stay byte-identical to monolithic "
                    "prefill)")
    ap.add_argument("--disagg", type=int, nargs="?", const=1, default=0,
                    metavar="K",
                    help="fleet only: dedicate K replicas to prefill and "
                    "the rest to decode, with verbatim KV-page handoff "
                    "between the roles (requires --replicas > K)")
    ap.add_argument("--autoscale", action="store_true",
                    help="paged engine: start at 1 slot and let the "
                    "autoscale control plane move capacity inside "
                    "[1, --batch]; with --replicas > 1 the fleet "
                    "controller moves whole replicas instead (see "
                    "docs/autoscaling.md)")
    ap.add_argument("--events-out", default=None,
                    help="write the run's event log as JSON lines for "
                    "replay: scale decisions under --autoscale, the "
                    "request-lifecycle trace otherwise")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="paged engine: write the request-lifecycle trace "
                    "as Chrome trace-event JSON (open in Perfetto / "
                    "chrome://tracing; see docs/observability.md)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="paged engine: dump the typed metric registries "
                    "in Prometheus text exposition at end of run")
    ap.add_argument("--spec", type=int, default=None, metavar="K",
                    help="paged engine: speculative decoding — draft and "
                    "batch-verify up to K tokens per stream per tick "
                    "(greedy accept keeps tokens byte-identical to spec "
                    "off; drafts come from n-gram prompt lookup unless "
                    "--spec-draft names a model)")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH",
                    choices=sorted(ARCHS),
                    help="reduced draft model for --spec (attention-only, "
                    "sharing the target's vocab), decoding through an "
                    "incremental paged cache mirroring the target's page "
                    "geometry; default is model-free n-gram lookup")
    ap.add_argument("--host-pages", type=int, default=None, metavar="N",
                    help="paged engine: host-RAM KV page tier of N pages "
                    "per scheduler — finished sessions' chains are "
                    "retained for resume and preempted to host RAM under "
                    "HBM pressure (recompute-vs-transfer cost model; see "
                    "docs/serving.md)")
    ap.add_argument("--tenants", default=None, metavar="NAME=PAGES,...",
                    help="per-tenant page quotas, e.g. free=32,pro=128; "
                    "workload requests round-robin over the tenants and "
                    "the first (sorted) tenant submits at priority 2 "
                    "(requires --host-pages)")
    ap.add_argument("--swap-crossover", type=int, default=None, metavar="T",
                    help="override the cost model's recompute-vs-transfer "
                    "decision point: chains of >= T tokens swap to host, "
                    "shorter ones re-prefill (default: derived from the "
                    "roofline constants in repro.obs.profile)")
    ap.add_argument("--profile", action="store_true",
                    help="paged engine: wall-time every kernel dispatch "
                    "and report modeled FLOPs/bytes + roofline fractions "
                    "in the result JSON (read-only; tokens unchanged)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.autoscale and args.engine != "paged":
        ap.error("--autoscale requires --engine paged")
    for flag, val in (("--events-out", args.events_out),
                      ("--trace-out", args.trace_out),
                      ("--metrics-out", args.metrics_out),
                      ("--profile", args.profile)):
        if val and args.engine != "paged":
            ap.error(f"{flag} requires --engine paged (the static engine "
                     "has no scheduler to observe)")
    if args.replicas > 1 and args.engine != "paged":
        ap.error("--replicas requires --engine paged (the fabric routes "
                 "over paged schedulers)")
    if args.shared_prefix and args.engine != "paged":
        ap.error("--shared-prefix requires --engine paged (only the paged "
                 "cache can share prefix pages)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.tp < 1:
        ap.error("--tp must be >= 1")
    if args.tp > 1 and args.engine != "paged":
        ap.error("--tp requires --engine paged (shard groups split the "
                 "paged KV pools)")
    if args.chunked_prefill is not None:
        if args.engine != "paged":
            ap.error("--chunked-prefill requires --engine paged")
        if args.chunked_prefill < 1:
            ap.error("--chunked-prefill must be >= 1")
    if args.spec is not None:
        if args.engine != "paged":
            ap.error("--spec requires --engine paged (speculation lives in "
                     "the continuous-batching scheduler)")
        if not 1 <= args.spec <= 32:
            ap.error("--spec must be in [1, 32]")
    if args.spec_draft and args.spec is None:
        ap.error("--spec-draft requires --spec")
    if args.disagg:
        if args.engine != "paged" or args.replicas < 2:
            ap.error("--disagg requires --engine paged and --replicas >= 2 "
                     "(one replica per role at minimum)")
        if args.disagg >= args.replicas:
            ap.error("--disagg must leave at least one decode replica "
                     "(--disagg < --replicas)")
    if args.host_pages is not None:
        if args.engine != "paged":
            ap.error("--host-pages requires --engine paged (the host tier "
                     "holds paged KV chains)")
        if args.host_pages < 1:
            ap.error("--host-pages must be >= 1")
        if args.prefix_cache is False:
            ap.error("--host-pages requires the prefix cache (session "
                     "chains are retained through the prefix index; drop "
                     "--no-prefix-cache)")
    for flag, val in (("--tenants", args.tenants),
                      ("--swap-crossover", args.swap_crossover)):
        if val is not None and args.host_pages is None:
            ap.error(f"{flag} requires --host-pages (tier features live "
                     "on the host-RAM page tier)")
    if args.swap_crossover is not None and args.swap_crossover < 1:
        ap.error("--swap-crossover must be >= 1")
    if args.tenants is not None:
        try:
            _parse_tenants(args.tenants)
        except ValueError as e:
            ap.error(str(e))

    cfg = get_reduced(args.arch)
    params = M.init(cfg, jax.random.PRNGKey(args.seed))
    if args.engine != "paged":
        runner = run_static
    elif args.replicas > 1:
        runner = run_fleet
    else:
        runner = run_paged
    out = runner(cfg, params, args)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
