"""Provisioning event log — lets tests assert the paper's Fig. 1 sequence."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Event:
    t: float
    actor: str        # "slave-3", "master", "cloud"
    action: str       # e.g. "create_temp_user"
    detail: Dict[str, Any]


class EventLog:
    def __init__(self) -> None:
        self.events: List[Event] = []

    def emit(self, t: float, actor: str, action: str, **detail: Any) -> None:
        self.events.append(Event(t, actor, action, dict(detail)))

    def actions(self, actor: Optional[str] = None) -> List[str]:
        return [e.action for e in self.events
                if actor is None or e.actor == actor
                or (actor.endswith("*") and e.actor.startswith(actor[:-1]))]

    def first_index(self, action: str) -> int:
        for i, e in enumerate(self.events):
            if e.action == action:
                return i
        raise KeyError(action)

    def last_index(self, action: str) -> int:
        idx = -1
        for i, e in enumerate(self.events):
            if e.action == action:
                idx = i
        if idx < 0:
            raise KeyError(action)
        return idx

    def assert_order(self, *actions: str) -> None:
        """Every listed action occurs, in the given order (first occurrences,
        except consecutive duplicates which use last-of-previous)."""
        prev = -1
        for a in actions:
            idx = next((i for i, e in enumerate(self.events)
                        if e.action == a and i > prev), None)
            if idx is None:
                raise AssertionError(
                    f"action {a!r} not found after index {prev} "
                    f"(log: {[e.action for e in self.events]})")
            prev = idx
