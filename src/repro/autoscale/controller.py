"""Autoscale controller: metrics in, ScaleDecisions out, resizes applied.

The control loop runs synchronously between decode ticks:

1. **sample** — scheduler + heartbeat signals land on the telemetry bus;
2. **decide** — every ``eval_interval`` ticks the policies run against
   windowed aggregates and emit ``ScaleDecision``s;
3. **actuate** — slot targets are snapped to power-of-two buckets (each
   distinct shape costs one jit re-trace, so the bucket ladder bounds the
   number of compiled programs), the page pool follows the slot target
   (worst-case pages per slot) unless a dedicated page policy is given,
   and ``ContinuousBatchingScheduler.resize`` applies the change —
   drain-before-shrink and reservation-aware by construction.

When cluster-wired (``lifecycle``/``cluster``), the slot ceiling is what
the current node fleet provides (``slots_per_node``): scaling out first
extends the cluster through ``ClusterLifecycle.extend`` and the new slots
become usable only after ``node_boot_ticks`` (boot latency); scaling in
drains slots first, then shrinks the emptied nodes away. Spot preemption
notices from SimCloud are handled by draining the lost capacity and
replacing the instance from the warm-spare pool when one is available.

Cost accounting is tick-integrated (``instance_ticks`` — node-ticks, and
``slot_ticks``) so benchmarks compare instance-seconds deterministically
on the simulated clock; see ``benchmarks/autoscale_bench.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

from repro.autoscale.metrics import (TelemetryBus, sample_monitor,
                                     sample_scheduler)
from repro.autoscale.policy import ScaleDecision, TargetTrackingPolicy
from repro.core.events import EventLog


@dataclasses.dataclass(frozen=True)
class CapacityBands:
    """Min/max capacity the policies may move within (blueprint-derived)."""
    min_slots: int
    max_slots: int
    min_pages: int
    max_pages: int

    @staticmethod
    def from_plan(plan: Dict[str, Any]) -> "CapacityBands":
        """Build bands from a ``serving_page_plan`` suggestion dict."""
        return CapacityBands(
            min_slots=plan["min_slots"], max_slots=plan["max_slots"],
            min_pages=plan["min_pages"], max_pages=plan["max_pages"])


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (slot targets snap up, never starving)."""
    return 1 << max(n - 1, 1).bit_length() if n > 1 else 1


def default_slot_policy(bands: CapacityBands) -> TargetTrackingPolicy:
    """Track (active + queued) / slots toward 80% occupancy. Quantized to
    the actuator's pow2 buckets so a desired value that buckets back to the
    current capacity is a non-decision (no cooldown burned, no log entry)."""
    return TargetTrackingPolicy(
        metric="demand_per_slot", target=0.8, tolerance=0.15,
        min_cap=bands.min_slots, max_cap=bands.max_slots,
        cooldown_in=24.0, cooldown_out=0.0, resource="slots",
        quantize=pow2_bucket)


class AutoscaleController:
    def __init__(self, sched, bands: CapacityBands, *,
                 slot_policy=None, page_policy=None,
                 eval_interval: int = 8, tick_seconds: float = 1.0,
                 slots_per_node: Optional[int] = None,
                 node_boot_ticks: int = 0,
                 lifecycle=None, cluster=None, monitor=None,
                 log: Optional[EventLog] = None, slo_monitors=None):
        self.sched = sched
        self.bands = bands
        self.slot_policy = slot_policy or default_slot_policy(bands)
        self.page_policy = page_policy        # None -> pages follow slots
        self.eval_interval = eval_interval
        self.tick_seconds = tick_seconds
        self.bus = TelemetryBus()
        # SLO burn-rate monitors (repro.obs.slo): sampled each tick, their
        # signals join the bus so policies can target burn rates directly
        self.slo_monitors = list(slo_monitors or [])
        self.monitor = monitor
        self.lifecycle = lifecycle
        self.cluster = cluster
        self.log = log if log is not None else (
            cluster.log if cluster is not None else EventLog())
        self.decisions: List[ScaleDecision] = []

        # ---- node fleet model -------------------------------------------
        self.slots_per_node = slots_per_node
        self.node_boot_ticks = node_boot_ticks
        if slots_per_node:
            self.nodes_ready = math.ceil(sched.target_slots / slots_per_node)
        else:
            self.nodes_ready = 0
        self._booting: List[tuple] = []        # (ready_tick, count)

        # ---- accounting --------------------------------------------------
        self.instance_ticks = 0.0              # node-ticks (cost integral)
        self.slot_ticks = 0.0
        self.capacity_log: List[tuple] = []    # (tick, nodes, slots, pages)
        self._last_tick = sched.step_idx
        self._next_eval = sched.step_idx

        sched.capacity_hint = bands.max_pages
        if cluster is not None and lifecycle is not None:
            lifecycle.cloud.on_preempt(self._on_preempt)

    # ------------------------------------------------------------- clock --
    @property
    def now(self) -> float:
        return self.sched.step_idx * self.tick_seconds

    def _nodes_total(self) -> int:
        return self.nodes_ready + sum(c for _, c in self._booting)

    # --------------------------------------------------------------- tick --
    def tick(self) -> None:
        """One control-loop pass. ``run`` calls this *before* each scheduler
        step: newly due requests are sampled as queue depth and the resize
        lands before that tick's admission, so with warm capacity
        (``node_boot_ticks == 0`` — the paper's fast-provisioning pitch) a
        reactive scale-out adds zero admission latency over static peak
        provisioning."""
        t = self.sched.step_idx
        elapsed = t - self._last_tick        # fused/idle steps advance >1
        self._last_tick = t
        if elapsed > 0:
            # billed while booting too — that is what makes over-eager
            # scale-out cost real in the benchmark
            self.instance_ticks += elapsed * self._nodes_total()
            # bill the allocated width (max_slots): a draining shrink keeps
            # decoding at the old width until its last request finishes
            self.slot_ticks += elapsed * self.sched.max_slots

        still_booting = []
        for ready, count in self._booting:
            if t >= ready:
                self.nodes_ready += count
            else:
                still_booting.append((ready, count))
        if len(still_booting) != len(self._booting):
            self._booting = still_booting
            self._apply_slot_target(self._desired_slots_cache)
        self._shrink_nodes()    # release nodes whose drain completed

        sample = sample_scheduler(self.sched)
        sample["demand_per_slot"] = sample["demand"] / max(sample["slots"], 1)
        sample.update(sample_monitor(self.monitor))
        for m in self.slo_monitors:
            sample.update(m.sample(t * self.tick_seconds))
        self.bus.record(t * self.tick_seconds, sample)

        if t >= self._next_eval:
            self._next_eval = t + self.eval_interval
            self._evaluate()

    _desired_slots_cache: int = 0

    def _evaluate(self) -> None:
        """Run the policies on windowed-max aggregates over the last eval
        interval: scale-out still sees this tick's spike at full strength
        (the freshest sample is in the window), while scale-in waits until
        the *whole* window is quiet — smoothing over single-tick dips."""
        now = self.now
        horizon = self.eval_interval * self.tick_seconds
        d = self.slot_policy.evaluate(
            now, self.bus.max(self.slot_policy.metric, horizon),
            int(self.sched.target_slots))
        if d is not None:
            self._record(d)
            self._scale_slots(d.desired)
        if self.page_policy is not None:
            dp = self.page_policy.evaluate(
                now, self.bus.max(self.page_policy.metric, horizon),
                int(self.sched.alloc.capacity + 1))
            if dp is not None:
                self._record(dp)
                self._scale_pages(dp.desired)

    def _record(self, d: ScaleDecision) -> None:
        self.decisions.append(d)
        self.log.emit(d.at, "autoscale", f"scale_{d.direction}",
                      resource=d.resource, desired=d.desired, delta=d.delta,
                      reason=d.reason)
        if self.sched.tracer is not None:
            self.sched.tracer.instant(
                "autoscale", t=self.sched.step_idx,
                direction=d.direction, resource=d.resource,
                desired=d.desired, delta=d.delta, reason=d.reason)

    # ----------------------------------------------------------- actuate --
    def _scale_slots(self, desired: int) -> None:
        desired = max(self.bands.min_slots,
                      min(self.bands.max_slots, pow2_bucket(desired)))
        self._desired_slots_cache = desired
        if self.slots_per_node:
            need_nodes = math.ceil(desired / self.slots_per_node)
            if need_nodes > self._nodes_total():
                self._extend_nodes(need_nodes - self._nodes_total())
        self._apply_slot_target(desired)    # node release: tick() handles it

    def _apply_slot_target(self, desired: int) -> None:
        if desired <= 0:
            return
        if self.slots_per_node:
            ceiling = max(self.nodes_ready * self.slots_per_node,
                          self.bands.min_slots)
            desired = min(desired, ceiling)
        if desired != self.sched.target_slots:
            self.sched.resize(max_slots=desired)
        if self.page_policy is None:
            # pages follow slots: worst-case pages per slot (+ sink), so a
            # page resize only ever happens together with a slot resize
            self._scale_pages(desired * self.sched.n_pg + 1)

    def _scale_pages(self, desired: int) -> None:
        desired = max(self.bands.min_pages,
                      min(self.bands.max_pages, desired))
        if desired != self.sched.alloc.effective_pages:
            self.sched.resize(num_pages=desired)

    # ------------------------------------------------------------- nodes --
    def _extend_nodes(self, n: int) -> None:
        t = self.sched.step_idx
        if self.lifecycle is not None and self.cluster is not None:
            self.lifecycle.extend(self.cluster, n)
            if self.monitor is not None:
                for node in self.cluster.directory.slaves()[-n:]:
                    self.monitor.register(node.hostname,
                                          now=self.lifecycle.cloud.clock)
        if self.node_boot_ticks == 0:
            self.nodes_ready += n       # warm-pool attach: usable this tick
        else:
            self._booting.append((t + self.node_boot_ticks, n))
        self.log.emit(self.now, "autoscale", "extend_nodes", n=n,
                      ready_tick=t + self.node_boot_ticks)

    def _shrink_nodes(self) -> None:
        """Release nodes whose slots have fully drained."""
        if not self.slots_per_node:
            return
        # only shrink nodes made idle by a *completed* slot shrink
        needed = math.ceil(self.sched.max_slots / self.slots_per_node)
        needed = max(needed, math.ceil(self.bands.min_slots
                                       / self.slots_per_node), 1)
        excess = self.nodes_ready - needed
        if excess <= 0:
            return
        self.nodes_ready = needed
        if self.lifecycle is not None and self.cluster is not None:
            victims = [n.hostname for n in
                       self.cluster.directory.slaves()[-excess:]]
            self.lifecycle.shrink(self.cluster, victims)
            if self.monitor is not None:
                for hn in victims:
                    self.monitor.deregister(hn)
        self.log.emit(self.now, "autoscale", "release_nodes", n=excess)

    def _on_preempt(self, inst) -> None:
        """SimCloud preemption notice: replace from the warm-spare pool if
        one is ready, otherwise drain the lost capacity."""
        if self.cluster is None:
            return
        hostname = None
        for node in self.cluster.directory.slaves():
            if node.instance_id == inst.instance_id:
                hostname = node.hostname
                break
        if hostname is None:
            return                              # not ours (e.g. a spare)
        if self.lifecycle.spares:
            self.lifecycle.replace_failed(self.cluster, hostname)
            self.log.emit(self.now, "autoscale", "preempt_replaced",
                          hostname=hostname)
        else:
            # no spare: drop the dead host from the fleet bookkeeping
            # (directory + monitor) and drain the lost slot capacity
            self.lifecycle.shrink(self.cluster, [hostname])
            if self.monitor is not None:
                self.monitor.deregister(hostname)
            self.nodes_ready = max(self.nodes_ready - 1, 1)
            ceiling = self.nodes_ready * (self.slots_per_node or
                                          self.sched.target_slots)
            self.sched.resize(max_slots=max(min(ceiling,
                                                self.sched.target_slots), 1))
            self.log.emit(self.now, "autoscale", "preempt_drained",
                          hostname=hostname, new_slots=self.sched.target_slots)

    # ---------------------------------------------------------------- run --
    def snapshot(self) -> None:
        self.capacity_log.append(
            (self.sched.step_idx, self._nodes_total(),
             self.sched.target_slots, self.sched.alloc.effective_pages))

    def run(self, max_steps: int = 100_000) -> list:
        """Drive the scheduler to completion under the control loop.

        ``max_fuse`` is capped at ``eval_interval`` so the controller gets
        a look-in at least once per interval even when decode fuses ticks.
        """
        sched = self.sched
        while (sched.waiting or sched.num_active) and max_steps:
            self.tick()                 # decide *before* this tick's admission
            sched.step(max_fuse=max(self.eval_interval, 1))
            self.snapshot()
            max_steps -= 1
        if sched.waiting or sched.num_active:
            raise RuntimeError("autoscale run exhausted max_steps")
        self.tick()                     # settle accounting for the last span
        sched._settle_resize()
        return sched.finished

    # ------------------------------------------------------------ summary --
    def summary(self) -> Dict[str, Any]:
        out = {
            "slot_seconds": self.slot_ticks * self.tick_seconds,
            "decisions": len(self.decisions),
            "scale_out": sum(1 for d in self.decisions if d.delta > 0),
            "scale_in": sum(1 for d in self.decisions if d.delta < 0),
            "peak_slots": max((s for _, _, s, _ in self.capacity_log),
                              default=self.sched.target_slots),
            "final_slots": self.sched.target_slots,
        }
        if self.slots_per_node:
            # node-level cost only exists when the controller is node-wired;
            # engine-only controllers report slot_seconds alone rather than
            # a misleading 0.0
            out["instance_seconds"] = self.instance_ticks * self.tick_seconds
        return out
