"""Quickstart: the full InstaCluster-on-TPU story in one script.

1. build a cluster (Fig. 1 provisioning + service install) in one call,
2. suggest a deployment blueprint for an assigned architecture,
3. submit a small training job through the interaction hub,
4. browse the checkpoints it wrote.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs.base import ShapeConfig
from repro.configs.registry import REDUCED
from repro.core.blueprint import suggest_plan
from repro.core.cluster import ClusterManager
from repro.launch.mesh import make_mesh_for
from repro.optim.adamw import OptimConfig
from repro.train.trainer import Trainer


def main() -> None:
    # -- 1. cluster provisioning + service provisioning -------------------
    mgr = ClusterManager()
    ic = mgr.build_cluster(n_slaves=4,
                           services=("hdfs", "yarn", "spark", "hue"))
    print(f"cluster up in {ic.bringup_seconds/60:.1f} simulated minutes "
          f"({ic.cluster.directory.total_chips()} chips)")
    print("hosts file:\n" + ic.cluster.directory.hosts_file())
    print("service pages:", ic.hue.service_pages())

    # -- 2. blueprint: Ambari-style suggested configuration ----------------
    cfg = REDUCED["gemma2-2b"]
    mesh = make_mesh_for(1, 1)
    plan = suggest_plan(cfg, ShapeConfig("demo", 64, 4, "train"), mesh)
    print(f"blueprint: remat={plan.remat} notes={list(plan.notes)}")

    # -- 3. submit a train job through the hub (use case 6) ----------------
    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(cfg, OptimConfig(peak_lr=1e-3, warmup_steps=5,
                                           total_steps=50),
                          batch=4, seq=64, ckpt_dir=ckdir, ckpt_every=10)

        def train_job():
            report = trainer.run(20)
            return {"first_loss": round(report.losses[0], 3),
                    "last_loss": round(report.losses[-1], 3),
                    "steps": report.final_step}

        job = ic.hue.submit_job("spark", train_job)
        print(f"train job: {job.status} -> {job.result}")
        assert job.result["last_loss"] < job.result["first_loss"]

        # -- 4. browse checkpoints (use case 5) ----------------------------
        for step in trainer.ckpt.all_steps():
            ic.hue.upload_file(f"/checkpoints/step_{step:08d}/manifest.json",
                               b"{}")
        print("checkpoint browser:", ic.hue.browse_storage("/checkpoints"))

    # -- reproducibility: export the environment spec -----------------------
    print("cluster spec for the paper's reproducibility story:")
    print(ic.spec_json())


if __name__ == "__main__":
    main()
